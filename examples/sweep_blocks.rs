//! Fig.-3-style sweep: accuracy / loss vs % of blocks selected.
//!
//! Reproduces the paper's preliminary study (Gradient-Guided Block
//! Selection, Algorithm 1) on any preset, printing one row per setting and
//! writing the CSV the plotting side of Fig. 3 consumes.
//!
//! ```bash
//! cargo run --release --example sweep_blocks -- --preset test-tiny --steps 60
//! cargo run --release --example sweep_blocks -- --preset qwen-sim --steps 300
//! ```

use std::path::PathBuf;

use adagradselect::experiments::{fig3_on, ExpOptions};
use adagradselect::runtime::ReferenceBackend;
use adagradselect::util::cli::Args;
use adagradselect::Result;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut args = Args::parse(&argv, &[])?;
    let preset = args.str_or("preset", "test-tiny");
    let steps = args.u64_or("steps", 60)?;
    let eval_problems = args.usize_or("eval-problems", 64)?;
    let pcts_raw = args.str_or("pcts", "10,20,30,50,75,100");
    let out = args.str_or("out", "results");
    args.finish()?;

    let pcts: Vec<f64> =
        pcts_raw.split(',').filter_map(|s| s.trim().parse().ok()).collect();
    let engine = ReferenceBackend::new();
    let opt = ExpOptions {
        artifacts_dir: PathBuf::from("artifacts"),
        out_dir: PathBuf::from(&out),
        steps,
        steps_per_epoch: (steps / 3).max(1),
        eval_problems,
        seed: 0,
    };
    println!("sweeping {preset} over pcts {pcts:?} ({steps} steps each)\n");
    println!("{:>6} {:>12} {:>12}", "pct", "gsm8k-sim", "math-sim");
    for (pct, gsm, math) in fig3_on(&engine, &opt, &preset, &pcts)? {
        println!("{pct:>5}% {:>11.1}% {:>11.1}%", gsm * 100.0, math * 100.0);
    }
    println!("\nCSV written to {out}/fig3_accuracy_vs_pct.csv");
    Ok(())
}
