//! End-to-end validation driver (DESIGN.md §6).
//!
//! Trains the `e2e` preset (the largest exported model) on the synthetic
//! math corpus with AdaGradSelect, logging the loss curve, running
//! periodic held-out evals, and finishing with greedy-decode accuracy on
//! both suites — proving the backend (native fwd/bwd) and the coordinator
//! (selection/optimizer/residency/data/eval) compose. The reference
//! run is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example e2e_train -- --steps 400 --method adagradselect
//! ```
//!
//! `--metrics-out PATH` writes the trainer's metric registry (step
//! counters, loss/lr and transfer gauges, the step-latency histogram) as
//! a Prometheus-style exposition at `PATH` plus a JSON snapshot at
//! `PATH.json`; `--trace-out PATH` records phase spans
//! (decide/h2d/execute/norms/choose/optimizer/d2h) and writes a Chrome
//! trace-event file for chrome://tracing or Perfetto.
//!
//! `--shards N` trains data-parallel instead: N worker backends over
//! deterministic batch shards with the selection-gated all-reduce
//! (bit-identical losses to `--shards 1`), reporting the modeled
//! communication bytes per step from the `train_comm_*` counters.

use std::path::PathBuf;

use adagradselect::config::{Method, RunConfig};
use adagradselect::data::{MathGen, Split, Suite};
use adagradselect::eval::Evaluator;
use adagradselect::runtime::{Backend, ReferenceBackend};
use adagradselect::telemetry::CsvWriter;
use adagradselect::train::{ShardedTrainer, Trainer};
use adagradselect::util::cli::Args;
use adagradselect::Result;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut args = Args::parse(&argv, &[])?;
    let preset = args.str_or("preset", "e2e");
    let steps = args.u64_or("steps", 400)?;
    let pct = args.f64_or("pct", 30.0)?;
    let method = args.str_or("method", "adagradselect");
    let eval_every = args.u64_or("eval-every", 100)?;
    let shards = args.u64_or("shards", 1)? as usize;
    let out = PathBuf::from(args.str_or("out", "results"));
    let metrics_out = args.str_opt("metrics-out");
    let trace_out = args.str_opt("trace-out");
    args.finish()?;
    std::fs::create_dir_all(&out).ok();

    let engine = ReferenceBackend::new();
    let mut cfg = RunConfig::preset_defaults(&preset);
    cfg.method = match method.as_str() {
        "full" => Method::Full,
        "lora" => Method::Lora { double_rank: false },
        "topk" => Method::TopK { pct },
        _ => Method::ags(pct),
    };
    cfg.train.steps = steps;
    cfg.train.steps_per_epoch = (steps / 3).max(1);
    cfg.train.log_every = 0;
    cfg.metrics_path = Some(out.join("e2e_metrics.jsonl"));

    let preset_info = engine.manifest().preset(&preset)?;
    println!(
        "e2e: {} ({} params, {} blocks) · {} · {} steps",
        preset,
        preset_info.total_params,
        preset_info.n_blocks(),
        cfg.method.label(),
        steps
    );

    if shards > 1 {
        return run_sharded(cfg, shards, steps, &out);
    }

    let mut trainer = Trainer::new(&engine, cfg.clone())?;
    if trace_out.is_some() {
        trainer.telemetry().enable_tracing(1 << 16);
    }
    let ev = Evaluator::new(&engine, &preset, 32)?;
    let gsm_eval = MathGen::new(Suite::Gsm8kSim, Split::Eval, 0).problems(0, 64);

    let mut curve = CsvWriter::create(out.join("e2e_loss_curve.csv"), &["step", "loss", "lr"])?;
    let t0 = std::time::Instant::now();
    let mut last = f32::NAN;
    for step in 0..steps {
        last = trainer.step_once()?;
        let rec = trainer.metrics.records.last().unwrap();
        curve.row(&[step.to_string(), format!("{:.4}", rec.loss), format!("{:.6}", rec.lr)])?;
        if step % 20 == 0 {
            println!("step {step:>5}  loss {last:.4}");
        }
        if eval_every > 0 && step > 0 && step % eval_every == 0 {
            let acc = ev.accuracy(&trainer.eval_state()?, &gsm_eval)?;
            println!(
                "  [eval @ {step}] gsm8k-sim {:.1}% (format {:.0}%)",
                acc.accuracy * 100.0,
                acc.format_rate * 100.0
            );
        }
    }
    curve.flush()?;
    let wall = t0.elapsed().as_secs_f64();
    let summary = trainer.summary(wall, last);

    println!("\n== e2e summary ==");
    println!("{}", summary.to_json());

    let state = trainer.eval_state()?;
    for suite in [Suite::Gsm8kSim, Suite::MathSim] {
        let probs = MathGen::new(suite, Split::Eval, 0).problems(0, 128);
        let res = ev.accuracy(&state, &probs)?;
        println!(
            "{}: {:.1}% ({}/{}), format rate {:.0}%",
            suite.name(),
            res.accuracy * 100.0,
            res.n_correct,
            res.n,
            res.format_rate * 100.0
        );
    }
    if let Some(path) = &metrics_out {
        use adagradselect::telemetry::{write_prometheus, write_snapshot_json};
        let reg = &trainer.telemetry().registry;
        write_prometheus(path, reg)?;
        write_snapshot_json(format!("{path}.json"), reg)?;
        println!("metrics -> {path} (exposition) and {path}.json (snapshot)");
    }
    if let Some(path) = &trace_out {
        adagradselect::telemetry::write_chrome_trace(path, &trainer.telemetry().tracer)?;
        println!("trace -> {path} (chrome://tracing / ui.perfetto.dev)");
    }
    state.save(out.join("e2e_final.ckpt"))?;
    println!(
        "loss curve -> {:?}; checkpoint -> {:?}",
        out.join("e2e_loss_curve.csv"),
        out.join("e2e_final.ckpt")
    );
    Ok(())
}

/// `--shards N` driver: data-parallel training with per-step
/// communication accounting from the selection-gated all-reduce.
fn run_sharded(cfg: RunConfig, shards: usize, steps: u64, out: &PathBuf) -> Result<()> {
    let preset = cfg.preset.clone();
    let mut trainer = ShardedTrainer::new(cfg, shards)?;
    println!(
        "sharded: {shards} workers · {} rows/shard/step",
        trainer.preset.model.batch / shards
    );

    let mut curve = CsvWriter::create(
        out.join("e2e_loss_curve.csv"),
        &["step", "loss", "comm_bytes"],
    )?;
    let t0 = std::time::Instant::now();
    let mut last = f32::NAN;
    let mut prev = trainer.comm_stats();
    for step in 0..steps {
        last = trainer.step_once()?;
        let now = trainer.comm_stats();
        let d = now.delta_since(&prev);
        prev = now;
        let bytes =
            d.grad_gather_bytes + d.grad_bcast_bytes + d.norm_bcast_bytes + d.ctrl_bytes;
        curve.row(&[step.to_string(), format!("{last:.4}"), bytes.to_string()])?;
        if step % 20 == 0 {
            println!("step {step:>5}  loss {last:.4}  comm {bytes} B/step");
        }
    }
    curve.flush()?;
    let wall = t0.elapsed().as_secs_f64();

    let c = trainer.comm_stats();
    let total = c.grad_gather_bytes + c.grad_bcast_bytes + c.norm_bcast_bytes + c.ctrl_bytes;
    println!("\n== sharded summary ==");
    println!(
        "{} steps · {:.1}s wall · loss {last:.4} · {} masked steps",
        steps,
        wall,
        trainer.masked_steps()
    );
    println!(
        "comm: {} B/step avg (gather {} B, bcast {} B, norms {} B, ctrl {} B, \
         {} all-reduces over {} steps)",
        total / steps.max(1),
        c.grad_gather_bytes,
        c.grad_bcast_bytes,
        c.norm_bcast_bytes,
        c.ctrl_bytes,
        c.allreduce_ops,
        steps
    );

    let engine = ReferenceBackend::new();
    let ev = Evaluator::new(&engine, &preset, 32)?;
    for suite in [Suite::Gsm8kSim, Suite::MathSim] {
        let probs = MathGen::new(suite, Split::Eval, 0).problems(0, 128);
        let res = ev.accuracy(&trainer.state, &probs)?;
        println!(
            "{}: {:.1}% ({}/{}), format rate {:.0}%",
            suite.name(),
            res.accuracy * 100.0,
            res.n_correct,
            res.n,
            res.format_rate * 100.0
        );
    }
    trainer.state.save(out.join("e2e_final.ckpt"))?;
    println!("loss curve -> {:?}", out.join("e2e_loss_curve.csv"));
    Ok(())
}
