//! Quickstart: fine-tune the tiny preset with AdaGradSelect on the
//! pure-Rust reference backend and evaluate — no Python, no artifacts.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use adagradselect::data::{MathGen, Split, Suite};
use adagradselect::prelude::*;

fn main() -> Result<()> {
    // 1. the reference backend ships its preset catalog built in
    let engine = ReferenceBackend::new();
    println!("backend: {}", engine.platform());

    // 2. configure a run: AdaGradSelect updating 30% of blocks per step
    let mut cfg = RunConfig::preset_defaults("test-tiny");
    cfg.method = Method::ags(30.0);
    cfg.train.steps = 120;
    cfg.train.steps_per_epoch = 60;
    cfg.train.log_every = 20;

    // 3. train
    let mut trainer = Trainer::new(&engine, cfg)?;
    let summary = trainer.run()?;
    let first_loss = trainer.metrics.records[0].loss;
    println!(
        "\ntrained {} steps: loss {:.3} -> {:.3} (explore {} / exploit {})",
        summary.steps,
        first_loss,
        summary.tail_loss,
        summary.explore_steps,
        summary.exploit_steps,
    );
    println!(
        "optimizer VRAM: peak {:.1} KB (full FT would be {:.1} KB)",
        summary.opt_vram_peak_bytes as f64 / 1e3,
        (2 * trainer.preset.total_params * 2) as f64 / 1e3,
    );
    println!("selection histogram: {:?}", summary.selection_histogram);
    assert!(
        summary.tail_loss < first_loss,
        "training did not reduce the loss ({first_loss} -> {})",
        summary.tail_loss
    );

    // 4. evaluate with greedy decoding on the held-out suite
    let ev = Evaluator::new(&engine, "test-tiny", 24)?;
    let problems = MathGen::new(Suite::Gsm8kSim, Split::Eval, 0).problems(0, 32);
    let res = ev.accuracy(&trainer.eval_state()?, &problems)?;
    println!(
        "gsm8k-sim accuracy after {} steps: {:.1}% ({} answers well-formed)",
        summary.steps,
        res.accuracy * 100.0,
        (res.format_rate * res.n as f64) as usize,
    );
    Ok(())
}
