//! Serving-style example: batched greedy decoding with latency and
//! throughput reporting.
//!
//! Loads a checkpoint (or quick-trains one when none is given), then
//! pushes batches of math problems through the `decode_step` artifact the
//! way a serving frontend would, reporting per-batch latency percentiles
//! and end-to-end token throughput.
//!
//! ```bash
//! cargo run --release --example serve_eval -- --requests 64
//! cargo run --release --example serve_eval -- --checkpoint results/e2e_final.ckpt --preset e2e
//! ```

use adagradselect::config::{Method, RunConfig};
use adagradselect::data::{extract_answer, MathGen, Split, Suite};
use adagradselect::eval::Evaluator;
use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, ReferenceBackend};
use adagradselect::train::Trainer;
use adagradselect::util::cli::Args;
use adagradselect::Result;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut args = Args::parse(&argv, &[])?;
    let preset = args.str_or("preset", "test-tiny");
    let requests = args.usize_or("requests", 64)?;
    let max_new = args.usize_or("max-new", 24)?;
    let checkpoint = args.str_opt("checkpoint");
    let warm_steps = args.u64_or("warm-steps", 60)?;
    args.finish()?;

    let engine = ReferenceBackend::new();
    let state: ModelState = match checkpoint {
        Some(path) => {
            println!("loading checkpoint {path}");
            ModelState::load(path)?
        }
        None => {
            println!("no checkpoint given; quick-training {warm_steps} steps first");
            let mut cfg = RunConfig::preset_defaults(&preset);
            cfg.method = Method::ags(30.0);
            cfg.train.steps = warm_steps;
            cfg.train.steps_per_epoch = (warm_steps / 2).max(1);
            cfg.train.log_every = 0;
            let mut t = Trainer::new(&engine, cfg)?;
            t.run()?;
            t.eval_state()?
        }
    };

    let ev = Evaluator::new(&engine, &preset, max_new)?;
    let p = engine.manifest().preset(&preset)?;
    let batch = p.model.batch;
    let problems = MathGen::new(Suite::Gsm8kSim, Split::Eval, 7).problems(1000, requests);

    // serve batches, measuring per-batch latency
    let device_blocks: Vec<_> =
        state.flats.iter().map(|f| engine.upload_f32(f)).collect::<Result<_>>()?;
    let tok = ev.tokenizer().clone();
    let mut latencies = Vec::new();
    let mut tokens_out = 0usize;
    let mut correct = 0usize;
    let t_all = std::time::Instant::now();
    for chunk in problems.chunks(batch) {
        let prompts: Vec<Vec<i32>> =
            chunk.iter().map(|p| tok.encode(&p.prompt(), true, false)).collect();
        let t0 = std::time::Instant::now();
        let gens = ev.generate(&device_blocks, &prompts)?;
        latencies.push(t0.elapsed().as_secs_f64());
        for (p, g) in chunk.iter().zip(&gens) {
            tokens_out += g.len();
            if extract_answer(&tok.decode_until_eos(g)) == Some(p.answer) {
                correct += 1;
            }
        }
    }
    let total_s = t_all.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize];

    println!("\n== serving report ({preset}, batch={batch}, max_new={max_new}) ==");
    println!("requests:        {requests} ({} batches)", latencies.len());
    println!("batch latency:   p50 {:.1} ms  p95 {:.1} ms", pct(0.5) * 1e3, pct(0.95) * 1e3);
    println!(
        "throughput:      {:.1} req/s, {:.0} generated tokens/s",
        requests as f64 / total_s,
        tokens_out as f64 / total_s
    );
    println!("exact match:     {correct}/{requests}");
    Ok(())
}
