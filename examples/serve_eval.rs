//! Serving front end over the KV-cached continuous-batching engine.
//!
//! Loads a checkpoint (or quick-trains one when none is given), then
//! replays an open-loop Poisson arrival trace through `serve::ServeEngine`:
//! prompts are admitted into freed KV slots mid-decode, every iteration
//! advances all resident sequences by one token, and the report shows
//! per-request TTFT / end-to-end latency percentiles, per-token decode
//! latency, aggregate token throughput, KV-cache footprint and exact-match
//! accuracy. `--oracle` additionally times the pre-KV full-reforward
//! decode loop on the same problems for a measured speedup.
//!
//! ```bash
//! cargo run --release --example serve_eval -- --requests 64 --rate 8
//! cargo run --release --example serve_eval -- --checkpoint results/e2e_final.ckpt --preset e2e
//! cargo run --release --example serve_eval -- --requests 16 --oracle
//! cargo run --release --example serve_eval -- --temperature 0.8 --top-k 40 --sample-seed 7
//! ```
//!
//! `--temperature > 0` switches every request to seeded sampling
//! (`--top-k`, `--top-p`, `--sample-seed` refine it); the draw at step
//! `g` of request `i` depends only on `(sample-seed + i, g)`, so a
//! sampled run is bit-reproducible regardless of batch interleaving.
//!
//! `--kv-pages N` overcommits the KV pool below the `slots × context`
//! worst case: admission turns optimistic and the engine preempts (and
//! later resumes, bit-identically) running requests when pages run dry.
//! `--priority-mix "2,1,1"` cycles submitted requests through priority
//! tiers (here: one priority-2 request, then two priority-1) — higher
//! tiers admit first and are preempted last:
//!
//! ```bash
//! cargo run --release --example serve_eval -- --requests 32 --kv-pages 12 --priority-mix 2,0,0,0
//! ```
//!
//! `--metrics-out PATH` writes the engine's metric registry as a
//! Prometheus-style text exposition at `PATH` and a JSON snapshot at
//! `PATH.json` (and cross-checks the histogram percentiles against this
//! report's hand-sorted figures). `--trace-out PATH` enables span
//! tracing for the run and writes a Chrome trace-event file — open it in
//! chrome://tracing or <https://ui.perfetto.dev>:
//!
//! ```bash
//! cargo run --release --example serve_eval -- --requests 64 --rate 8 \
//!     --metrics-out results/serve.prom --trace-out results/serve_trace.json
//! ```

use adagradselect::config::{Method, RunConfig};
use adagradselect::data::{extract_answer, MathGen, Split, Suite};
use adagradselect::eval::Evaluator;
use adagradselect::memory::kv_cache_bytes;
use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, ReferenceBackend};
use adagradselect::serve::{Response, SamplingParams, ServeConfig, ServeEngine};
use adagradselect::train::Trainer;
use adagradselect::util::cli::Args;
use adagradselect::util::rng::Rng;
use adagradselect::{anyhow, Result};

fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * q) as usize]
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut args = Args::parse(&argv, &["oracle"])?;
    let preset = args.str_or("preset", "test-tiny");
    let requests = args.usize_or("requests", 64)?;
    let max_new = args.usize_or("max-new", 24)?;
    let checkpoint = args.str_opt("checkpoint");
    let warm_steps = args.u64_or("warm-steps", 60)?;
    let slots = args.usize_or("slots", 0)?;
    let rate = args.f64_or("rate", 0.0)?; // Poisson arrivals per second; 0 = all at t=0
    let seed = args.u64_or("seed", 7)?;
    let temperature = args.f64_or("temperature", 0.0)? as f32; // 0 = greedy
    let top_k = args.usize_or("top-k", 0)?;
    let top_p = args.f64_or("top-p", 1.0)? as f32;
    let sample_seed = args.u64_or("sample-seed", 0)?;
    let kv_pages = args.usize_or("kv-pages", 0)?; // 0 = worst-case pool
    let priority_mix = args.str_opt("priority-mix");
    let metrics_out = args.str_opt("metrics-out");
    let trace_out = args.str_opt("trace-out");
    let compare_oracle = args.bool_flag("oracle");
    args.finish()?;
    let sampled = temperature > 0.0;
    // e.g. "2,0,0,0": request i gets the (i mod len)-th tier
    let priorities: Vec<u8> = match &priority_mix {
        None => vec![0],
        Some(s) => s
            .split(',')
            .map(|t| {
                t.trim()
                    .parse::<u8>()
                    .map_err(|_| anyhow!("--priority-mix: bad tier {t:?} in {s:?}"))
            })
            .collect::<Result<_>>()?,
    };

    let engine = ReferenceBackend::new();
    let state: ModelState = match checkpoint {
        Some(path) => {
            println!("loading checkpoint {path}");
            ModelState::load(path)?
        }
        None => {
            println!("no checkpoint given; quick-training {warm_steps} steps first");
            let mut cfg = RunConfig::preset_defaults(&preset);
            cfg.method = Method::ags(30.0);
            cfg.train.steps = warm_steps;
            cfg.train.steps_per_epoch = (warm_steps / 2).max(1);
            cfg.train.log_every = 0;
            let mut t = Trainer::new(&engine, cfg)?;
            t.run()?;
            t.eval_state()?
        }
    };

    let p = engine.manifest().preset(&preset)?.clone();
    let slots = if slots == 0 { p.model.batch } else { slots };
    let ev = Evaluator::new(&engine, &preset, max_new)?;
    let tok = ev.tokenizer().clone();
    let problems = MathGen::new(Suite::Gsm8kSim, Split::Eval, seed).problems(1000, requests);

    // open-loop Poisson trace: exponential inter-arrival gaps
    let mut srv = ServeEngine::new(
        &engine,
        &preset,
        &state,
        ServeConfig { slots, max_new_tokens: max_new, kv_pages, ..Default::default() },
    )?;
    if trace_out.is_some() {
        srv.telemetry().enable_tracing(1 << 16);
    }
    let mut rng = Rng::seed_from_u64(seed);
    let mut arrival = 0.0f64;
    let mut ids = Vec::with_capacity(requests);
    for (i, prob) in problems.iter().enumerate() {
        if rate > 0.0 {
            arrival += -(1.0 - rng.gen_f64()).ln() / rate;
        }
        let prompt = tok.encode(&prob.prompt(), true, false);
        let priority = priorities[i % priorities.len()];
        let params = if sampled {
            SamplingParams {
                temperature,
                top_k,
                top_p,
                seed: sample_seed.wrapping_add(i as u64),
                stop: Vec::new(),
            }
        } else {
            SamplingParams::default()
        };
        ids.push(srv.submit_prio(prompt, 0, arrival, priority, params));
    }

    let t_all = std::time::Instant::now();
    let responses = srv.run_until_idle()?;
    let wall_s = t_all.elapsed().as_secs_f64();
    let stats = srv.stats();

    // score + latency distributions
    let by_id = |id: u64| ids.iter().position(|&x| x == id).expect("own request");
    let mut correct = 0usize;
    let mut truncated = 0usize;
    let mut gen_tokens = 0usize;
    let mut ttft: Vec<f64> = Vec::new();
    let mut latency: Vec<f64> = Vec::new();
    for r in &responses {
        if r.truncated {
            truncated += 1;
            continue;
        }
        gen_tokens += r.tokens.len();
        ttft.push(r.ttft_s());
        latency.push(r.latency_s());
        if extract_answer(&tok.decode_until_eos(&r.tokens)) == Some(problems[by_id(r.id)].answer)
        {
            correct += 1;
        }
    }
    ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
    latency.sort_by(|a, b| a.partial_cmp(b).unwrap());

    println!("\n== serving report ({preset}, slots={slots}, max_new={max_new}, rate={rate}/s) ==");
    println!(
        "requests:        {requests} ({} served, {truncated} rejected over-length)",
        requests - truncated
    );
    println!(
        "ttft:            p50 {:.2} ms  p95 {:.2} ms",
        pct(&ttft, 0.5) * 1e3,
        pct(&ttft, 0.95) * 1e3
    );
    println!(
        "latency:         p50 {:.2} ms  p95 {:.2} ms",
        pct(&latency, 0.5) * 1e3,
        pct(&latency, 0.95) * 1e3
    );
    if stats.decode_tokens > 0 {
        println!(
            "decode:          {:.3} ms/token ({} steps, mean batch {:.1}, peak {} slots)",
            stats.decode_s / stats.decode_tokens as f64 * 1e3,
            stats.decode_steps,
            stats.decode_tokens as f64 / stats.decode_steps.max(1) as f64,
            stats.peak_active
        );
    }
    let reg = &srv.telemetry().registry;
    if let Some(itl) = reg.hist_by_name("serve_itl_seconds") {
        if reg.hist_count(itl) > 0 {
            println!(
                "itl:             p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms ({} samples, \
                 streaming histogram)",
                reg.hist_quantile(itl, 0.5) * 1e3,
                reg.hist_quantile(itl, 0.95) * 1e3,
                reg.hist_quantile(itl, 0.99) * 1e3,
                reg.hist_count(itl),
            );
        }
    }
    println!(
        "prefill:         {:.2} ms/prompt ({} prompts, {} tokens)",
        stats.prefill_s / stats.n_prefills.max(1) as f64 * 1e3,
        stats.n_prefills,
        stats.prefill_tokens
    );
    println!(
        "throughput:      {:.1} req/s, {:.0} generated tokens/s",
        (requests - truncated) as f64 / wall_s,
        gen_tokens as f64 / wall_s
    );
    println!(
        "kv cache:        peak {:.2} MiB of paged {:.2} MiB worst case ({} slots x {} rows; \
         formula {:.2} MiB)",
        stats.kv_peak_bytes as f64 / (1024.0 * 1024.0),
        stats.kv_bytes as f64 / (1024.0 * 1024.0),
        slots,
        p.model.seq_len,
        kv_cache_bytes(&p.model, slots, 4) as f64 / (1024.0 * 1024.0)
    );
    println!(
        "paging:          {} pages allocated, {} copy-on-write forks, {} prefix-hit tokens",
        stats.pages_allocated, stats.cow_copies, stats.prefix_hit_tokens
    );
    println!(
        "preemption:      {} evictions, {} cached tokens recycled ({} pool: {} pages)",
        stats.n_preemptions,
        stats.preempted_tokens,
        if kv_pages == 0 { "worst-case" } else { "overcommitted" },
        srv.kv_pool().n_pages(),
    );
    if let Some(mix) = &priority_mix {
        println!("priorities:      cycling tiers [{mix}] across requests");
    }
    if sampled {
        println!(
            "sampling:        temperature {temperature}, top-k {top_k}, top-p {top_p}, \
             seed {sample_seed}"
        );
    }
    println!("exact match:     {correct}/{requests}");

    if let Some(path) = &metrics_out {
        use adagradselect::telemetry::{write_prometheus, write_snapshot_json};
        write_prometheus(path, reg)?;
        let snap_path = format!("{path}.json");
        write_snapshot_json(&snap_path, reg)?;
        // the streaming histograms must reproduce the hand-sorted
        // percentiles above to within one log bucket (both pick rank
        // floor((n-1)·q); the histogram answers with the bucket midpoint)
        let bucket_frac = 2f64.powf(1.0 / 8.0) - 1.0;
        for (name, sorted) in
            [("serve_ttft_seconds", &ttft), ("serve_latency_seconds", &latency)]
        {
            let id = reg
                .hist_by_name(name)
                .ok_or_else(|| anyhow!("metric {name} not registered"))?;
            if reg.hist_count(id) != sorted.len() as u64 {
                return Err(anyhow!(
                    "{name}: {} histogram samples vs {} hand-collected",
                    reg.hist_count(id),
                    sorted.len()
                ));
            }
            for q in [0.5, 0.95] {
                let h = reg.hist_quantile(id, q);
                let e = pct(sorted, q);
                if (h - e).abs() > e * bucket_frac + 1e-9 {
                    return Err(anyhow!(
                        "{name} p{:.0}: histogram {h:.6}s vs sorted {e:.6}s \
                         (outside one bucket width)",
                        q * 100.0
                    ));
                }
            }
        }
        println!("metrics:         wrote {path} (exposition) and {snap_path} (snapshot); \
                  percentiles agree with the sorted figures above");
    }
    if let Some(path) = &trace_out {
        let tracer = &srv.telemetry().tracer;
        adagradselect::telemetry::write_chrome_trace(path, tracer)?;
        println!(
            "trace:           wrote {path} ({} spans, {} overwritten) — open in \
             chrome://tracing or ui.perfetto.dev",
            tracer.n_events(),
            tracer.dropped(),
        );
    }

    if compare_oracle {
        // the retained full-reforward loop on the same problems, one
        // padded batch at a time — the pre-KV serving path
        let device = ev.upload_state(&state)?;
        let mut oracle_tokens = 0usize;
        let t0 = std::time::Instant::now();
        let mut oracle_gens: Vec<Vec<i32>> = Vec::with_capacity(requests);
        for chunk in problems.chunks(p.model.batch) {
            let prompts: Vec<Vec<i32>> =
                chunk.iter().map(|pr| tok.encode(&pr.prompt(), true, false)).collect();
            for g in ev.generate_oracle(&device, &prompts)? {
                oracle_tokens += g.len();
                oracle_gens.push(g);
            }
        }
        let oracle_s = t0.elapsed().as_secs_f64();
        println!("\n-- oracle (full reforward per token) on the same problems --");
        println!(
            "throughput:      {:.0} generated tokens/s ({:.2}s total)",
            oracle_tokens as f64 / oracle_s,
            oracle_s
        );
        println!(
            "speedup:         {:.1}x tokens/s (cached {:.0} vs reforward {:.0})",
            (gen_tokens as f64 / wall_s) / (oracle_tokens as f64 / oracle_s).max(1e-9),
            gen_tokens as f64 / wall_s,
            oracle_tokens as f64 / oracle_s
        );
        // token-for-token parity spot check (the oracle is greedy, so a
        // sampled run has nothing to compare against)
        if sampled {
            println!("parity:          skipped (sampled run vs greedy oracle)");
        } else {
            let mismatch = responses.iter().filter(|r| !r.truncated).any(|r: &Response| {
                oracle_gens.get(by_id(r.id)).map(|g| g != &r.tokens).unwrap_or(true)
            });
            println!(
                "parity:          {}",
                if mismatch { "MISMATCH" } else { "token-for-token ok" }
            );
        }
    }
    Ok(())
}
