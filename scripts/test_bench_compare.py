#!/usr/bin/env python3
"""Self-test for bench_compare's failure modes (stdlib only).

Covers the fail-loudly contract: malformed or truncated BENCH JSON must
exit nonzero and name the offending file, valid inputs must keep
working, and declared invariants must still gate. Run with:

    python3 scripts/test_bench_compare.py
"""
import json
import os
import subprocess
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
BENCH_COMPARE = os.path.join(HERE, "bench_compare")

VALID = {
    "calibrated": True,
    "workspace": {"steady_state_grows_10_steps": 0, "high_water_bytes": 1048576},
    "results": [{"name": "train_step/tiny", "mean_ns": 1000000.0}],
    "invariants": [{"name": "audit/compiled_out", "value": 1.0, "min": 1.0}],
}

failures = []


def check(name, ok, detail=""):
    status = "ok" if ok else "FAIL"
    print(f"  {name}: {status}")
    if not ok:
        failures.append(name)
        if detail:
            print(detail)


def run(*args):
    p = subprocess.run(
        [sys.executable, BENCH_COMPARE, *args], capture_output=True, text=True
    )
    return p.returncode, p.stdout + p.stderr


def write(d, name, text):
    path = os.path.join(d, name)
    with open(path, "w") as fh:
        fh.write(text)
    return path


def main():
    print("test_bench_compare:")
    with tempfile.TemporaryDirectory() as d:
        base = write(d, "baseline.json", json.dumps(VALID))
        cur = write(d, "current.json", json.dumps(VALID))

        code, out = run(base, cur)
        check("valid baseline+current passes", code == 0, out)

        trunc = write(d, "truncated.json", json.dumps(VALID)[:40])
        code, out = run(base, trunc)
        check("truncated current exits 1", code == 1, out)
        check("truncated current names the file", "truncated.json" in out, out)
        check("truncated current says malformed", "malformed bench JSON" in out, out)

        code, out = run(trunc, cur)
        check("truncated baseline exits 1", code == 1, out)
        check("truncated baseline names the file", "truncated.json" in out, out)

        garbage = write(d, "garbage.json", "not json at all {{{")
        code, out = run(base, garbage)
        check("garbage current exits 1", code == 1, out)
        check("garbage current names the file", "garbage.json" in out, out)

        notobj = write(d, "notobj.json", "[1, 2, 3]")
        code, out = run(base, notobj)
        check("non-object current exits 1", code == 1, out)
        check("non-object current names the file", "notobj.json" in out, out)

        missing = os.path.join(d, "does-not-exist.json")
        code, out = run(base, missing)
        check("missing current exits 1", code == 1, out)
        check("missing current names the file", "does-not-exist.json" in out, out)

        bad_inv = dict(VALID)
        bad_inv["invariants"] = [
            {"name": "audit/compiled_out", "value": 0.0, "min": 1.0}
        ]
        badp = write(d, "bad_inv.json", json.dumps(bad_inv))
        code, out = run(base, badp)
        check("violated invariant exits 1", code == 1, out)
        check("violated invariant is named", "audit/compiled_out" in out, out)

    if failures:
        print(f"test_bench_compare: FAIL ({len(failures)} check(s))")
        return 1
    print("test_bench_compare: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
