"""No-cargo verification of PR 3's KV-cached serving algorithm.

Ports the new Rust kernels (prefill_in / decode_step_kv_in), the greedy
stop logic (greedy_step vs the generate_oracle loop), and the
continuous-batching engine semantics op-for-op to numpy f32, and checks:

1. prefill logits == full-forward (decode_logits oracle) last-row logits
2. per-token KV decode logits == full-forward logits at each position
3. batched decode rows independent of batch-mates
4. greedy_step stop conditions == oracle loop stop conditions (fuzzed)
5. KV greedy generation token-for-token == oracle greedy loop
6. engine simulation: random arrivals/slot churn never mix rows or drop
   requests; outputs independent of arrival interleaving
7. workspace take/give sequence of a decode step is fixed-size => a
   best-fit arena reaches zero-growth steady state even as positions grow

PR 6 (paged KV + prefix sharing + seeded sampling) extends this with
op-for-op Python ports of serve::kv::KvPool, serve::prefix::PrefixCache,
serve::scheduler::Scheduler::admit and serve::sampling, plus a tag-level
port of ServeEngine::step (every K/V row carries a hash of its own token
prefix instead of floats, so any sharing/COW/interleaving bug shows up
as a tag mismatch):

8.  paged pool bookkeeping: alloc/release cycles, bytes ~ pages in use,
    attach/COW refcounts, release idempotence, page-offset addressing
    disjointness ([page, layer, page_size, d] vs a dense mirror)
9.  prefix cache: longest-chain lookup, first-writer-wins insert,
    LRU eviction skips referenced pages, chains unwind tail-first
10. scheduler: admit never exceeds slots/page budget, equal-need
    requests keep arrival order, the starvation guard forces the head
11. sampling: greedy == argmax, per-(seed,step) determinism and step
    independence, top-k/top-p support constraints, empirical
    distribution ~ softmax, stop_len fuzz vs a naive oracle
12. engine simulation: paged + prefix-shared + sampled serving is
    token-identical to a per-request oracle across slot counts and
    arrival orders, never faults on pages (admission budget proof),
    stems prefill once, divergence pages fork (COW), refcounts balance
    after every step and drain to zero

PR 7 (preempt-and-requeue, priorities, SLA-aware victim policy) adds:

13. preemptive serving: priority-first admission and requeue keep ids,
    arrivals and resume state; on an overcommitted pool the optimistic
    budget + preemption backstop produce greedy AND seeded outputs
    bit-identical to the uninterrupted oracle across forced-eviction
    schedules x slot counts; the victim policy spares high-priority
    requests; TTFT is stamped at the first emission only; worst-case
    reservation never preempts while optimistic matches or beats its
    decode utilization on the bench's bursty trace; refcounts balance
    and pages drain to zero through evict->requeue->finish churn; the
    budget identity reserved <= held + free + evictable holds at every
    admission; random workloads always drain (forward progress)

PR 8 (unified telemetry) adds:

14. streaming log-bucketed histogram (telemetry::hist::LogHistogram):
    the bucket_index formula ports exactly; quantile(q) (rank
    floor((n-1)q), geometric bucket midpoint clamped to [min, max]) is
    within one bucket width of the exact sorted quantile on log-uniform
    and lognormal draws; q=0/q=1 are exact; merge(a, b) equals feeding
    the concatenation; count/sum are exact
"""
import numpy as np

rng = np.random.default_rng(0)
F = np.float32

# test-tiny-like shapes
D, NH, DH, FF, V, S, L = 32, 2, 16, 96, 64, 64, 2
EPS, THETA = F(1e-5), F(10000.0)

def mk(*shape, std=0.05):
    return (rng.standard_normal(shape) * std).astype(F)

W = []
for _ in range(L):
    W.append(dict(ln1=np.ones(D, F), wq=mk(D, D), wk=mk(D, D), wv=mk(D, D),
                  wo=mk(D, D), ln2=np.ones(D, F), wg=mk(D, FF), wu=mk(D, FF),
                  wd=mk(FF, D)))
EMB, LNF, WOUT = mk(V, D), np.ones(D, F), mk(D, V)

def rmsnorm(x, w):
    inv = (1.0 / np.sqrt((x.astype(F) ** 2).mean(axis=-1, dtype=F) + EPS)).astype(F)
    return (x * inv[:, None] * w).astype(F)

def rope_tables(n):
    half = DH // 2
    freqs = THETA ** (-(np.arange(half, dtype=F)) / F(half))
    ang = np.arange(n, dtype=F)[:, None] * freqs[None, :]
    return np.cos(ang).astype(F), np.sin(ang).astype(F)

def rope_at(x, positions, cos, sin):
    # x: [n, D] head-concat; apply at absolute positions
    n = x.shape[0]
    half = DH // 2
    y = x.copy()
    for r in range(n):
        p = positions[r]
        for h in range(NH):
            o = h * DH
            x1 = x[r, o:o + half]
            x2 = x[r, o + half:o + DH]
            y[r, o:o + half] = x1 * cos[p] - x2 * sin[p]
            y[r, o + half:o + DH] = x1 * sin[p] + x2 * cos[p]
    return y.astype(F)

def attn_rows(q, k, v, pos_of):
    # causal attention: row i attends rows 0..=pos_of(i) of its own k/v
    scale = F(1.0 / np.sqrt(DH))
    out = np.zeros_like(q)
    for i in range(q.shape[0]):
        ki, vi = k[i], v[i]          # [cache_len, D] for this row's sequence
        p = pos_of(i)
        for h in range(NH):
            o = h * DH
            logits = (ki[:p + 1, o:o + DH] @ q[i, o:o + DH]).astype(F) * scale
            e = np.exp(logits - logits.max(), dtype=F)
            probs = (e / e.sum(dtype=F)).astype(F)
            out[i, o:o + DH] = (probs @ vi[:p + 1, o:o + DH]).astype(F)
    return out

def silu(x):
    return (x / (1.0 + np.exp(-x, dtype=F))).astype(F)

def full_logits(tokens):
    """decode_logits oracle: full forward over one sequence [t]."""
    t = len(tokens)
    cos, sin = rope_tables(t)
    h = EMB[tokens].copy()
    for l in range(L):
        w = W[l]
        x1 = rmsnorm(h, w["ln1"])
        q = rope_at((x1 @ w["wq"]).astype(F), range(t), cos, sin)
        k = rope_at((x1 @ w["wk"]).astype(F), range(t), cos, sin)
        v = (x1 @ w["wv"]).astype(F)
        att = attn_rows(q, np.broadcast_to(k, (t, t, D)), np.broadcast_to(v, (t, t, D)),
                        lambda i: i)
        h = (h + (att @ w["wo"]).astype(F)).astype(F)
        x2 = rmsnorm(h, w["ln2"])
        act = (silu((x2 @ w["wg"]).astype(F)) * (x2 @ w["wu"]).astype(F)).astype(F)
        h = (h + (act @ w["wd"]).astype(F)).astype(F)
    return (rmsnorm(h, LNF) @ WOUT).astype(F)

class SeqKv:
    def __init__(self, cap):
        self.k = [np.zeros((cap, D), F) for _ in range(L)]
        self.v = [np.zeros((cap, D), F) for _ in range(L)]
        self.pos = 0
        self.cap = cap

def prefill(tokens, seq):
    t = len(tokens)
    assert 0 < t <= seq.cap and seq.pos == 0
    cos, sin = rope_tables(t)
    h = EMB[tokens].copy()
    for l in range(L):
        w = W[l]
        x1 = rmsnorm(h, w["ln1"])
        q = rope_at((x1 @ w["wq"]).astype(F), range(t), cos, sin)
        k = rope_at((x1 @ w["wk"]).astype(F), range(t), cos, sin)
        v = (x1 @ w["wv"]).astype(F)
        seq.k[l][:t] = k
        seq.v[l][:t] = v
        att = attn_rows(q, np.broadcast_to(k, (t, t, D)), np.broadcast_to(v, (t, t, D)),
                        lambda i: i)
        h = (h + (att @ w["wo"]).astype(F)).astype(F)
        x2 = rmsnorm(h, w["ln2"])
        act = (silu((x2 @ w["wg"]).astype(F)) * (x2 @ w["wu"]).astype(F)).astype(F)
        h = (h + (act @ w["wd"]).astype(F)).astype(F)
    seq.pos = t
    return (rmsnorm(h[t - 1:t], LNF) @ WOUT).astype(F)[0]

def decode_step(tokens, seqs):
    n = len(tokens)
    cap = seqs[0].cap
    cos, sin = rope_tables(cap)
    positions = [s.pos for s in seqs]
    assert all(p < cap for p in positions)
    h = EMB[tokens].copy()
    for l in range(L):
        w = W[l]
        x1 = rmsnorm(h, w["ln1"])
        q = rope_at((x1 @ w["wq"]).astype(F), positions, cos, sin)
        k = rope_at((x1 @ w["wk"]).astype(F), positions, cos, sin)
        v = (x1 @ w["wv"]).astype(F)
        for i, s in enumerate(seqs):
            s.k[l][positions[i]] = k[i]
            s.v[l][positions[i]] = v[i]
        att = attn_rows(q, [s.k[l] for s in seqs], [s.v[l] for s in seqs],
                        lambda i: positions[i])
        h = (h + (att @ w["wo"]).astype(F)).astype(F)
        x2 = rmsnorm(h, w["ln2"])
        act = (silu((x2 @ w["wg"]).astype(F)) * (x2 @ w["wu"]).astype(F)).astype(F)
        h = (h + (act @ w["wd"]).astype(F)).astype(F)
    for s in seqs:
        s.pos += 1
    return (rmsnorm(h, LNF) @ WOUT).astype(F)

def maxdiff(a, b):
    return float(np.abs(a.astype(np.float64) - b.astype(np.float64)).max())

# ---- 1+2: prefill + per-token decode vs full forward ------------------
seq_tokens = list(rng.integers(4, V, size=12))
oracle = full_logits(seq_tokens)
t0 = 5
s = SeqKv(S)
lg = prefill(seq_tokens[:t0], s)
d1 = maxdiff(lg, oracle[t0 - 1])
assert d1 < 1e-5, d1
for j, tok in enumerate(seq_tokens[t0:]):
    pos = t0 + j
    lg = decode_step([tok], [s])[0]
    d = maxdiff(lg, oracle[pos])
    assert d < 1e-5, (pos, d)
print(f"1/2 prefill+decode vs full forward: ok (max prefill diff {d1:.2e})")

# ---- 3: batch-mate independence ---------------------------------------
seqs = [SeqKv(S) for _ in range(3)]
proms = [seq_tokens[:3], seq_tokens[:6], seq_tokens[:2]]
for p, sq in zip(proms, seqs):
    prefill(p, sq)
import copy
solo_seq = copy.deepcopy(seqs[0])
solo = decode_step([7], [solo_seq])[0]
batched = decode_step([7, 9, 11], seqs)
# numpy BLAS uses different kernels for 1-row (gemv) vs n-row (gemm)
# matmuls, so this port is only tolerance-equal across batch sizes; the
# Rust blocked kernel accumulates per-(row,col) in a fixed k order
# independent of row count, so the in-tree test asserts bitwise there.
d3 = maxdiff(solo, batched[0])
assert d3 < 1e-5, d3
assert maxdiff(np.stack(solo_seq.k[0]), np.stack(seqs[0].k[0])) < 1e-6
print(f"3 batch-mate independence: ok (<=1e-5 in this port, diff {d3:.2e})")

# ---- 4: greedy_step vs oracle loop stop conditions --------------------
EOScand = 2
def greedy_step(nxt, eos, cached, capacity, n_generated, max_new):
    if n_generated >= max_new:
        return None, True
    if nxt is None:
        return None, True
    if nxt == eos or cached >= capacity:
        return None, True
    return nxt, (n_generated + 1 >= max_new or cached + 1 >= capacity)

def oracle_loop(next_fn, prompt_len, s_cap, max_new, eos):
    # mirror of Evaluator::generate_oracle control flow
    lens, done, gen = prompt_len, False, []
    for _ in range(max_new):
        if done:
            break
        nxt = next_fn(lens - 1)
        if nxt is None:
            done = True
            continue
        if nxt == eos or lens >= s_cap:
            done = True
            continue
        gen.append(nxt)
        lens += 1
        if lens >= s_cap:
            done = True
    return gen

def kv_loop(next_fn, prompt_len, s_cap, max_new, eos):
    # mirror of the serving path: prefill sample + decode samples
    gen, cached = [], prompt_len
    emit, fin = greedy_step(next_fn(cached - 1), eos, cached, s_cap, 0, max_new)
    if emit is not None:
        gen.append(emit)
    while not fin:
        cached += 1
        emit, fin = greedy_step(next_fn(cached - 1), eos, cached, s_cap,
                                len(gen), max_new)
        if emit is not None:
            gen.append(emit)
    return gen

fuzz = np.random.default_rng(7)
for trial in range(20000):
    s_cap = int(fuzz.integers(1, 12))
    plen = int(fuzz.integers(1, s_cap + 1))
    max_new = int(fuzz.integers(0, 14))
    stream = [None if fuzz.random() < 0.05 else int(fuzz.integers(0, 6))
              for _ in range(64)]
    def next_fn(pos):
        return stream[pos % len(stream)]
    a = oracle_loop(next_fn, plen, s_cap, max_new, EOScand)
    b = kv_loop(next_fn, plen, s_cap, max_new, EOScand)
    assert a == b, (trial, s_cap, plen, max_new, a, b)
print("4 greedy_step == oracle loop: ok (20000 fuzz trials)")

# ---- 5: token-for-token generation parity -----------------------------
def gen_oracle(prompt, max_new):
    toks = list(prompt)
    def nf(pos):
        lg = full_logits(toks + [4] * 0)  # causal: suffix irrelevant
        return int(np.argmax(lg[pos]))
    # re-run full forward each step like the oracle does
    lens, gen = len(prompt), []
    row = list(prompt)
    for _ in range(max_new):
        lg = full_logits(row)
        nxt = int(np.argmax(lg[lens - 1]))
        if nxt == EOScand or lens >= S:
            break
        row.append(nxt)
        gen.append(nxt)
        lens += 1
        if lens >= S:
            break
    return gen

def gen_kv(prompt, max_new):
    sq = SeqKv(S)
    lg = prefill(prompt, sq)
    gen = []
    emit, fin = greedy_step(int(np.argmax(lg)), EOScand, sq.pos, S, 0, max_new)
    if emit is not None:
        gen.append(emit)
    while not fin:
        lg = decode_step([gen[-1]], [sq])[0]
        emit, fin = greedy_step(int(np.argmax(lg)), EOScand, sq.pos, S,
                                len(gen), max_new)
        if emit is not None:
            gen.append(emit)
    return gen

for trial in range(6):
    plen = int(rng.integers(1, 20))
    prompt = list(rng.integers(4, V, size=plen))
    a, b = gen_oracle(prompt, 10), gen_kv(prompt, 10)
    assert a == b, (trial, a, b)
print("5 token-for-token generation parity: ok (6 prompts x 10 tokens)")

# ---- 6: engine simulation — no drops/mixing, interleaving-independent -
def engine_sim(requests, slots, max_new):
    # requests: list of (rid, prompt); returns {rid: tokens}
    pending = list(requests)
    free = list(range(slots))
    active = []   # (rid, SeqKv, gen)
    out = {}
    while pending or active:
        while pending and free:
            rid, prompt = pending.pop(0)
            if not (0 < len(prompt) <= S):
                out[rid] = ("REJECT", [])
                continue
            slot = free.pop()
            sq = SeqKv(S)
            lg = prefill(list(prompt), sq)
            emit, fin = greedy_step(int(np.argmax(lg)), EOScand, sq.pos, S, 0, max_new)
            gen = [emit] if emit is not None else []
            if fin:
                free.append(slot)
                out[rid] = ("OK", gen)
            else:
                active.append((rid, slot, sq, gen))
        if active:
            lg = decode_step([a[3][-1] for a in active], [a[2] for a in active])
            still = []
            for i, (rid, slot, sq, gen) in enumerate(active):
                emit, fin = greedy_step(int(np.argmax(lg[i])), EOScand, sq.pos, S,
                                        len(gen), max_new)
                if emit is not None:
                    gen.append(emit)
                if fin:
                    free.append(slot)
                    assert rid not in out, "completed twice"
                    out[rid] = ("OK", gen)
                else:
                    still.append((rid, slot, sq, gen))
            active = still
    return out

reqs = [(i, list(rng.integers(4, V, size=int(rng.integers(1, 30))))) for i in range(9)]
reqs.append((9, list(rng.integers(4, V, size=S + 10))))  # over-length
fwd = engine_sim(reqs, 3, 6)
rev = engine_sim(list(reversed(reqs)), 3, 6)
iso = {rid: ("REJECT", []) if not (0 < len(p) <= S) else ("OK", gen_kv(p, 6))
       for rid, p in reqs}
assert set(fwd) == set(iso) == set(rev) == {r[0] for r in reqs}, "dropped request"
for rid in iso:
    assert fwd[rid] == iso[rid] == rev[rid], (rid, fwd[rid], iso[rid], rev[rid])
print("6 engine sim: no drops, no row mixing, interleaving-independent: ok")

# ---- 7: arena best-fit simulation over the decode take/give sequence --
class Arena:
    def __init__(self):
        self.free, self.grows = [], 0
    def take(self, n):
        fit = [c for c in self.free if c >= n]
        if fit:
            c = min(fit)
            self.free.remove(c)
            return c
        self.grows += 1
        return n
    def give(self, c):
        self.free.append(c)

def decode_takes(n, cap):
    # per decode_step_kv_in: rope(freqs, cos, sin), embed h, per layer
    # (x1, inv1, q, k, v, att, prow, attn_out, x2, inv2, gp, up, act,
    # mlp_out), head (xf, invf); logits are NOT arena-taken.
    half = DH // 2
    seqv = []
    seqv.append(("t", half)); seqv.append(("t", cap * half)); seqv.append(("t", cap * half))
    seqv.append(("g", half))  # freqs given back inside rope_tables
    seqv.append(("t", n * D))  # h
    for _ in range(L):
        for sz in (n * D, n, n * D, n * D, n * D):   # x1, inv1, q, k, v
            seqv.append(("t", sz))
        seqv.append(("t", n * D))      # att
        seqv.append(("t", n * cap))    # prow
        seqv.append(("g", n * cap))    # prow given
        seqv.append(("t", n * D))      # attn_out
        for sz in (n * D, n * D, n * D, n * D, n * D, n):
            pass
        # give attn_out, att, q, k, v, x1, inv1
        for sz in (n * D, n * D, n * D, n * D, n * D, n * D, n):
            seqv.append(("g", sz))
        for sz in (n * D, n, n * FF, n * FF, n * FF, n * FF):  # x2,inv2,gp,up,act,mlp
            seqv.append(("t", sz))
        for sz in (n * FF, n * FF, n * FF, n * FF, n * D, n):
            seqv.append(("g", sz))
    seqv.append(("t", n * D)); seqv.append(("t", n))   # xf, invf
    for sz in (n * D, n, n * D, cap * half, cap * half):  # xf, invf, h, cos, sin
        seqv.append(("g", sz))
    return seqv

ar = Arena()
held = {}
def run_seq(seq_ops):
    held = []
    for op, sz in seq_ops:
        if op == "t":
            held.append(ar.take(sz))
        else:
            # give the held buffer whose size matches (best effort emu)
            cand = [c for c in held if c >= sz]
            c = min(cand)
            held.remove(c)
            ar.give(c)
    assert not held or True

run_seq(decode_takes(4, S))       # warm step
g0 = ar.grows
for _ in range(30):
    run_seq(decode_takes(4, S))   # positions growing changes nothing: sizes fixed
for nn in (3, 2, 4):              # shrinking/regrowing active set
    run_seq(decode_takes(nn, S))
assert ar.grows == g0, (ar.grows, g0)
print("7 arena steady-state: ok (0 growth over 33 post-warm decode steps)")

# =======================================================================
# PR 6: paged KV pool + prefix sharing + scheduler + seeded sampling
# =======================================================================
import math
import struct

M64 = (1 << 64) - 1


class RngX:
    """xoshiro256++ with SplitMix64 seeding (mirrors util::rng::Rng; the
    same port as scripts/gen_golden.py, pinned there to published
    vectors)."""

    def __init__(self, seed):
        x = seed & M64
        s = []
        for _ in range(4):
            x = (x + 0x9E3779B97F4A7C15) & M64
            z = x
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self):
        s = self.s
        x = (s[0] + s[3]) & M64
        result = ((((x << 23) | (x >> 41)) & M64) + s[0]) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = ((s[3] << 45) | (s[3] >> 19)) & M64
        return result

    def gen_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))


# SplitMix64 canonical seed-0 vector pins the seeding path
_sm = RngX(0)
assert _sm.s[0] == 0xE220A8397B1DCDAF, hex(_sm.s[0])
assert _sm.s[1] == 0x6E789E6AA1B965F4, hex(_sm.s[1])


class PagedPool:
    """serve::kv::KvPool bookkeeping, ported op-for-op (release-build
    semantics). The [layer, page_size, d] float payload of a page is
    replaced by one content *tag* per row — COW copies tags, so any
    sharing bug becomes a tag mismatch at read time."""

    def __init__(self, n_slots, capacity, page_size=16):
        self.page_size = min(page_size, max(capacity, 1))
        self.capacity = capacity
        self.n_slots = n_slots
        self.n_pages = n_slots * -(-capacity // self.page_size)
        self.rows = [[None] * self.page_size for _ in range(self.n_pages)]
        self.refc = [0] * self.n_pages
        self.free_pages = list(range(self.n_pages))[::-1]
        self.tables = [[] for _ in range(n_slots)]
        self.lens = [0] * n_slots
        self.in_use = [False] * n_slots
        self.free_slots = list(range(n_slots))[::-1]
        self.peak_pages = 0
        self.pages_allocated = 0
        self.cow_copies = 0
        self.peak_in_use = 0

    def pages_for(self, rows):
        return -(-rows // self.page_size)

    def n_free(self):
        return len(self.free_slots)

    def n_free_pages(self):
        return len(self.free_pages)

    def pages_in_use(self):
        return self.n_pages - len(self.free_pages)

    def pages_held(self, slot):
        return len(self.tables[slot])

    def alloc(self):
        if not self.free_slots:
            return None
        slot = self.free_slots.pop()
        assert not self.tables[slot]
        self.lens[slot] = 0
        self.in_use[slot] = True
        self.peak_in_use = max(self.peak_in_use, self.n_slots - len(self.free_slots))
        return slot

    def release(self, slot):
        if slot >= self.n_slots or not self.in_use[slot]:
            return  # release-build idempotence (the PR 6 bugfix)
        table, self.tables[slot] = self.tables[slot], []
        for page in table:
            self.release_page(page)
        self.in_use[slot] = False
        self.lens[slot] = 0
        self.free_slots.append(slot)

    def set_len(self, slot, ln):
        assert self.in_use[slot] and ln <= self.capacity
        assert ln <= len(self.tables[slot]) * self.page_size
        self.lens[slot] = ln

    def advance(self, slot):
        assert self.in_use[slot] and self.lens[slot] < self.capacity
        assert self.lens[slot] < len(self.tables[slot]) * self.page_size
        self.lens[slot] += 1

    def alloc_page(self):
        if not self.free_pages:
            raise RuntimeError("kv pool: out of pages")
        page = self.free_pages.pop()
        assert self.refc[page] == 0
        self.refc[page] = 1
        self.pages_allocated += 1
        self.peak_pages = max(self.peak_pages, self.pages_in_use())
        return page

    def retain_page(self, page):
        assert self.refc[page] > 0
        self.refc[page] += 1

    def release_page(self, page):
        assert self.refc[page] > 0
        self.refc[page] -= 1
        if self.refc[page] == 0:
            self.free_pages.append(page)

    def ensure_room(self, slot, rows):
        assert self.in_use[slot]
        assert rows <= self.capacity
        while len(self.tables[slot]) < self.pages_for(rows):
            self.tables[slot].append(self.alloc_page())

    def attach_shared(self, slot, pages, covered):
        assert self.in_use[slot] and not self.tables[slot] and self.lens[slot] == 0
        assert covered <= len(pages) * self.page_size and covered <= self.capacity
        for page in pages:
            self.retain_page(page)
            self.tables[slot].append(page)
        self.lens[slot] = covered
        self.peak_pages = max(self.peak_pages, self.pages_in_use())

    def make_row_writable(self, slot, row):
        assert self.in_use[slot]
        idx = row // self.page_size
        if idx >= len(self.tables[slot]):
            return
        old = self.tables[slot][idx]
        if self.refc[old] <= 1:
            return
        fresh = self.alloc_page()
        self.rows[fresh] = list(self.rows[old])
        self.refc[old] -= 1
        self.tables[slot][idx] = fresh
        self.cow_copies += 1

    def views_check(self, slots):
        """KvPool::views contract: distinct in-use slots, next row
        auto-mapped, pages covering writable rows (>= len) exclusive."""
        assert len(set(slots)) == len(slots)
        for s in slots:
            assert self.in_use[s]
            self.ensure_room(s, min(self.lens[s] + 1, self.capacity))
            for pi, page in enumerate(self.tables[s]):
                if (pi + 1) * self.page_size > self.lens[s]:
                    assert self.refc[page] == 1, (s, page, "shared writable page")

    def write_row(self, slot, row, tagv):
        page = self.tables[slot][row // self.page_size]
        assert self.refc[page] == 1, "write into a shared page"
        self.rows[page][row % self.page_size] = tagv

    def read_row(self, slot, row):
        return self.rows[self.tables[slot][row // self.page_size]][row % self.page_size]

    def check_refcounts(self, cache=None):
        held = [0] * self.n_pages
        for s in range(self.n_slots):
            for page in self.tables[s]:
                held[page] += 1
        if cache is not None:
            for page, _stamp in cache.entries.values():
                held[page] += 1
        assert held == self.refc, "refcount drift vs actual references"
        assert sorted(self.free_pages) == [p for p in range(self.n_pages) if self.refc[p] == 0]
        assert len(set(self.free_pages)) == len(self.free_pages), "free-list duplicate"
        assert len(set(self.free_slots)) == len(self.free_slots), "free-slot duplicate"


class PrefixCacheSim:
    """serve::prefix::PrefixCache, ported op-for-op."""

    def __init__(self):
        self.entries = {}  # tuple(prefix tokens) -> [page, stamp]
        self.clock = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def stamp(now, depth):
        return (now << 16) | (0xFFFF - min(depth, 0xFFFE))

    def lookup(self, prompt, page_size):
        now = self.clock
        self.clock += 1
        chain, k = [], 1
        while k * page_size <= len(prompt):
            e = self.entries.get(tuple(prompt[: k * page_size]))
            if e is None:
                break
            e[1] = self.stamp(now, k - 1)
            chain.append(e[0])
            k += 1
        if chain:
            self.hits += 1
        else:
            self.misses += 1
        return chain

    def insert(self, prompt, table, pool):
        ps = pool.page_size
        now = self.clock
        self.clock += 1
        k = 1
        while k * ps <= len(prompt) and k <= len(table):
            key = tuple(prompt[: k * ps])
            st = self.stamp(now, k - 1)
            e = self.entries.get(key)
            if e is not None:
                e[1] = st
            else:
                pool.retain_page(table[k - 1])
                self.entries[key] = [table[k - 1], st]
            k += 1

    def evictable(self, pool):
        return sum(1 for page, _ in self.entries.values() if pool.refc[page] == 1)

    def evict(self, pool, n):
        freed = 0
        while freed < n:
            cands = [(e[1], k) for k, e in self.entries.items() if pool.refc[e[0]] == 1]
            if not cands:
                break
            key = min(cands)[1]
            page, _ = self.entries.pop(key)
            pool.release_page(page)
            self.evictions += 1
            freed += 1
        return freed

    def clear(self, pool):
        for page, _ in self.entries.values():
            pool.release_page(page)
        self.entries.clear()


class SchedulerSim:
    """serve::scheduler::Scheduler::admit, ported op-for-op."""

    STARVATION_ROUNDS = 8

    def __init__(self):
        self.pending = []  # dicts: id, prompt, max_new, arrival, params
        self.next_id = 0
        self.starved_id = None
        self.head_skips = 0

    def submit(self, prompt, max_new, arrival_s, params=None):
        rid = self.next_id
        self.next_id += 1
        at = 0
        for i in range(len(self.pending) - 1, -1, -1):
            if self.pending[i]["arrival"] <= arrival_s:
                at = i + 1
                break
        self.pending.insert(
            at,
            dict(id=rid, prompt=list(prompt), max_new=max_new, arrival=arrival_s, params=params),
        )
        return rid

    def next_arrival(self):
        return self.pending[0]["arrival"] if self.pending else None

    def admit(self, now_s, free_slots, free_pages, page_need):
        n_arrived = 0
        for r in self.pending:
            if r["arrival"] <= now_s:
                n_arrived += 1
            else:
                break
        if n_arrived == 0 or free_slots == 0:
            return []
        needs = [page_need(r) for r in self.pending[:n_arrived]]
        order = sorted(
            range(n_arrived),
            key=lambda i: (needs[i], self.pending[i]["arrival"], self.pending[i]["id"]),
        )
        head_id = self.pending[0]["id"]
        starving = self.starved_id == head_id and self.head_skips >= self.STARVATION_ROUNDS
        budget = free_pages
        picked = []  # indices, in selection (cheapest-first) order
        for i in order:
            if len(picked) >= free_slots:
                break
            if starving and not picked and i != 0:
                if needs[0] > budget:
                    break
                continue
            if needs[i] <= budget:
                budget -= needs[i]
                picked.append(i)
        if 0 in picked:
            self.starved_id = None
            self.head_skips = 0
        elif picked:
            if self.starved_id == head_id:
                self.head_skips += 1
            else:
                self.starved_id = head_id
                self.head_skips = 1
        out = [self.pending[i] for i in picked]
        for i in sorted(picked, reverse=True):
            del self.pending[i]
        return out


def rust_argmax(logits):
    """eval::argmax: NaN-skipping, first max wins, all-NaN -> None."""
    best, best_v = None, None
    for i, l in enumerate(logits):
        if math.isnan(l):
            continue
        if best_v is None or l > best_v:
            best, best_v = i, float(l)
    return best


def f32_total_key(x):
    """f32::total_cmp's integer key (sign-magnitude to two's complement),
    so the sort below orders -0.0 < +0.0 exactly like the Rust sort."""
    b = struct.unpack("<i", struct.pack("<f", float(np.float32(x))))[0]
    return b ^ 0x7FFFFFFF if b < 0 else b


def sample_token_sim(logits, params, n_generated):
    """serve::sampling::sample_token (params: dict with temperature,
    top_k, top_p, seed, stop)."""
    if params["temperature"] <= 0.0:
        return rust_argmax(logits)
    cand = [(i, np.float32(l)) for i, l in enumerate(logits) if not math.isnan(l)]
    if not cand:
        return None
    cand.sort(key=lambda t: (-f32_total_key(t[1]), t[0]))
    if params["top_k"] > 0 and len(cand) > params["top_k"]:
        cand = cand[: params["top_k"]]
    maxl = cand[0][1]
    invt = 1.0 / float(np.float32(params["temperature"]))
    probs = [math.exp(float(l - maxl) * invt) for _, l in cand]
    total = sum(probs)
    if params["top_p"] < 1.0:
        target = total * max(float(np.float32(params["top_p"])), 0.0)
        cum, keep = 0.0, len(probs)
        for i, p in enumerate(probs):
            cum += p
            if cum >= target:
                keep = i + 1
                break
        probs = probs[:keep]
        total = cum
    rng = RngX(params["seed"] ^ ((n_generated * 0x9E3779B97F4A7C15) & M64))
    u = rng.gen_f64() * total
    acc = 0.0
    for i, p in enumerate(probs):
        acc += p
        if u < acc:
            return cand[i][0]
    return cand[len(probs) - 1][0]


def stop_len_sim(generated, stop):
    hits = [len(s) for s in stop if s and generated[-len(s):] == list(s)]
    return max(hits) if hits else None


# ---- 8: paged pool bookkeeping + page addressing ----------------------
pp = PagedPool(2, 64, 16)
assert pp.n_pages == 8 and pp.pages_in_use() == 0
a8 = pp.alloc()
pp.ensure_room(a8, 17)
assert pp.pages_held(a8) == 2 and pp.pages_in_use() == 2
pp.set_len(a8, 17)
pp.release(a8)
assert pp.pages_in_use() == 0 and pp.n_free() == 2
pp.release(a8)  # double release: idempotent, free lists stay unique
pp.check_refcounts()
assert pp.n_free() == 2
# attach/COW refcounts mirror the kv.rs unit tests
a8 = pp.alloc()
pp.ensure_room(a8, 17)
pp.set_len(a8, 17)
stem_page = pp.tables[a8][0]
for j in range(16):
    pp.write_row(a8, j, ("row", j))
b8 = pp.alloc()
pp.attach_shared(b8, [stem_page], 15)  # divergence mid-page
assert pp.refc[stem_page] == 2
try:
    pp.views_check([b8])
    raise AssertionError("shared writable page must be rejected")
except AssertionError as e:
    if "rejected" in str(e):
        raise
before = pp.cow_copies
pp.make_row_writable(b8, 15)
assert pp.cow_copies == before + 1 and pp.tables[b8][0] != stem_page
assert pp.refc[stem_page] == 1, "fork drops the slot's reference"
assert all(pp.read_row(b8, j) == ("row", j) for j in range(15)), "fork copied content"
pp.views_check([b8])
pp.release(b8)
pp.release(a8)
pp.check_refcounts()
assert pp.pages_in_use() == 0
# [page, layer, page_size, d] addressing: disjoint and dense-equivalent
NLAY, DD, PSZ = 3, 5, 4
table9 = [4, 1, 3]
flat9 = np.full(6 * NLAY * PSZ * DD, np.nan, F)
dense9 = np.zeros((NLAY, PSZ * len(table9), DD), F)
offs = set()
r9 = np.random.default_rng(9)
for layer in range(NLAY):
    for row in range(PSZ * len(table9)):
        off = ((table9[row // PSZ] * NLAY + layer) * PSZ + row % PSZ) * DD
        assert off not in offs
        offs.add(off)
        vals = r9.standard_normal(DD).astype(F)
        flat9[off:off + DD] = vals
        dense9[layer, row] = vals
for layer in range(NLAY):
    for row in range(PSZ * len(table9)):
        off = ((table9[row // PSZ] * NLAY + layer) * PSZ + row % PSZ) * DD
        assert np.array_equal(flat9[off:off + DD], dense9[layer, row])
print("8 paged pool bookkeeping + page addressing: ok")

# ---- 9: prefix cache semantics ----------------------------------------
pp = PagedPool(2, 64, 16)
pc = PrefixCacheSim()
prompt9 = list(range(2 * 16 + 3))
assert pc.lookup(prompt9, 16) == []
s9 = pp.alloc()
pp.ensure_room(s9, len(prompt9))
pp.set_len(s9, len(prompt9))
t9 = list(pp.tables[s9])
pc.insert(prompt9, t9, pp)
assert len(pc.entries) == 2, "only full pages are cached"
assert pc.lookup(prompt9, 16) == t9[:2]
other9 = list(prompt9)
other9[17] ^= 1
assert pc.lookup(other9, 16) == t9[:1], "chain stops at the divergent page"
pp.release(s9)
assert pp.refc[t9[0]] == 1 and pc.evictable(pp) == 2
# first-writer-wins: a second insert under the same key only touches LRU
s9b = pp.alloc()
pp.ensure_room(s9b, 16)
pp.set_len(s9b, 16)
pc.insert(prompt9[:16], list(pp.tables[s9b]), pp)
assert pc.entries[tuple(prompt9[:16])][0] == t9[0], "first entry kept"
pp.release(s9b)
# eviction: LRU first, chains unwind tail-first, pinned entries skipped
assert pc.evict(pp, 1) == 1
assert pc.lookup(prompt9, 16) == t9[:1], "stem page survives tail eviction"
pc.clear(pp)
pp.check_refcounts(pc)
assert pp.pages_in_use() == 0
print("9 prefix cache lookup/insert/evict: ok")

# ---- 10: scheduler admission fuzz -------------------------------------
def need_10(r, cap=64, ps=16):
    L = len(r["prompt"])
    if L == 0 or L > cap:
        return 0
    return -(-min(L + r["max_new"], cap) // ps)

fz = np.random.default_rng(0xC0FFEE)
for trial in range(200):
    sch = SchedulerSim()
    n = int(fz.integers(1, 12))
    for _ in range(n):
        sch.submit([1] * int(fz.integers(0, 80)), int(fz.integers(1, 20)), float(fz.random() * 5))
    got_total, rounds = 0, 0
    while sch.pending:
        rounds += 1
        if rounds > 2000:  # drain with full resources; must empty out
            got = sch.admit(1e9, 100, 10**9, need_10)
            got_total += len(got)
            continue
        now = float(fz.random() * 10)
        free_slots = int(fz.integers(0, 4))
        budget = int(fz.integers(0, 9))
        got = sch.admit(now, free_slots, budget, need_10)
        assert len(got) <= free_slots, "over-admitted slots"
        assert sum(need_10(g) for g in got) <= budget, "over-admitted pages"
        assert all(g["arrival"] <= now for g in got), "admitted the future"
        got_total += len(got)
        assert rounds < 2100
    assert got_total == n, "requests dropped"
# equal demand keeps arrival order
sch = SchedulerSim()
for t in (3.0, 1.0, 2.0):
    sch.submit([1] * 8, 4, t)
got = sch.admit(10.0, 8, 10**9, lambda r: 1)
assert [g["arrival"] for g in got] == [1.0, 2.0, 3.0]
# starvation guard: the bypassed head is eventually head-or-nothing
sch = SchedulerSim()
long_id = sch.submit([1] * 64, 8, 0.0)
need_s = lambda r: -(-len(r["prompt"]) // 16)
rounds = 0
while True:
    sch.submit([1] * 8, 4, 0.0)
    got = sch.admit(1.0, 1, 2, need_s)
    if not got:
        break
    assert all(g["id"] != long_id for g in got), "2 pages cannot fit the head"
    rounds += 1
    assert rounds <= 2 * SchedulerSim.STARVATION_ROUNDS, "guard never tripped"
for _ in range(3):
    assert sch.admit(1.0, 1, 2, need_s) == [], "head or nothing while starving"
got = sch.admit(1.0, 2, 8, need_s)
assert got[0]["id"] == long_id, "starving head admitted first"
print(f"10 scheduler admission: ok (200 fuzz trials; guard at round {rounds})")

# ---- 11: seeded sampling properties -----------------------------------
lg11 = np.array([0.1, 2.5, -1.0, 2.4, 0.0, 1.5], F)
greedy11 = dict(temperature=0.0, top_k=0, top_p=1.0, seed=0, stop=[])
assert sample_token_sim(lg11, greedy11, 0) == rust_argmax(lg11) == 1
assert rust_argmax([F("nan"), F(1.0)]) == 1 and rust_argmax([F("nan")] * 2) is None
p11 = dict(temperature=1.0, top_k=0, top_p=1.0, seed=42, stop=[])
draws_a = [sample_token_sim(lg11, p11, g) for g in range(50)]
draws_b = [sample_token_sim(lg11, p11, g) for g in reversed(range(50))]
assert draws_a == draws_b[::-1], "draw depends only on (seed, step), not call order"
assert len(set(draws_a)) > 1, "temperature 1 must vary"
p11c = dict(p11, seed=43)
assert draws_a != [sample_token_sim(lg11, p11c, g) for g in range(50)], "seeds diverge"
pk = dict(temperature=5.0, top_k=1, top_p=1.0, seed=7, stop=[])
assert all(sample_token_sim(lg11, pk, g) == 1 for g in range(20)), "top-k 1 is argmax"
pk2 = dict(temperature=1.0, top_k=2, top_p=1.0, seed=3, stop=[])
assert all(sample_token_sim(lg11, pk2, g) in (1, 3) for g in range(200))
lgp = np.array([10.0, 9.9, -5.0, -6.0, -7.0], F)
pnuc = dict(temperature=1.0, top_k=0, top_p=0.5, seed=9, stop=[])
assert all(sample_token_sim(lgp, pnuc, g) <= 1 for g in range(300)), "nucleus"
pnan = dict(temperature=1.0, top_k=0, top_p=1.0, seed=0, stop=[])
assert sample_token_sim([F("nan")] * 3, pnan, 0) is None
# empirical distribution ~ softmax over 20k step-keyed draws
lgd = np.array([2.0, 1.0, 0.0, -1.0], F)
pd11 = dict(temperature=1.0, top_k=0, top_p=1.0, seed=5, stop=[])
counts = np.zeros(4)
NDRAW = 20000
for g in range(NDRAW):
    counts[sample_token_sim(lgd, pd11, g)] += 1
e = np.exp(lgd.astype(np.float64))
dmax = float(np.abs(counts / NDRAW - e / e.sum()).max())
assert dmax < 0.015, dmax
# stop_len vs a naive longest-tail oracle
fz = np.random.default_rng(11)
for _ in range(2000):
    gen = [int(t) for t in fz.integers(0, 4, size=int(fz.integers(0, 8)))]
    stops = [[int(t) for t in fz.integers(0, 4, size=int(fz.integers(0, 3)))]
             for _ in range(int(fz.integers(0, 4)))]
    naive = max(
        (len(s) for s in stops if 0 < len(s) <= len(gen) and gen[len(gen) - len(s):] == s),
        default=None,
    )
    assert stop_len_sim(gen, stops) == naive
print(f"11 seeded sampling: ok (empirical-vs-softmax max diff {dmax:.4f})")

# ---- 12: engine simulation over the paged pool ------------------------
EOS_T = 2
VOC = 24


def tag12(prefix):
    h = 1469598103934665603
    for t in prefix:
        h = ((h ^ (t & 0xFFFF)) * 1099511628211) & M64
    return h


def model_logits_sim(toks):
    """Deterministic fake model: logits are a pure function of the token
    history, like the causal kernels verified in sections 1-5."""
    return np.random.default_rng(tag12(toks) % (1 << 32)).standard_normal(VOC).astype(F)


def push_tok(gen, stop, emit, finished):
    """ServeEngine::push_token on a bare list."""
    if emit is None:
        return True
    gen.append(emit)
    k = stop_len_sim(gen, stop)
    if k is not None:
        del gen[len(gen) - k:]
        return True
    return finished


def oracle_gen(prompt, max_new, cap, params=None):
    """Per-request oracle: the greedy_step loop over the fake model."""
    toks, gen = list(prompt), []
    stop = params["stop"] if params else []

    def sample(g):
        lg = model_logits_sim(toks)
        return rust_argmax(lg) if params is None else sample_token_sim(lg, params, g)

    emit, fin = greedy_step(sample(0), EOS_T, len(toks), cap, 0, max_new)
    fin = push_tok(gen, stop, emit, fin)
    while not fin:
        toks.append(gen[-1])
        emit, f2 = greedy_step(sample(len(gen)), EOS_T, len(toks), cap, len(gen), max_new)
        fin = push_tok(gen, stop, emit, f2)
    return gen


class EngineSim:
    """ServeEngine::step ported to the tag level: admission loop with the
    page budget, prefix attach + COW, prefill/decode row writes, release
    on finish. Row reads assert the slot sees exactly its own history."""

    def __init__(self, slots, capacity, page_size=16, chunked=True):
        self.pool = PagedPool(slots, capacity, page_size)
        self.cache = PrefixCacheSim()
        self.sched = SchedulerSim()
        self.active = []
        self.chunked = chunked
        self.now = 0.0
        self.stats = dict(n_prefills=0, prefill_tokens=0, prefix_hit_tokens=0)

    def submit(self, prompt, max_new, arrival_s, params=None):
        return self.sched.submit(prompt, max_new, arrival_s, params)

    def page_budget(self):
        reserved = sum(
            max(0, a["worst"] - self.pool.pages_held(a["slot"])) for a in self.active
        )
        return max(
            0, self.pool.n_free_pages() + self.cache.evictable(self.pool) - reserved
        )

    def ensure_room_evicting(self, slot, rows):
        missing = self.pool.pages_for(min(rows, self.pool.capacity)) - self.pool.pages_held(slot)
        if missing > self.pool.n_free_pages():
            self.cache.evict(self.pool, missing - self.pool.n_free_pages())
        self.pool.ensure_room(slot, rows)

    def make_row_writable_evicting(self, slot, row):
        if self.pool.n_free_pages() == 0:
            self.cache.evict(self.pool, 1)
        self.pool.make_row_writable(slot, row)

    def sample(self, toks, params, g):
        lg = model_logits_sim(toks)
        return rust_argmax(lg) if params is None else sample_token_sim(lg, params, g)

    def assert_rows(self, slot, toks, n):
        for j in range(n):
            assert self.pool.read_row(slot, j) == tag12(toks[: j + 1]), (
                "row contamination", slot, j)

    def finish(self, a, done):
        self.pool.release(a["slot"])
        done.append((a["id"], "OK", a["generated"]))

    def step(self):
        done = []
        cap, ps = self.pool.capacity, self.pool.page_size

        def need(r):
            if not r["prompt"] or len(r["prompt"]) > cap:
                return 0
            return -(-min(len(r["prompt"]) + r["max_new"], cap) // ps)

        while True:
            budget = self.page_budget()
            batch = self.sched.admit(self.now, self.pool.n_free(), budget, need)
            if not batch:
                break
            for req in batch:
                prompt, max_new = req["prompt"], req["max_new"]
                if not prompt or len(prompt) > cap:
                    done.append((req["id"], "REJECT", []))
                    continue
                worst = self.pool.pages_for(min(len(prompt) + max_new, cap))
                slot = self.pool.alloc()
                assert slot is not None, "admit() never exceeds free slots"
                covered = 0
                if self.chunked:
                    chain = self.cache.lookup(prompt, ps)
                    covered = min(len(chain) * ps, len(prompt) - 1)
                    if covered > 0:
                        self.pool.attach_shared(slot, chain[: -(-covered // ps)], covered)
                self.ensure_room_evicting(slot, len(prompt))
                if covered > 0:
                    self.make_row_writable_evicting(slot, covered)
                self.pool.views_check([slot])
                self.assert_rows(slot, prompt, covered)  # attached stem is bit-right
                for j in range(covered, len(prompt)):
                    self.pool.write_row(slot, j, tag12(prompt[: j + 1]))
                self.pool.set_len(slot, len(prompt))
                self.stats["n_prefills"] += 1
                self.stats["prefill_tokens"] += len(prompt) - covered
                self.stats["prefix_hit_tokens"] += covered
                if self.chunked:
                    self.cache.insert(prompt, list(self.pool.tables[slot]), self.pool)
                a = dict(id=req["id"], slot=slot, last=0, generated=[],
                         toks=list(prompt), max_new=max_new, params=req["params"],
                         worst=worst)
                emit, fin = greedy_step(self.sample(prompt, a["params"], 0), EOS_T,
                                        self.pool.lens[slot], cap, 0, max_new)
                if emit is not None:
                    a["last"] = emit
                if push_tok(a["generated"], a["params"]["stop"] if a["params"] else [],
                            emit, fin):
                    self.finish(a, done)
                else:
                    self.active.append(a)
        if self.active:
            for a in self.active:
                rows = min(self.pool.lens[a["slot"]] + 1, cap)
                self.ensure_room_evicting(a["slot"], rows)
            self.pool.views_check([a["slot"] for a in self.active])
            still = []
            for a in self.active:
                slot = a["slot"]
                ln = self.pool.lens[slot]
                self.assert_rows(slot, a["toks"], ln)  # attention reads own rows only
                a["toks"].append(a["last"])
                self.pool.write_row(slot, ln, tag12(a["toks"]))
                self.pool.advance(slot)
                g = len(a["generated"])
                emit, fin = greedy_step(self.sample(a["toks"], a["params"], g), EOS_T,
                                        self.pool.lens[slot], cap, g, a["max_new"])
                if emit is not None:
                    a["last"] = emit
                if push_tok(a["generated"], a["params"]["stop"] if a["params"] else [],
                            emit, fin):
                    self.finish(a, done)
                else:
                    still.append(a)
            self.active = still
        self.pool.check_refcounts(self.cache)
        assert self.pool.pages_in_use() <= self.pool.n_pages
        return done

    def run_until_idle(self):
        out, iters = [], 0
        while True:
            if not self.active:
                na = self.sched.next_arrival()
                if na is None:
                    break
                self.now = max(self.now, na)
            out.extend(self.step())
            iters += 1
            assert iters < 50000, "engine sim livelock"
        return out


PS12, CAP12 = 16, 64
stem_a = [5 + (i % 7) for i in range(2 * PS12)]
stem_b = [9, 10] * PS12
r12 = np.random.default_rng(123)
reqs12 = []
for i in range(28):
    kind = i % 7
    if kind < 2:
        p = stem_a + [int(t) for t in r12.integers(3, VOC, size=int(r12.integers(1, 6)))]
    elif kind == 2:
        p = stem_b + [int(t) for t in r12.integers(3, VOC, size=int(r12.integers(1, 6)))]
    elif kind == 3:
        p = list(stem_a)  # page-aligned resubmission: the COW case
    elif kind == 4:
        p = []  # invalid: empty
    elif kind == 5:
        p = [int(t) for t in r12.integers(3, VOC, size=CAP12 + 3)]  # over-length
    else:
        p = [int(t) for t in r12.integers(3, VOC, size=int(r12.integers(1, CAP12)))]
    reqs12.append((p, int(r12.integers(1, 40))))  # large max_new stresses the budget

expected = {
    i: ("REJECT", []) if (not p or len(p) > CAP12) else ("OK", oracle_gen(p, mn, CAP12))
    for i, (p, mn) in enumerate(reqs12)
}
for slots in (1, 2, 3):
    for order_name, idxs, arrivals in (
        ("batch", range(len(reqs12)), lambda i: 0.0),
        ("reversed", range(len(reqs12) - 1, -1, -1), lambda i: 0.0),
        ("staggered", range(len(reqs12)), lambda i: i * 0.25),
    ):
        eng = EngineSim(slots, CAP12, PS12)
        idmap = {}
        for i in idxs:
            idmap[eng.submit(reqs12[i][0], reqs12[i][1], arrivals(i))] = i
        out = eng.run_until_idle()
        assert len(out) == len(reqs12), "dropped or duplicated requests"
        for rid, status, gen in out:
            want = expected[idmap[rid]]
            assert (status, gen) == want, (slots, order_name, idmap[rid], gen, want[1])
        # drain: only cache-held pages remain; clearing frees everything
        assert not eng.active and eng.pool.n_free() == slots
        eng.cache.clear(eng.pool)
        eng.pool.check_refcounts(eng.cache)
        assert eng.pool.pages_in_use() == 0, "page leak"
print("12a engine sim: paged+prefix outputs == oracle over 3 slot counts x 3 orders")

# stems prefill once: 1 miss + 7 full-chain hits, bytes stay paged
eng = EngineSim(2, CAP12, PS12)
followers = [stem_a + [int(t) for t in r12.integers(3, VOC, size=4)] for _ in range(8)]
idmap = {eng.submit(p, 6, i * 1000.0): i for i, p in enumerate(followers)}
out = eng.run_until_idle()
assert eng.stats["prefix_hit_tokens"] == 7 * 2 * PS12, eng.stats
assert eng.stats["prefill_tokens"] == sum(len(p) for p in followers) - 7 * 2 * PS12
assert eng.stats["n_prefills"] == 8
assert eng.cache.hits == 7 and eng.cache.misses == 1
for rid, status, gen in out:
    assert (status, gen) == ("OK", oracle_gen(followers[idmap[rid]], 6, CAP12))
assert eng.pool.peak_pages < eng.pool.n_pages, "peak must beat the slot model here"

# resubmissions fork their divergence page (COW): a page-aligned full
# resubmission (covered = 2p-1, row 31 inside attached page 1) and a
# one-page resubmission (covered = p-1, row 15 inside attached page 0)
eng = EngineSim(1, CAP12, PS12)
eng.submit(stem_a, 4, 0.0)
eng.submit(stem_a, 4, 1000.0)
part = stem_a[:PS12]
eng.submit(part, 4, 2000.0)
eng.submit(part, 4, 3000.0)
out = eng.run_until_idle()
assert eng.pool.cow_copies == 3, eng.pool.cow_copies
assert eng.stats["prefix_hit_tokens"] == (2 * PS12 - 1) + 2 * (PS12 - 1)
for rid, status, gen in out:
    want = oracle_gen(stem_a if rid < 2 else part, 4, CAP12)
    assert (status, gen) == ("OK", want), (rid, gen, want)
print("12b engine sim: stem prefilled once; COW forks on both divergence shapes")

# sampled decode: bit-reproducible across batch compositions, stops trim
sp12 = dict(temperature=0.9, top_k=8, top_p=0.95, seed=0, stop=[])
sreqs = [([int(t) for t in r12.integers(3, VOC, size=int(r12.integers(1, 24)))],
          int(r12.integers(2, 10)), dict(sp12, seed=500 + i)) for i in range(10)]
sexp = {i: oracle_gen(p, mn, CAP12, pr) for i, (p, mn, pr) in enumerate(sreqs)}
for slots, rev in ((1, False), (3, False), (3, True)):
    eng = EngineSim(slots, CAP12, PS12)
    idxs = range(len(sreqs) - 1, -1, -1) if rev else range(len(sreqs))
    idmap = {eng.submit(sreqs[i][0], sreqs[i][1], 0.0, sreqs[i][2]): i for i in idxs}
    for rid, status, gen in eng.run_until_idle():
        assert (status, gen) == ("OK", sexp[idmap[rid]]), (slots, rev)
# a stop sequence cut from the greedy continuation trims and finishes
base = sreqs[0][0]
w = oracle_gen(base, 12, CAP12)
if len(w) >= 3:
    stopp = dict(temperature=0.0, top_k=0, top_p=1.0, seed=0, stop=[w[1:3]])
    eng = EngineSim(2, CAP12, PS12)
    rid = eng.submit(base, 12, 0.0, stopp)
    (got,) = [g for r, _, g in eng.run_until_idle() if r == rid]
    assert got == oracle_gen(base, 12, CAP12, stopp)
    assert len(got) < len(w), "matched stop run must trim the output"
print("12c engine sim: sampled decode batch-invariant; stop sequences trim")


# ---- 13: preemption, priorities, optimistic reservation ----------------
class SchedulerSim13(SchedulerSim):
    """PR 7 scheduler: priority tiers lead the candidate order; requeue
    keeps the id, original arrival and resume state (generated tokens,
    preemption count, first-token stamp)."""

    def submit(self, prompt, max_new, arrival_s, params=None, priority=0):
        rid = self.next_id
        self.next_id += 1
        self.requeue(dict(id=rid, prompt=list(prompt), max_new=max_new,
                          arrival=arrival_s, params=params, priority=priority,
                          generated=[], n_preemptions=0, first_token=None))
        return rid

    def requeue(self, req):
        at = 0
        for i in range(len(self.pending) - 1, -1, -1):
            if self.pending[i]["arrival"] <= req["arrival"]:
                at = i + 1
                break
        self.pending.insert(at, req)

    def admit(self, now_s, free_slots, free_pages, page_need):
        n_arrived = 0
        for r in self.pending:
            if r["arrival"] <= now_s:
                n_arrived += 1
            else:
                break
        if n_arrived == 0 or free_slots == 0:
            return []
        needs = [page_need(r) for r in self.pending[:n_arrived]]
        order = sorted(
            range(n_arrived),
            key=lambda i: (-self.pending[i]["priority"], needs[i],
                           self.pending[i]["arrival"], self.pending[i]["id"]),
        )
        head_id = self.pending[0]["id"]
        starving = self.starved_id == head_id and self.head_skips >= self.STARVATION_ROUNDS
        budget = free_pages
        picked = []
        for i in order:
            if len(picked) >= free_slots:
                break
            if starving and not picked and i != 0:
                if needs[0] > budget:
                    break
                continue
            if needs[i] <= budget:
                budget -= needs[i]
                picked.append(i)
        if 0 in picked:
            self.starved_id = None
            self.head_skips = 0
        elif picked:
            if self.starved_id == head_id:
                self.head_skips += 1
            else:
                self.starved_id = head_id
                self.head_skips = 1
        out = [self.pending[i] for i in picked]
        for i in sorted(picked, reverse=True):
            del self.pending[i]
        return out


class EngineSim13(EngineSim):
    """PR 7 engine: optimistic vs worst-case page reservation, a
    ``kv_pages`` overcommit knob (floored at one full-context sequence),
    the SLA-aware victim policy, preempt-and-requeue with bit-identical
    resume, and TTFT stamped at the first emission only. The virtual
    clock ticks once per step so stamp ordering is checkable.
    Completions are ``(id, status, generated, meta)`` where meta carries
    arrival/first_token/finish/n_preemptions."""

    def __init__(self, slots, capacity, page_size=16, kv_pages=0,
                 reservation="optimistic"):
        super().__init__(slots, capacity, page_size)
        if kv_pages:
            npg = max(kv_pages, self.pool.pages_for(capacity))
            self.pool.n_pages = npg
            self.pool.rows = [[None] * self.pool.page_size for _ in range(npg)]
            self.pool.refc = [0] * npg
            self.pool.free_pages = list(range(npg))[::-1]
        self.sched = SchedulerSim13()
        self.reservation = reservation
        self.stats.update(n_preemptions=0, preempted_tokens=0,
                          decode_steps=0, decode_tokens=0)

    def submit(self, prompt, max_new, arrival_s, params=None, priority=0):
        return self.sched.submit(prompt, max_new, arrival_s, params, priority)

    def page_budget(self):
        held = reserved = 0
        for a in self.active:
            h = self.pool.pages_held(a["slot"])
            held += h
            if self.reservation == "worst":
                reserved += max(0, a["worst"] - h)
            else:
                nxt = min(self.pool.lens[a["slot"]] + 1, self.pool.capacity)
                reserved += max(0, self.pool.pages_for(nxt) - h)
        free = self.pool.n_free_pages()
        ev = self.cache.evictable(self.pool)
        # the engine's debug_assert, hard here: what admission promises
        # can never exceed what exists
        assert reserved <= held + free + ev, (
            "page-budget drift", reserved, held, free, ev)
        return max(0, free + ev - reserved)

    def exclusive_pages(self, slot):
        return sum(1 for p in self.pool.tables[slot] if self.pool.refc[p] == 1)

    def pick_victim(self):
        if len(self.active) <= 1:
            return None  # the pool floor fits the last survivor
        return min(
            range(len(self.active)),
            key=lambda i: (self.active[i]["priority"],
                           -self.exclusive_pages(self.active[i]["slot"]),
                           self.pool.lens[self.active[i]["slot"]],
                           -self.active[i]["id"]))

    def preempt(self, idx):
        a = self.active.pop(idx)
        ln = self.pool.lens[a["slot"]]
        if self.chunked and a["generated"]:
            # cached rows = prompt + generated[:-1]: the last emitted
            # token was not fed yet
            run = a["prompt"] + a["generated"][:-1]
            assert len(run) == ln, "cached rows must match the fed history"
            self.cache.insert(run, list(self.pool.tables[a["slot"]]), self.pool)
        self.pool.release(a["slot"])
        self.stats["n_preemptions"] += 1
        self.stats["preempted_tokens"] += ln
        self.sched.requeue(dict(
            id=a["id"], prompt=a["prompt"], max_new=a["max_new"],
            arrival=a["arrival"], params=a["params"], priority=a["priority"],
            generated=a["generated"], n_preemptions=a["n_preemptions"] + 1,
            first_token=a["first_token"]))

    def finish(self, a, done):
        self.pool.release(a["slot"])
        done.append((a["id"], "OK", a["generated"],
                     dict(arrival=a["arrival"], first_token=a["first_token"],
                          finish=self.now, n_preemptions=a["n_preemptions"])))

    def step(self):
        done = []
        self.now += 1.0
        cap, ps = self.pool.capacity, self.pool.page_size

        def need(r):
            if not r["prompt"] or len(r["prompt"]) > cap:
                return 0
            if self.reservation == "worst":
                return -(-min(len(r["prompt"]) + r["max_new"], cap) // ps)
            fed = len(r["prompt"]) + len(r["generated"])
            return -(-min(fed + 1, cap) // ps)

        while True:
            budget = self.page_budget()
            batch = self.sched.admit(self.now, self.pool.n_free(), budget, need)
            if not batch:
                break
            for req in batch:
                prompt, max_new = req["prompt"], req["max_new"]
                if not prompt or len(prompt) > cap:
                    done.append((req["id"], "REJECT", [],
                                 dict(arrival=req["arrival"],
                                      first_token=self.now, finish=self.now,
                                      n_preemptions=req["n_preemptions"])))
                    continue
                worst = self.pool.pages_for(min(len(prompt) + max_new, cap))
                slot = self.pool.alloc()
                assert slot is not None, "admit() never exceeds free slots"
                # rows to (re-)feed: the prompt plus, after a preemption,
                # every token generated so far
                run = prompt + req["generated"]
                covered = 0
                if self.chunked:
                    chain = self.cache.lookup(run, ps)
                    covered = min(len(chain) * ps, len(run) - 1)
                    if covered > 0:
                        self.pool.attach_shared(slot, chain[: -(-covered // ps)],
                                                covered)
                self.ensure_room_evicting(slot, len(run))
                if covered > 0:
                    self.make_row_writable_evicting(slot, covered)
                self.pool.views_check([slot])
                self.assert_rows(slot, run, covered)
                for j in range(covered, len(run)):
                    self.pool.write_row(slot, j, tag12(run[: j + 1]))
                self.pool.set_len(slot, len(run))
                self.stats["n_prefills"] += 1
                self.stats["prefill_tokens"] += len(run) - covered
                self.stats["prefix_hit_tokens"] += covered
                if self.chunked:
                    self.cache.insert(run, list(self.pool.tables[slot]), self.pool)
                # first emission only: a resumed request keeps its stamp
                g0 = len(req["generated"])
                ft = req["first_token"] if req["first_token"] is not None else self.now
                a = dict(id=req["id"], slot=slot, last=0,
                         generated=list(req["generated"]), prompt=list(prompt),
                         toks=list(run), max_new=max_new, params=req["params"],
                         worst=worst, priority=req["priority"],
                         n_preemptions=req["n_preemptions"],
                         arrival=req["arrival"], first_token=ft)
                emit, fin = greedy_step(self.sample(run, a["params"], g0), EOS_T,
                                        self.pool.lens[slot], cap, g0, max_new)
                if emit is not None:
                    a["last"] = emit
                if push_tok(a["generated"],
                            a["params"]["stop"] if a["params"] else [], emit, fin):
                    self.finish(a, done)
                else:
                    self.active.append(a)
        if self.active:
            # map next-row pages; when the free list runs dry even after
            # eviction, the preemption backstop shrinks the active set and
            # the mapping pass restarts over the survivors
            while True:
                preempted = False
                for i in range(len(self.active)):
                    s = self.active[i]["slot"]
                    rows = min(self.pool.lens[s] + 1, cap)
                    try:
                        self.ensure_room_evicting(s, rows)
                    except RuntimeError:
                        v = self.pick_victim()
                        assert v is not None, \
                            "out of pages for the last active sequence"
                        self.preempt(v)
                        preempted = True
                        break
                if not preempted:
                    break
            self.pool.views_check([a["slot"] for a in self.active])
            self.stats["decode_steps"] += 1
            self.stats["decode_tokens"] += len(self.active)
            still = []
            for a in self.active:
                slot = a["slot"]
                ln = self.pool.lens[slot]
                self.assert_rows(slot, a["toks"], ln)
                a["toks"].append(a["last"])
                self.pool.write_row(slot, ln, tag12(a["toks"]))
                self.pool.advance(slot)
                g = len(a["generated"])
                emit, fin = greedy_step(self.sample(a["toks"], a["params"], g),
                                        EOS_T, self.pool.lens[slot], cap, g,
                                        a["max_new"])
                if emit is not None:
                    a["last"] = emit
                if push_tok(a["generated"],
                            a["params"]["stop"] if a["params"] else [], emit, fin):
                    self.finish(a, done)
                else:
                    still.append(a)
            self.active = still
        self.pool.check_refcounts(self.cache)
        assert self.pool.pages_in_use() <= self.pool.n_pages
        return done


def drain_and_check_leaks(eng, slots):
    """After a drain: no active sequences, every slot free, and the only
    in-use pages are the prefix cache's (one page per entry); clearing
    the cache frees everything."""
    assert not eng.active and eng.pool.n_free() == slots
    assert eng.pool.pages_in_use() == len(eng.cache.entries), "page leak"
    eng.cache.clear(eng.pool)
    eng.pool.check_refcounts(eng.cache)
    assert eng.pool.pages_in_use() == 0, "page leak after cache clear"


# 13a: priority-first admission + requeue resume-state semantics
s13 = SchedulerSim13()
cheap_low = s13.submit([0] * 4, 4, 0.0)
costly_high = s13.submit([0] * 40, 4, 0.0, priority=2)
cheap_mid = s13.submit([0] * 4, 4, 0.0, priority=1)
need13 = lambda r: -(-len(r["prompt"]) // 16)
got = s13.admit(0.0, 3, 10 ** 9, need13)
assert [r["id"] for r in got] == [costly_high, cheap_mid, cheap_low], \
    "priority first, page demand only breaks ties within a tier"
a13 = s13.submit([1], 8, 0.0)
s13.submit([2], 8, 5.0)
victim = s13.admit(10.0, 2, 10 ** 9, lambda r: 1)[0]
assert victim["id"] == a13
victim.update(generated=[7, 9], n_preemptions=1, first_token=0.5)
s13.requeue(victim)
s13.submit([3], 8, 7.0)
got = s13.admit(10.0, 3, 10 ** 9, lambda r: 1)
assert got[0]["id"] == a13, "the t=0 arrival resumes at the queue head"
assert got[0]["generated"] == [7, 9] and got[0]["first_token"] == 0.5
assert got[0]["n_preemptions"] == 1
print("13a priority admission + requeue keeps id/arrival/resume state: ok")

# 13b: forced preemption keeps greedy AND sampled output bit-identical to
# the uninterrupted oracle; worst-case reservation never preempts; pages
# and refcounts balance through the evict->requeue->finish churn
P13 = 31


def prompt13(salt):
    # pairwise-distinct 31-token prompts sharing no prefix (tokens >= 3)
    return [3 + ((j * 5 + salt * 11) % (VOC - 3)) for j in range(P13)]


preq13 = [(prompt13(i), 8) for i in range(3)]
pexp13 = {i: oracle_gen(p, mn, CAP12) for i, (p, mn) in enumerate(preq13)}
preempt_totals = {}
for slots, kvp in ((2, 4), (2, 5), (3, 4)):
    eng = EngineSim13(slots, CAP12, PS12, kv_pages=kvp)
    assert eng.pool.n_pages == kvp, "the overcommit knob was ignored"
    idmap = {eng.submit(p, mn, 0.0): i for i, (p, mn) in enumerate(preq13)}
    out = eng.run_until_idle()
    assert len(out) == len(preq13), "dropped or duplicated requests"
    meta_preempts = 0
    for rid, status, gen, meta in out:
        assert (status, gen) == ("OK", pexp13[idmap[rid]]), \
            ("preempted run diverged from the oracle", slots, kvp, idmap[rid])
        meta_preempts += meta["n_preemptions"]
    assert meta_preempts == eng.stats["n_preemptions"], \
        "per-request preemption counts must sum to the engine counter"
    preempt_totals[(slots, kvp)] = eng.stats["n_preemptions"]
    drain_and_check_leaks(eng, slots)
assert sum(preempt_totals.values()) >= 1, \
    ("no schedule exercised the backstop", preempt_totals)
# the uncontended worst-case pool never preempts and matches too
eng = EngineSim13(2, CAP12, PS12)
idmap = {eng.submit(p, mn, 0.0): i for i, (p, mn) in enumerate(preq13)}
for rid, status, gen, _ in eng.run_until_idle():
    assert (status, gen) == ("OK", pexp13[idmap[rid]])
assert eng.stats["n_preemptions"] == 0, "uncontended pool must not preempt"
# worst-case reservation on the overcommitted pool: serializes, never
# preempts, still bit-identical
eng = EngineSim13(2, CAP12, PS12, kv_pages=4, reservation="worst")
idmap = {eng.submit(p, mn, 0.0): i for i, (p, mn) in enumerate(preq13)}
for rid, status, gen, _ in eng.run_until_idle():
    assert (status, gen) == ("OK", pexp13[idmap[rid]])
assert eng.stats["n_preemptions"] == 0, "worst-case reservation must not preempt"
drain_and_check_leaks(eng, 2)
# seeded sampling across a forced preemption: same per-step seed ^
# splitmix(g) stream, so constrained == unconstrained bit-for-bit
sp13 = dict(temperature=0.9, top_k=12, top_p=0.95, seed=0, stop=[])
sreq13 = [(prompt13(i), 8, dict(sp13, seed=700 + i)) for i in range(3)]
sexp13 = {i: oracle_gen(p, mn, CAP12, pr) for i, (p, mn, pr) in enumerate(sreq13)}
eng = EngineSim13(2, CAP12, PS12, kv_pages=4)
idmap = {eng.submit(p, mn, 0.0, pr): i for i, (p, mn, pr) in enumerate(sreq13)}
sampled_preempts = 0
for rid, status, gen, meta in eng.run_until_idle():
    assert (status, gen) == ("OK", sexp13[idmap[rid]]), \
        ("sampled resume diverged", idmap[rid])
    sampled_preempts += meta["n_preemptions"]
assert sampled_preempts == eng.stats["n_preemptions"]
drain_and_check_leaks(eng, 2)
n_exercised = sum(1 for v in preempt_totals.values() if v) + (1 if sampled_preempts else 0)
assert n_exercised >= 1
print(f"13b preempted greedy+sampled == oracle over 3 pool shapes "
      f"({sum(preempt_totals.values())}+{sampled_preempts} preemptions); "
      f"worst-case/uncontended: 0")

# 13c: the victim policy spares the high tier; TTFT is stamped at the
# first emission and never re-stamped across preemption/resume
eng = EngineSim13(2, CAP12, PS12, kv_pages=4)
hi_id = eng.submit(prompt13(0), 8, 0.0, priority=2)
for i in (1, 2, 3):
    eng.submit(prompt13(i), 8, 0.0)
seen_first = {}
out13c = []
steps = 0
while eng.active or eng.sched.next_arrival() is not None:
    if not eng.active:
        eng.now = max(eng.now, eng.sched.next_arrival())
    out13c.extend(eng.step())
    for a in eng.active:
        if a["id"] in seen_first:
            assert a["first_token"] == seen_first[a["id"]], \
                "TTFT re-stamped across a preemption"
        else:
            seen_first[a["id"]] = a["first_token"]
    steps += 1
    assert steps < 5000, "no forward progress"
assert len(out13c) == 4
for rid, status, gen, meta in out13c:
    assert status == "OK"
    if rid in seen_first:
        assert meta["first_token"] == seen_first[rid]
    assert meta["arrival"] <= meta["first_token"] <= meta["finish"]
    if rid == hi_id:
        assert meta["n_preemptions"] == 0, \
            "the high-priority request must never be the victim"
assert eng.stats["n_preemptions"] >= 1, "the contended run must preempt"
drain_and_check_leaks(eng, 2)
print(f"13c victim policy spares the high tier; TTFT stamped once "
      f"({eng.stats['n_preemptions']} preemptions)")

# 13d: the bench's bursty gate — on a contended trace, optimistic
# admission matches or beats worst-case decode occupancy
def bursty13(reservation):
    eng = EngineSim13(2, CAP12, PS12, kv_pages=4, reservation=reservation)
    for i in range(8):
        eng.submit(prompt13(i), 8, 0.0)
    out = eng.run_until_idle()
    assert len(out) == 8 and all(s == "OK" for _, s, _, _ in out)
    drain_and_check_leaks(eng, 2)
    return (eng.stats["decode_tokens"] / max(eng.stats["decode_steps"], 1),
            eng.stats["n_preemptions"])


wc_util, wc_pre = bursty13("worst")
opt_util, opt_pre = bursty13("optimistic")
assert wc_pre == 0, "worst-case reservation must never preempt"
assert opt_util >= wc_util, (opt_util, wc_util)
assert opt_pre >= 1, "the bursty trace must exercise the backstop"
print(f"13d bursty occupancy: optimistic {opt_util:.2f} >= worst-case "
      f"{wc_util:.2f} ({opt_pre} preemptions)")

# 13e: forward-progress fuzz — random lengths, priorities and arrival
# waves over an overcommitted pool always drain, exactly once each
r13 = np.random.default_rng(777)
fuzz_preempts = 0
for _trial in range(4):
    slots = 2 + int(r13.integers(0, 2))
    kvp = 4 + int(r13.integers(0, 2))
    eng = EngineSim13(slots, CAP12, PS12, kv_pages=kvp)
    ids = set()

    def wave(count, at):
        for _ in range(count):
            ln = 1 + int(r13.integers(0, 48))
            p = [3 + int(t) for t in r13.integers(0, VOC - 3, size=ln)]
            ids.add(eng.submit(p, 1 + int(r13.integers(0, 8)), at,
                               priority=int(r13.integers(0, 4))))

    wave(4 + int(r13.integers(0, 4)), 0.0)
    out, steps = [], 0
    while eng.active or eng.sched.next_arrival() is not None:
        if not eng.active:
            eng.now = max(eng.now, eng.sched.next_arrival())
        out.extend(eng.step())
        steps += 1
        if steps == 2:
            wave(2 + int(r13.integers(0, 3)), eng.now)
        assert steps < 5000, "no forward progress"
    got_ids = sorted(o[0] for o in out)
    assert got_ids == sorted(ids), "dropped or duplicated requests"
    fuzz_preempts += eng.stats["n_preemptions"]
    drain_and_check_leaks(eng, slots)
print(f"13e forward-progress fuzz: 4 random overcommitted workloads drained "
      f"({fuzz_preempts} preemptions)")

# ---- 14: streaming log-bucketed histogram (telemetry::hist) ------------
# Op-for-op port of LogHistogram: fixed 320 preallocated buckets, 8 per
# octave starting at 1e-9, rank-based quantiles at geometric bucket
# midpoints clamped to the exact observed [min, max].
H_MIN, H_BPO, H_NB = 1e-9, 8, 320


def h_bucket_index(v):
    if not np.isfinite(v) or v <= H_MIN:
        return 0
    return min(int((np.log2(v) - np.log2(H_MIN)) * H_BPO), H_NB - 1)


def h_lower(i):
    return H_MIN * 2.0 ** (i / H_BPO)


def h_width(i):
    return h_lower(i + 1) - h_lower(i)


class HistSim14:
    def __init__(self):
        self.counts = np.zeros(H_NB, dtype=np.uint64)
        self.n, self.total = 0, 0.0
        self.lo, self.hi = np.inf, -np.inf

    def record(self, v):
        if not np.isfinite(v):
            return
        self.counts[h_bucket_index(v)] += 1
        self.n += 1
        self.total += v
        self.lo, self.hi = min(self.lo, v), max(self.hi, v)

    def merge(self, other):
        self.counts += other.counts
        self.n += other.n
        self.total += other.total
        self.lo, self.hi = min(self.lo, other.lo), max(self.hi, other.hi)

    def quantile(self, q):
        if self.n == 0:
            return float("nan")
        if q <= 0.0:
            return self.lo
        if q >= 1.0:
            return self.hi
        rank = int((self.n - 1) * q)
        seen = 0
        for i in range(H_NB):
            seen += int(self.counts[i])
            if seen > rank:
                mid = h_lower(i) * 2.0 ** (1.0 / (2 * H_BPO))
                return min(max(mid, self.lo), self.hi)
        return self.hi


r14 = np.random.default_rng(1414)
n_checked = 0
for dist in ("loguniform", "lognormal"):
    for n in (1, 2, 7, 100, 2000):
        if dist == "loguniform":
            samples = 10.0 ** r14.uniform(-6.0, 2.0, size=n)
        else:
            samples = np.exp(r14.normal(-5.0, 2.0, size=n))
        h = HistSim14()
        for v in samples:
            h.record(float(v))
        srt = np.sort(samples)
        assert h.n == n and abs(h.total - samples.sum()) <= 1e-9 * samples.sum()
        for q in (0.0, 0.5, 0.9, 0.95, 0.99, 1.0):
            exact = float(srt[int((n - 1) * q)])
            got = h.quantile(q)
            tol = h_width(h_bucket_index(exact)) + 1e-15
            assert abs(got - exact) <= tol, (dist, n, q, got, exact, tol)
            n_checked += 1
        assert h.quantile(0.0) == float(srt[0]), "q=0 must be exact"
        assert h.quantile(1.0) == float(srt[-1]), "q=1 must be exact"

# merge(a, b) == feed(a ++ b), bucket-for-bucket and quantile-for-quantile
xs = 10.0 ** r14.uniform(-6.0, 2.0, size=500)
ys = np.exp(r14.normal(-5.0, 2.0, size=313))
ha, hb, hw = HistSim14(), HistSim14(), HistSim14()
for v in xs:
    ha.record(float(v))
    hw.record(float(v))
for v in ys:
    hb.record(float(v))
    hw.record(float(v))
ha.merge(hb)
assert np.array_equal(ha.counts, hw.counts) and ha.n == hw.n
assert ha.lo == hw.lo and ha.hi == hw.hi
for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
    assert ha.quantile(q) == hw.quantile(q), q

# bucket formula edges: underflow clamps to 0, overflow to the top bucket
assert h_bucket_index(0.0) == 0 and h_bucket_index(H_MIN) == 0
assert h_bucket_index(float("nan")) == 0
assert h_bucket_index(1e300) == H_NB - 1
mid_ratio = 2.0 ** (1.0 / H_BPO)
assert abs(mid_ratio - 1.0902) < 1e-3, "one bucket spans ~9%"
print(f"14 log-bucketed histogram: {n_checked} quantiles within one bucket "
      f"width of exact; merge == concat-feed; edges clamp")

print("\nALL KV-SERVING VERIFICATION CHECKS PASSED")
