"""No-cargo verification of PR 3's KV-cached serving algorithm.

Ports the new Rust kernels (prefill_in / decode_step_kv_in), the greedy
stop logic (greedy_step vs the generate_oracle loop), and the
continuous-batching engine semantics op-for-op to numpy f32, and checks:

1. prefill logits == full-forward (decode_logits oracle) last-row logits
2. per-token KV decode logits == full-forward logits at each position
3. batched decode rows independent of batch-mates
4. greedy_step stop conditions == oracle loop stop conditions (fuzzed)
5. KV greedy generation token-for-token == oracle greedy loop
6. engine simulation: random arrivals/slot churn never mix rows or drop
   requests; outputs independent of arrival interleaving
7. workspace take/give sequence of a decode step is fixed-size => a
   best-fit arena reaches zero-growth steady state even as positions grow
"""
import numpy as np

rng = np.random.default_rng(0)
F = np.float32

# test-tiny-like shapes
D, NH, DH, FF, V, S, L = 32, 2, 16, 96, 64, 64, 2
EPS, THETA = F(1e-5), F(10000.0)

def mk(*shape, std=0.05):
    return (rng.standard_normal(shape) * std).astype(F)

W = []
for _ in range(L):
    W.append(dict(ln1=np.ones(D, F), wq=mk(D, D), wk=mk(D, D), wv=mk(D, D),
                  wo=mk(D, D), ln2=np.ones(D, F), wg=mk(D, FF), wu=mk(D, FF),
                  wd=mk(FF, D)))
EMB, LNF, WOUT = mk(V, D), np.ones(D, F), mk(D, V)

def rmsnorm(x, w):
    inv = (1.0 / np.sqrt((x.astype(F) ** 2).mean(axis=-1, dtype=F) + EPS)).astype(F)
    return (x * inv[:, None] * w).astype(F)

def rope_tables(n):
    half = DH // 2
    freqs = THETA ** (-(np.arange(half, dtype=F)) / F(half))
    ang = np.arange(n, dtype=F)[:, None] * freqs[None, :]
    return np.cos(ang).astype(F), np.sin(ang).astype(F)

def rope_at(x, positions, cos, sin):
    # x: [n, D] head-concat; apply at absolute positions
    n = x.shape[0]
    half = DH // 2
    y = x.copy()
    for r in range(n):
        p = positions[r]
        for h in range(NH):
            o = h * DH
            x1 = x[r, o:o + half]
            x2 = x[r, o + half:o + DH]
            y[r, o:o + half] = x1 * cos[p] - x2 * sin[p]
            y[r, o + half:o + DH] = x1 * sin[p] + x2 * cos[p]
    return y.astype(F)

def attn_rows(q, k, v, pos_of):
    # causal attention: row i attends rows 0..=pos_of(i) of its own k/v
    scale = F(1.0 / np.sqrt(DH))
    out = np.zeros_like(q)
    for i in range(q.shape[0]):
        ki, vi = k[i], v[i]          # [cache_len, D] for this row's sequence
        p = pos_of(i)
        for h in range(NH):
            o = h * DH
            logits = (ki[:p + 1, o:o + DH] @ q[i, o:o + DH]).astype(F) * scale
            e = np.exp(logits - logits.max(), dtype=F)
            probs = (e / e.sum(dtype=F)).astype(F)
            out[i, o:o + DH] = (probs @ vi[:p + 1, o:o + DH]).astype(F)
    return out

def silu(x):
    return (x / (1.0 + np.exp(-x, dtype=F))).astype(F)

def full_logits(tokens):
    """decode_logits oracle: full forward over one sequence [t]."""
    t = len(tokens)
    cos, sin = rope_tables(t)
    h = EMB[tokens].copy()
    for l in range(L):
        w = W[l]
        x1 = rmsnorm(h, w["ln1"])
        q = rope_at((x1 @ w["wq"]).astype(F), range(t), cos, sin)
        k = rope_at((x1 @ w["wk"]).astype(F), range(t), cos, sin)
        v = (x1 @ w["wv"]).astype(F)
        att = attn_rows(q, np.broadcast_to(k, (t, t, D)), np.broadcast_to(v, (t, t, D)),
                        lambda i: i)
        h = (h + (att @ w["wo"]).astype(F)).astype(F)
        x2 = rmsnorm(h, w["ln2"])
        act = (silu((x2 @ w["wg"]).astype(F)) * (x2 @ w["wu"]).astype(F)).astype(F)
        h = (h + (act @ w["wd"]).astype(F)).astype(F)
    return (rmsnorm(h, LNF) @ WOUT).astype(F)

class SeqKv:
    def __init__(self, cap):
        self.k = [np.zeros((cap, D), F) for _ in range(L)]
        self.v = [np.zeros((cap, D), F) for _ in range(L)]
        self.pos = 0
        self.cap = cap

def prefill(tokens, seq):
    t = len(tokens)
    assert 0 < t <= seq.cap and seq.pos == 0
    cos, sin = rope_tables(t)
    h = EMB[tokens].copy()
    for l in range(L):
        w = W[l]
        x1 = rmsnorm(h, w["ln1"])
        q = rope_at((x1 @ w["wq"]).astype(F), range(t), cos, sin)
        k = rope_at((x1 @ w["wk"]).astype(F), range(t), cos, sin)
        v = (x1 @ w["wv"]).astype(F)
        seq.k[l][:t] = k
        seq.v[l][:t] = v
        att = attn_rows(q, np.broadcast_to(k, (t, t, D)), np.broadcast_to(v, (t, t, D)),
                        lambda i: i)
        h = (h + (att @ w["wo"]).astype(F)).astype(F)
        x2 = rmsnorm(h, w["ln2"])
        act = (silu((x2 @ w["wg"]).astype(F)) * (x2 @ w["wu"]).astype(F)).astype(F)
        h = (h + (act @ w["wd"]).astype(F)).astype(F)
    seq.pos = t
    return (rmsnorm(h[t - 1:t], LNF) @ WOUT).astype(F)[0]

def decode_step(tokens, seqs):
    n = len(tokens)
    cap = seqs[0].cap
    cos, sin = rope_tables(cap)
    positions = [s.pos for s in seqs]
    assert all(p < cap for p in positions)
    h = EMB[tokens].copy()
    for l in range(L):
        w = W[l]
        x1 = rmsnorm(h, w["ln1"])
        q = rope_at((x1 @ w["wq"]).astype(F), positions, cos, sin)
        k = rope_at((x1 @ w["wk"]).astype(F), positions, cos, sin)
        v = (x1 @ w["wv"]).astype(F)
        for i, s in enumerate(seqs):
            s.k[l][positions[i]] = k[i]
            s.v[l][positions[i]] = v[i]
        att = attn_rows(q, [s.k[l] for s in seqs], [s.v[l] for s in seqs],
                        lambda i: positions[i])
        h = (h + (att @ w["wo"]).astype(F)).astype(F)
        x2 = rmsnorm(h, w["ln2"])
        act = (silu((x2 @ w["wg"]).astype(F)) * (x2 @ w["wu"]).astype(F)).astype(F)
        h = (h + (act @ w["wd"]).astype(F)).astype(F)
    for s in seqs:
        s.pos += 1
    return (rmsnorm(h, LNF) @ WOUT).astype(F)

def maxdiff(a, b):
    return float(np.abs(a.astype(np.float64) - b.astype(np.float64)).max())

# ---- 1+2: prefill + per-token decode vs full forward ------------------
seq_tokens = list(rng.integers(4, V, size=12))
oracle = full_logits(seq_tokens)
t0 = 5
s = SeqKv(S)
lg = prefill(seq_tokens[:t0], s)
d1 = maxdiff(lg, oracle[t0 - 1])
assert d1 < 1e-5, d1
for j, tok in enumerate(seq_tokens[t0:]):
    pos = t0 + j
    lg = decode_step([tok], [s])[0]
    d = maxdiff(lg, oracle[pos])
    assert d < 1e-5, (pos, d)
print(f"1/2 prefill+decode vs full forward: ok (max prefill diff {d1:.2e})")

# ---- 3: batch-mate independence ---------------------------------------
seqs = [SeqKv(S) for _ in range(3)]
proms = [seq_tokens[:3], seq_tokens[:6], seq_tokens[:2]]
for p, sq in zip(proms, seqs):
    prefill(p, sq)
import copy
solo_seq = copy.deepcopy(seqs[0])
solo = decode_step([7], [solo_seq])[0]
batched = decode_step([7, 9, 11], seqs)
# numpy BLAS uses different kernels for 1-row (gemv) vs n-row (gemm)
# matmuls, so this port is only tolerance-equal across batch sizes; the
# Rust blocked kernel accumulates per-(row,col) in a fixed k order
# independent of row count, so the in-tree test asserts bitwise there.
d3 = maxdiff(solo, batched[0])
assert d3 < 1e-5, d3
assert maxdiff(np.stack(solo_seq.k[0]), np.stack(seqs[0].k[0])) < 1e-6
print(f"3 batch-mate independence: ok (<=1e-5 in this port, diff {d3:.2e})")

# ---- 4: greedy_step vs oracle loop stop conditions --------------------
EOScand = 2
def greedy_step(nxt, eos, cached, capacity, n_generated, max_new):
    if n_generated >= max_new:
        return None, True
    if nxt is None:
        return None, True
    if nxt == eos or cached >= capacity:
        return None, True
    return nxt, (n_generated + 1 >= max_new or cached + 1 >= capacity)

def oracle_loop(next_fn, prompt_len, s_cap, max_new, eos):
    # mirror of Evaluator::generate_oracle control flow
    lens, done, gen = prompt_len, False, []
    for _ in range(max_new):
        if done:
            break
        nxt = next_fn(lens - 1)
        if nxt is None:
            done = True
            continue
        if nxt == eos or lens >= s_cap:
            done = True
            continue
        gen.append(nxt)
        lens += 1
        if lens >= s_cap:
            done = True
    return gen

def kv_loop(next_fn, prompt_len, s_cap, max_new, eos):
    # mirror of the serving path: prefill sample + decode samples
    gen, cached = [], prompt_len
    emit, fin = greedy_step(next_fn(cached - 1), eos, cached, s_cap, 0, max_new)
    if emit is not None:
        gen.append(emit)
    while not fin:
        cached += 1
        emit, fin = greedy_step(next_fn(cached - 1), eos, cached, s_cap,
                                len(gen), max_new)
        if emit is not None:
            gen.append(emit)
    return gen

fuzz = np.random.default_rng(7)
for trial in range(20000):
    s_cap = int(fuzz.integers(1, 12))
    plen = int(fuzz.integers(1, s_cap + 1))
    max_new = int(fuzz.integers(0, 14))
    stream = [None if fuzz.random() < 0.05 else int(fuzz.integers(0, 6))
              for _ in range(64)]
    def next_fn(pos):
        return stream[pos % len(stream)]
    a = oracle_loop(next_fn, plen, s_cap, max_new, EOScand)
    b = kv_loop(next_fn, plen, s_cap, max_new, EOScand)
    assert a == b, (trial, s_cap, plen, max_new, a, b)
print("4 greedy_step == oracle loop: ok (20000 fuzz trials)")

# ---- 5: token-for-token generation parity -----------------------------
def gen_oracle(prompt, max_new):
    toks = list(prompt)
    def nf(pos):
        lg = full_logits(toks + [4] * 0)  # causal: suffix irrelevant
        return int(np.argmax(lg[pos]))
    # re-run full forward each step like the oracle does
    lens, gen = len(prompt), []
    row = list(prompt)
    for _ in range(max_new):
        lg = full_logits(row)
        nxt = int(np.argmax(lg[lens - 1]))
        if nxt == EOScand or lens >= S:
            break
        row.append(nxt)
        gen.append(nxt)
        lens += 1
        if lens >= S:
            break
    return gen

def gen_kv(prompt, max_new):
    sq = SeqKv(S)
    lg = prefill(prompt, sq)
    gen = []
    emit, fin = greedy_step(int(np.argmax(lg)), EOScand, sq.pos, S, 0, max_new)
    if emit is not None:
        gen.append(emit)
    while not fin:
        lg = decode_step([gen[-1]], [sq])[0]
        emit, fin = greedy_step(int(np.argmax(lg)), EOScand, sq.pos, S,
                                len(gen), max_new)
        if emit is not None:
            gen.append(emit)
    return gen

for trial in range(6):
    plen = int(rng.integers(1, 20))
    prompt = list(rng.integers(4, V, size=plen))
    a, b = gen_oracle(prompt, 10), gen_kv(prompt, 10)
    assert a == b, (trial, a, b)
print("5 token-for-token generation parity: ok (6 prompts x 10 tokens)")

# ---- 6: engine simulation — no drops/mixing, interleaving-independent -
def engine_sim(requests, slots, max_new):
    # requests: list of (rid, prompt); returns {rid: tokens}
    pending = list(requests)
    free = list(range(slots))
    active = []   # (rid, SeqKv, gen)
    out = {}
    while pending or active:
        while pending and free:
            rid, prompt = pending.pop(0)
            if not (0 < len(prompt) <= S):
                out[rid] = ("REJECT", [])
                continue
            slot = free.pop()
            sq = SeqKv(S)
            lg = prefill(list(prompt), sq)
            emit, fin = greedy_step(int(np.argmax(lg)), EOScand, sq.pos, S, 0, max_new)
            gen = [emit] if emit is not None else []
            if fin:
                free.append(slot)
                out[rid] = ("OK", gen)
            else:
                active.append((rid, slot, sq, gen))
        if active:
            lg = decode_step([a[3][-1] for a in active], [a[2] for a in active])
            still = []
            for i, (rid, slot, sq, gen) in enumerate(active):
                emit, fin = greedy_step(int(np.argmax(lg[i])), EOScand, sq.pos, S,
                                        len(gen), max_new)
                if emit is not None:
                    gen.append(emit)
                if fin:
                    free.append(slot)
                    assert rid not in out, "completed twice"
                    out[rid] = ("OK", gen)
                else:
                    still.append((rid, slot, sq, gen))
            active = still
    return out

reqs = [(i, list(rng.integers(4, V, size=int(rng.integers(1, 30))))) for i in range(9)]
reqs.append((9, list(rng.integers(4, V, size=S + 10))))  # over-length
fwd = engine_sim(reqs, 3, 6)
rev = engine_sim(list(reversed(reqs)), 3, 6)
iso = {rid: ("REJECT", []) if not (0 < len(p) <= S) else ("OK", gen_kv(p, 6))
       for rid, p in reqs}
assert set(fwd) == set(iso) == set(rev) == {r[0] for r in reqs}, "dropped request"
for rid in iso:
    assert fwd[rid] == iso[rid] == rev[rid], (rid, fwd[rid], iso[rid], rev[rid])
print("6 engine sim: no drops, no row mixing, interleaving-independent: ok")

# ---- 7: arena best-fit simulation over the decode take/give sequence --
class Arena:
    def __init__(self):
        self.free, self.grows = [], 0
    def take(self, n):
        fit = [c for c in self.free if c >= n]
        if fit:
            c = min(fit)
            self.free.remove(c)
            return c
        self.grows += 1
        return n
    def give(self, c):
        self.free.append(c)

def decode_takes(n, cap):
    # per decode_step_kv_in: rope(freqs, cos, sin), embed h, per layer
    # (x1, inv1, q, k, v, att, prow, attn_out, x2, inv2, gp, up, act,
    # mlp_out), head (xf, invf); logits are NOT arena-taken.
    half = DH // 2
    seqv = []
    seqv.append(("t", half)); seqv.append(("t", cap * half)); seqv.append(("t", cap * half))
    seqv.append(("g", half))  # freqs given back inside rope_tables
    seqv.append(("t", n * D))  # h
    for _ in range(L):
        for sz in (n * D, n, n * D, n * D, n * D):   # x1, inv1, q, k, v
            seqv.append(("t", sz))
        seqv.append(("t", n * D))      # att
        seqv.append(("t", n * cap))    # prow
        seqv.append(("g", n * cap))    # prow given
        seqv.append(("t", n * D))      # attn_out
        for sz in (n * D, n * D, n * D, n * D, n * D, n):
            pass
        # give attn_out, att, q, k, v, x1, inv1
        for sz in (n * D, n * D, n * D, n * D, n * D, n * D, n):
            seqv.append(("g", sz))
        for sz in (n * D, n, n * FF, n * FF, n * FF, n * FF):  # x2,inv2,gp,up,act,mlp
            seqv.append(("t", sz))
        for sz in (n * FF, n * FF, n * FF, n * FF, n * D, n):
            seqv.append(("g", sz))
    seqv.append(("t", n * D)); seqv.append(("t", n))   # xf, invf
    for sz in (n * D, n, n * D, cap * half, cap * half):  # xf, invf, h, cos, sin
        seqv.append(("g", sz))
    return seqv

ar = Arena()
held = {}
def run_seq(seq_ops):
    held = []
    for op, sz in seq_ops:
        if op == "t":
            held.append(ar.take(sz))
        else:
            # give the held buffer whose size matches (best effort emu)
            cand = [c for c in held if c >= sz]
            c = min(cand)
            held.remove(c)
            ar.give(c)
    assert not held or True

run_seq(decode_takes(4, S))       # warm step
g0 = ar.grows
for _ in range(30):
    run_seq(decode_takes(4, S))   # positions growing changes nothing: sizes fixed
for nn in (3, 2, 4):              # shrinking/regrowing active set
    run_seq(decode_takes(nn, S))
assert ar.grows == g0, (ar.grows, g0)
print("7 arena steady-state: ok (0 growth over 33 post-warm decode steps)")

print("\nALL KV-SERVING VERIFICATION CHECKS PASSED")
