//! Fixture: code every lint rule should accept.

/// Reads one element out of a raw buffer.
pub fn read_one(buf: &[f32], i: usize) -> f32 {
    assert!(i < buf.len());
    // SAFETY: the bounds check above guarantees `i` is in range, and
    // the shared borrow keeps the buffer alive for the read.
    unsafe { *buf.as_ptr().add(i) }
}

/// A doc-commented unsafe fn is covered by its `# Safety` section.
///
/// # Safety
///
/// `p` must be non-null and valid for reads of one `f32`.
pub unsafe fn read_raw(p: *const f32) -> f32 {
    // SAFETY: caller contract (see `# Safety` above)
    unsafe { *p }
}

pub fn steady_loop(xs: &[f32]) -> f32 {
    let mut acc = 0.0;
    // steady-state: per-element invariants are debug-only
    for &x in xs {
        debug_assert!(x.is_finite());
        acc += x;
    }
    acc
}

pub fn fallible(v: Option<u32>) -> u32 {
    // unwrap_or is fine even in serve/ paths
    v.unwrap_or(0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let v: Option<u32> = Some(3);
        assert_eq!(v.unwrap(), 3);
    }
}
