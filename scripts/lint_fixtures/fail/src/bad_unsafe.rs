//! Fixture: unsafe without a SAFETY comment must be flagged.

pub fn read_one(buf: &[f32], i: usize) -> f32 {
    unsafe { *buf.as_ptr().add(i) }
}
