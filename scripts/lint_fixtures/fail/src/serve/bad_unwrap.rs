//! Fixture: unwrap/expect in a serve hot path must be flagged.

pub fn pick(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn pick2(v: Option<u32>) -> u32 {
    v.expect("always present")
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_here_is_fine() {
        assert_eq!(super::pick(Some(1)), 1);
    }
}
