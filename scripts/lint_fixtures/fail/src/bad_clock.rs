//! Fixture: a raw clock read outside telemetry/ must be flagged.

pub fn timestamp() -> f64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
