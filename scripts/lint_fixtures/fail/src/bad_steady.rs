//! Fixture: bare assert! in a steady-state-marked block must be flagged.

pub fn steady_loop(xs: &[f32]) -> f32 {
    let mut acc = 0.0;
    // steady-state: invariants here must be debug-only
    for &x in xs {
        assert!(x.is_finite());
        acc += x;
    }
    acc
}
