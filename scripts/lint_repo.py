#!/usr/bin/env python3
"""Repo-local soundness lint for the Rust tree (stdlib only).

Four rules, each keyed to an invariant the compiler cannot check:

  unsafe-needs-safety      every `unsafe` block / impl / fn must be
                           preceded (within a few lines) by a
                           `// SAFETY:` comment or a `/// # Safety`
                           doc section stating the proof obligation.
  no-unwrap-in-hot-path    `unwrap()` / `expect(` are banned in
                           `src/serve/` and `src/runtime/` outside
                           test code — hot paths return Result, they
                           do not abort the process.
  steady-state-assert      a block opened under a `// steady-state:`
                           marker must use `debug_assert` rather than
                           bare `assert!` so the invariant costs
                           nothing in release builds.
  clock-outside-telemetry  `std::time` clock reads (Instant / SystemTime)
                           are confined to `src/telemetry/`,
                           `src/util/bench.rs`, benches, examples and
                           tests; everything else goes through
                           `telemetry::Stopwatch` so tests and Miri see
                           a single, mockable time seam.

Failures print `path:line: rule-id: message`, one per line, and the
process exits 1. Run from anywhere:

    python3 scripts/lint_repo.py           # lint the repo
    python3 scripts/lint_repo.py DIR ...   # lint specific roots (tests)
"""
import os
import re
import sys

RULE_SAFETY = "unsafe-needs-safety"
RULE_UNWRAP = "no-unwrap-in-hot-path"
RULE_STEADY = "steady-state-assert"
RULE_CLOCK = "clock-outside-telemetry"

# how many *code* lines above an `unsafe` keyword we accept its SAFETY
# comment; comment / doc / attribute lines are free so one comment can
# govern a short group of unsafe expressions or sit atop a doc block
SAFETY_LOOKBACK = 6
SAFETY_WALK_CAP = 40

# `unsafe` as a word — does not match `unsafe_op_in_unsafe_fn` etc.
# (underscore is a word char, so \b already excludes those).
UNSAFE_RE = re.compile(r"\bunsafe\b")
SAFETY_COMMENT_RE = re.compile(r"//\s*SAFETY:|#\s*Safety")
UNWRAP_RE = re.compile(r"\.(unwrap|expect)\s*\(")
UNWRAP_OK_RE = re.compile(r"\.(unwrap_or|unwrap_or_else|unwrap_or_default|unwrap_unchecked|expect_err)\b")
STEADY_MARK_RE = re.compile(r"//\s*steady-state:")
BARE_ASSERT_RE = re.compile(r"(?<!debug_)\bassert(_eq|_ne)?!\s*[\(\[]")
CLOCK_RE = re.compile(r"\b(?:std::time::)?(Instant|SystemTime)\s*::\s*now\s*\(|use\s+std::time")

# directories / files where clock reads are legitimate (posix-style
# fragments matched against the normalized relative path)
CLOCK_ALLOW = ("src/telemetry/", "src/util/bench.rs", "benches/", "examples/", "tests/")
# paths where unwrap/expect are banned outside test code
HOT_PATHS = ("src/serve/", "src/runtime/")


def has_safety_comment(lines, idx):
    """Walk upward from the unsafe at `idx` looking for its proof.

    Comment, doc-comment and attribute lines are free; at most
    SAFETY_LOOKBACK other lines may separate the comment from the
    `unsafe` keyword (so one `// SAFETY:` can cover a short group of
    consecutive unsafe expressions), capped at SAFETY_WALK_CAP lines.
    """
    budget = SAFETY_LOOKBACK
    for i in range(idx, max(-1, idx - SAFETY_WALK_CAP), -1):
        line = lines[i]
        if SAFETY_COMMENT_RE.search(line):
            return True
        stripped = line.strip()
        free = (stripped.startswith("//") or stripped.startswith("#[")
                or stripped.startswith("#!["))
        if i != idx and not free:
            budget -= 1
            if budget < 0:
                return False
    return False


def is_test_region(lines, idx):
    """True if line idx sits under a `#[cfg(test)]` module.

    Heuristic: the nearest enclosing `mod`-opening brace preceded by
    `#[cfg(test)]`. Good enough for this tree, where test modules are
    the conventional trailing `#[cfg(test)] mod tests { .. }`.
    """
    depth = 0
    for i in range(idx, -1, -1):
        line = lines[i]
        depth += line.count("}") - line.count("{")
        if depth < 0 and "mod " in line:
            for j in range(max(0, i - 3), i + 1):
                if "#[cfg(test)]" in lines[j]:
                    return True
            depth = 0  # keep walking up through outer scopes
    return False


def lint_file(path, rel, findings):
    try:
        with open(path, encoding="utf-8") as fh:
            lines = fh.read().splitlines()
    except (OSError, UnicodeDecodeError) as e:
        findings.append(f"{rel}:1: io: cannot read file: {e}")
        return

    in_hot_path = any(frag in rel for frag in HOT_PATHS)
    clock_allowed = any(frag in rel for frag in CLOCK_ALLOW)

    steady_until = -1  # line index bound of the active steady-state block
    steady_line = -1

    for idx, raw in enumerate(lines):
        lineno = idx + 1
        # strip line comments for code-pattern rules, but keep the raw
        # text for comment-pattern rules
        code = raw.split("//", 1)[0] if "//" in raw else raw

        # --- rule: unsafe-needs-safety ------------------------------
        if UNSAFE_RE.search(code) and not has_safety_comment(lines, idx):
            findings.append(
                f"{rel}:{lineno}: {RULE_SAFETY}: `unsafe` without a "
                f"`// SAFETY:` comment within {SAFETY_LOOKBACK} lines above"
            )

        # --- rule: no-unwrap-in-hot-path ----------------------------
        if in_hot_path and UNWRAP_RE.search(code) and not UNWRAP_OK_RE.search(code):
            if not is_test_region(lines, idx):
                findings.append(
                    f"{rel}:{lineno}: {RULE_UNWRAP}: unwrap()/expect() in a "
                    "serve/runtime hot path (return an error instead)"
                )

        # --- rule: steady-state-assert ------------------------------
        if STEADY_MARK_RE.search(raw):
            steady_line = lineno
            steady_until = idx + 12  # marker governs the next block
        if idx <= steady_until and BARE_ASSERT_RE.search(code):
            findings.append(
                f"{rel}:{lineno}: {RULE_STEADY}: bare assert! in a block "
                f"marked `// steady-state:` (line {steady_line}); use "
                "debug_assert! so release builds pay nothing"
            )

        # --- rule: clock-outside-telemetry --------------------------
        if not clock_allowed and CLOCK_RE.search(code):
            if not is_test_region(lines, idx):
                findings.append(
                    f"{rel}:{lineno}: {RULE_CLOCK}: std::time clock read "
                    "outside telemetry/ (use telemetry::Stopwatch)"
                )


def iter_rust_files(root):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in ("vendor", "target", ".git")]
        for name in sorted(filenames):
            if name.endswith(".rs"):
                yield os.path.join(dirpath, name)


def main(argv):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if len(argv) > 1:
        roots = argv[1:]
        base = os.path.commonpath([os.path.abspath(r) for r in roots])
    else:
        roots = [os.path.join(repo, "rust", "src"),
                 os.path.join(repo, "rust", "tests"),
                 os.path.join(repo, "rust", "benches"),
                 os.path.join(repo, "examples")]
        base = repo
    findings = []
    n_files = 0
    for root in roots:
        if not os.path.isdir(root):
            continue
        for path in iter_rust_files(root):
            n_files += 1
            rel = os.path.relpath(path, base).replace(os.sep, "/")
            lint_file(path, rel, findings)
    for f in findings:
        print(f)
    if findings:
        print(f"lint_repo: FAIL: {len(findings)} finding(s) across {n_files} files")
        return 1
    print(f"lint_repo: OK ({n_files} files clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
