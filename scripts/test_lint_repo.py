#!/usr/bin/env python3
"""Self-test for lint_repo.py against the checked-in fixture corpus.

Runs the linter over scripts/lint_fixtures/{pass,fail} and asserts that
the pass corpus is clean, that every fail fixture fires exactly the rule
it was written to exercise, and that nothing else fires. Run with:

    python3 scripts/test_lint_repo.py
"""
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
LINT = os.path.join(HERE, "lint_repo.py")
FIXTURES = os.path.join(HERE, "lint_fixtures")

failures = []


def check(name, ok, detail=""):
    status = "ok" if ok else "FAIL"
    print(f"  {name}: {status}")
    if not ok:
        failures.append(name)
        if detail:
            print(detail)


def run(root):
    p = subprocess.run(
        [sys.executable, LINT, root], capture_output=True, text=True
    )
    return p.returncode, p.stdout


def main():
    print("test_lint_repo:")

    code, out = run(os.path.join(FIXTURES, "pass"))
    check("pass corpus is clean (exit 0)", code == 0, out)
    check("pass corpus has no findings", "FAIL" not in out, out)

    code, out = run(os.path.join(FIXTURES, "fail"))
    check("fail corpus exits nonzero", code == 1, out)

    expected = [
        ("bad_unsafe.rs:4", "unsafe-needs-safety"),
        ("serve/bad_unwrap.rs:4", "no-unwrap-in-hot-path"),
        ("serve/bad_unwrap.rs:8", "no-unwrap-in-hot-path"),
        ("bad_steady.rs:7", "steady-state-assert"),
        ("bad_clock.rs:4", "clock-outside-telemetry"),
    ]
    for loc, rule in expected:
        hit = any(loc in line and rule in line for line in out.splitlines())
        check(f"fires {rule} at {loc}", hit, out)

    # each fail fixture fires exactly its own rule: no cross-talk, and
    # the test-module unwrap inside bad_unwrap.rs is not flagged
    finding_lines = [l for l in out.splitlines() if ": " in l and ".rs:" in l]
    check(
        f"exactly {len(expected)} findings (got {len(finding_lines)})",
        len(finding_lines) == len(expected),
        out,
    )
    check(
        "test-module unwrap not flagged",
        not any("bad_unwrap.rs:15" in l for l in finding_lines),
        out,
    )

    if failures:
        print(f"test_lint_repo: FAIL ({len(failures)} check(s))")
        return 1
    print("test_lint_repo: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
