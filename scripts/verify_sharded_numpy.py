#!/usr/bin/env python3
"""Protocol-level verification of the sharded data-parallel trainer
(rust/src/train/sharded.rs) against a single-worker oracle, in numpy f32.

The Rust parity suite (tests/sharded_parity.rs) pins the real kernels;
this script validates the *protocol algebra* the trainer relies on, in
an environment without a Rust toolchain:

  1. the contiguous floor-half reduction tree (model::forward::
     tree_sum_f32 / tree_add_chunks): folding per-shard subtree partials
     reproduces the full-batch reduction bit-for-bit whenever a
     power-of-two shard count divides the batch;
  2. the two-phase selection-gated collective: explore steps gather all
     blocks, the coordinator reduces, computes f32-rounded norms
     (sqrt(f64(f32(sum g^2)))), clips, records, chooses; exploit steps
     gather only the decided blocks; masked+clip records selected-only
     norms — all mirroring train/trainer.rs's host-loop gating exactly;
  3. worker replicas reconstruct the tracker from the broadcast pre-clip
     f32 squared norms and the clip scale, resolve the same selection,
     and apply the same AdamW update — ending every step bit-identical
     to both the coordinator and the single-worker oracle.

Each step-shape/clip combination runs 24 steps at shard counts {1,2,4}
and asserts per-step loss bits, per-step coordinator AND worker replica
parameter bits, and final parameter bits against the single-worker run.
"""

import struct
import numpy as np

F32 = np.float32
N_BLOCKS = 5
NUMELS = [7, 12, 5, 9, 16]
BATCH = 8
STEPS = 24
LR = F32(0.01)
B1, B2, EPS, WD = F32(0.9), F32(0.999), F32(1e-8), F32(0.01)


def bits(x):
    return struct.pack("<f", float(F32(x)))


def arr_bits(a):
    return np.asarray(a, dtype=F32).tobytes()


# ---- model::forward reduction trees (contiguous floor-half) ----

def tree_sum_f32(xs):
    n = len(xs)
    if n == 0:
        return F32(0.0)
    if n == 1:
        return F32(xs[0])
    h = n // 2
    return F32(tree_sum_f32(xs[:h]) + tree_sum_f32(xs[h:]))


def tree_add(parts):
    """tree_add_chunks over a list of equal-length f32 vectors."""
    n = len(parts)
    if n == 1:
        return parts[0].copy()
    h = n // 2
    return (tree_add(parts[:h]) + tree_add(parts[h:])).astype(F32)


def loss_from_sum(s, n_mask):
    return F32(F32(s) / F32(max(n_mask, 1)))


# ---- selection::grad_norm (f32 boundary rounding) ----

def block_norm_sq(g):
    acc = 0.0
    for x in np.asarray(g, dtype=F32):
        acc += float(x) * float(x)
    return acc  # f64


def norm_from_sq_f32(sq32):
    return float(np.sqrt(np.float64(F32(sq32))))


def clip_scale(clip, norms):
    g = float(np.sqrt(sum(n * n for n in norms)))
    if g > clip:
        return F32(clip / g)
    return None


def top_k(values, k):
    idx = sorted(range(len(values)), key=lambda i: (-values[i], i))[:k]
    return sorted(idx)


# ---- toy per-row backward: deterministic f32 grads/losses ----

def splitmix(x):
    x = (x + 0x9E3779B97F4A7C15) & (2**64 - 1)
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & (2**64 - 1)
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & (2**64 - 1)
    return (z ^ (z >> 31)) & (2**64 - 1), x


def row_grads(step, row, params):
    """Gradient partial of one batch row: a deterministic f32 function of
    (step, row) plus a small pull toward the current parameters, so the
    trajectory actually depends on the updates (divergence would show)."""
    out = []
    s = (step * 1315423911 + row * 2654435761) & (2**64 - 1)
    for b in range(N_BLOCKS):
        g = np.empty(NUMELS[b], dtype=F32)
        for i in range(NUMELS[b]):
            v, s = splitmix(s)
            g[i] = F32((v % 20011) / 10005.5 - 1.0)
        out.append((g + F32(0.05) * params[b]).astype(F32))
    return out


def row_loss(step, row):
    v, _ = splitmix((step * 40503 + row) & (2**64 - 1))
    return F32(2.0 + (v % 1009) / 1009.0)


def row_count(step, row):
    return 5 + (step + row) % 3


class AdamW:
    def __init__(self):
        self.m = [np.zeros(d, dtype=F32) for d in NUMELS]
        self.v = [np.zeros(d, dtype=F32) for d in NUMELS]
        self.t = [0] * N_BLOCKS

    def update(self, selected, params, grads):
        one = F32(1.0)
        for b in selected:
            self.t[b] += 1
            t = self.t[b]
            g = grads[b]
            self.m[b] = (B1 * self.m[b] + (one - B1) * g).astype(F32)
            self.v[b] = (B2 * self.v[b] + (one - B2) * g * g).astype(F32)
            mh = (self.m[b] / F32(one - B1 ** F32(t))).astype(F32)
            vh = (self.v[b] / F32(one - B2 ** F32(t))).astype(F32)
            upd = (mh / (np.sqrt(vh) + EPS) + WD * params[b]).astype(F32)
            params[b] = (params[b] - LR * upd).astype(F32)


class Replica:
    """One full training-state replica: params, AdamW, tracker."""

    def __init__(self):
        rng = np.random.default_rng(7)
        self.params = [
            rng.standard_normal(d).astype(F32) * F32(0.1) for d in NUMELS
        ]
        self.opt = AdamW()
        self.last = [0.0] * N_BLOCKS  # tracker.last (f64 norms)

    def record(self, norms):
        self.last = list(norms)

    def record_selected(self, sel, norms):
        for j, b in enumerate(sel):
            self.last[b] = norms[j]


def decide(method, step):
    """strategy.decide: Some(selection) or None (NeedsNorms)."""
    if method == "full":
        return list(range(N_BLOCKS))
    if method == "fixed":
        return [1, 3]
    return None  # topk ranks every step


def choose(method, last):
    assert method == "topk"
    return top_k(last, 2)


def single_worker_step(rep, step, method, clip):
    """train/trainer.rs host-loop step over the toy backward."""
    decided = decide(method, step)
    masked = decided is not None and len(decided) < N_BLOCKS
    rows = [row_grads(step, r, rep.params) for r in range(BATCH)]
    denom = sum(row_count(step, r) for r in range(BATCH))
    loss = loss_from_sum(
        tree_sum_f32([row_loss(step, r) for r in range(BATCH)]), denom
    )
    grad_blocks = decided if masked else list(range(N_BLOCKS))
    # the kernel scales each entry's gradient by 1/denom *before* the
    # cross-entry reduction — that pre-scaling is what lets the shard
    # fold distribute over the tree bit-exactly
    inv = F32(F32(1.0) / F32(denom))
    grads = {
        b: tree_add([(rows[r][b] * inv).astype(F32) for r in range(BATCH)])
        for b in grad_blocks
    }
    # norms/clip gating — trainer.rs lines "masked { if clip }" / "else if"
    if masked:
        if clip is not None:
            norms = [norm_from_sq_f32(block_norm_sq(grads[b])) for b in decided]
            s = clip_scale(clip, norms)
            if s is not None:
                for b in decided:
                    grads[b] = (grads[b] * s).astype(F32)
                norms = [n * float(np.float64(s)) for n in norms]
            rep.record_selected(decided, norms)
    elif decided is None or clip is not None:
        norms = [norm_from_sq_f32(block_norm_sq(grads[b])) for b in range(N_BLOCKS)]
        if clip is not None:
            s = clip_scale(clip, norms)
            if s is not None:
                for b in range(N_BLOCKS):
                    grads[b] = (grads[b] * s).astype(F32)
                norms = [n * float(np.float64(s)) for n in norms]
        rep.record(norms)
    selected = decided if decided is not None else choose(method, rep.last)
    rep.opt.update(selected, rep.params, grads)
    return loss


def sharded_step(coord, workers, n_shards, step, method, clip):
    """train/sharded.rs step_once + worker protocol over the toy backward."""
    per = BATCH // n_shards
    decided = decide(method, step)  # every replica's decide (same RNG)
    masked = decided is not None and len(decided) < N_BLOCKS
    grad_blocks = decided if masked else list(range(N_BLOCKS))

    # workers: shard backward with the globally summed denom
    denom = sum(row_count(step, r) for r in range(BATCH))
    loss_parts, rank_grads = [], []
    for rank in range(n_shards):
        rows = list(range(rank * per, (rank + 1) * per))
        loss_parts.append(
            tree_sum_f32([row_loss(step, r) for r in rows])
        )
        rg = [row_grads(step, r, workers[rank].params) for r in rows]
        inv = F32(F32(1.0) / F32(denom))
        rank_grads.append(
            {
                b: tree_add([(g[b] * inv).astype(F32) for g in rg])
                for b in grad_blocks
            }
        )

    # coordinator: fold rank partials in the same floor-half tree
    loss = loss_from_sum(tree_sum_f32(loss_parts), denom)
    grads = {
        b: tree_add([rank_grads[r][b] for r in range(n_shards)])
        for b in grad_blocks
    }

    # coordinator norms/clip (pre-clip f32 squared norms ride the bcast)
    norms_sq, scale = None, None
    if masked:
        if clip is not None:
            norms_sq = [F32(block_norm_sq(grads[b])) for b in decided]
            norms = [norm_from_sq_f32(sq) for sq in norms_sq]
            scale = clip_scale(clip, norms)
            if scale is not None:
                for b in decided:
                    grads[b] = (grads[b] * scale).astype(F32)
                norms = [n * float(np.float64(scale)) for n in norms]
            coord.record_selected(decided, norms)
    elif decided is None or clip is not None:
        norms_sq = [F32(block_norm_sq(grads[b])) for b in range(N_BLOCKS)]
        norms = [norm_from_sq_f32(sq) for sq in norms_sq]
        if clip is not None:
            scale = clip_scale(clip, norms)
            if scale is not None:
                for b in range(N_BLOCKS):
                    grads[b] = (grads[b] * scale).astype(F32)
                norms = [n * float(np.float64(scale)) for n in norms]
        coord.record(norms)
    selected = decided if decided is not None else choose(method, coord.last)
    coord.opt.update(selected, coord.params, grads)

    # workers: reconstruct tracker from the broadcast, update identically
    for w in workers:
        if norms_sq is not None:
            wn = [norm_from_sq_f32(sq) for sq in norms_sq]
            if scale is not None:
                wn = [n * float(np.float64(scale)) for n in wn]
            if masked:
                w.record_selected(decided, wn)
            else:
                w.record(wn)
        wsel = decided if decided is not None else choose(method, w.last)
        assert wsel == selected, "replica selection diverged"
        w.opt.update(wsel, w.params, {b: grads[b] for b in selected})
    return loss


def run_case(method, clip, label):
    for n_shards in (1, 2, 4):
        oracle = Replica()
        coord = Replica()
        workers = [Replica() for _ in range(n_shards)]
        for step in range(STEPS):
            ls = single_worker_step(oracle, step, method, clip)
            ld = sharded_step(coord, workers, n_shards, step, method, clip)
            assert bits(ls) == bits(ld), (
                f"{label}/x{n_shards}: loss bits diverged at step {step}: {ls} vs {ld}"
            )
            for b in range(N_BLOCKS):
                assert arr_bits(coord.params[b]) == arr_bits(oracle.params[b]), (
                    f"{label}/x{n_shards}: coordinator block {b} diverged at step {step}"
                )
                for r, w in enumerate(workers):
                    assert arr_bits(w.params[b]) == arr_bits(oracle.params[b]), (
                        f"{label}/x{n_shards}: worker {r} block {b} diverged at step {step}"
                    )
    print(f"  {label}: loss + coordinator + worker params bit-match "
          f"the single worker over {STEPS} steps x shards (1,2,4)")


def check_tree_alignment():
    """Raw reduction property at many (B, n) shapes, f32-exact."""
    rng = np.random.default_rng(3)
    for B in (4, 6, 8, 12, 16, 24):
        xs = rng.uniform(-1, 1, B).astype(F32)
        full = tree_sum_f32(list(xs))
        vecs = [rng.uniform(-1, 1, 11).astype(F32) for _ in range(B)]
        vfull = tree_add(vecs)
        for n in (1, 2, 4, 8):
            if B % n:
                continue
            per = B // n
            parts = [tree_sum_f32(list(xs[r * per:(r + 1) * per])) for r in range(n)]
            assert bits(tree_sum_f32(parts)) == bits(full), (B, n)
            vparts = [tree_add(vecs[r * per:(r + 1) * per]) for r in range(n)]
            assert arr_bits(tree_add(vparts)) == arr_bits(vfull), (B, n)
    print("  tree fold: shard partials == full reduction bitwise over "
          "B in (4,6,8,12,16,24) x pow2 shard counts")


def main():
    print("sharded data-parallel protocol verification (numpy f32):")
    check_tree_alignment()
    run_case("fixed", None, "exploit (masked, no clip)")
    run_case("fixed", 0.5, "masked + clip")
    run_case("topk", None, "top-k explore")
    run_case("topk", 0.5, "top-k explore + clip")
    run_case("full", 0.5, "full fine-tuning + clip")
    print("ALL SHARDED-TRAINER PROTOCOL CHECKS PASSED")


if __name__ == "__main__":
    main()
