#!/usr/bin/env python3
"""Fill EXPERIMENTS.md placeholders from results/*.csv (run after `make exp`)."""

import csv
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
RESULTS = ROOT / "results"
EXP = ROOT / "EXPERIMENTS.md"


def read(name):
    path = RESULTS / name
    if not path.exists():
        return None
    with open(path) as f:
        return list(csv.DictReader(f))


def md_table(rows, cols, fmt=None):
    fmt = fmt or {}
    out = ["| " + " | ".join(cols) + " |", "|" + "---|" * len(cols)]
    for r in rows:
        out.append("| " + " | ".join(fmt.get(c, str)(r[c]) for c in cols) + " |")
    return "\n".join(out)


def pct(x):
    return f"{float(x) * 100:.1f}%"


def fill(text, marker, content):
    if content is None:
        return text
    return text.replace(marker, content)


def main():
    text = EXP.read_text()

    t1 = read("table1_accuracy.csv")
    if t1:
        text = fill(text, "<!-- TABLE1 -->", md_table(
            t1, ["preset", "method", "gsm8k_acc", "math_acc", "tail_loss"],
            {"gsm8k_acc": pct, "math_acc": pct}))

    f1 = read("fig1_time_vs_memory.csv")
    if f1:
        text = fill(text, "<!-- FIG1 -->", md_table(
            f1, ["method", "sim_time_s", "wallclock_s", "gpu_mem_total_mb",
                 "gpu_mem_optimizer_mb", "opt_vram_avg_mb", "pcie_stall_s"]))

    f3 = read("fig3_accuracy_vs_pct.csv")
    if f3:
        text = fill(text, "<!-- FIG3 -->", md_table(
            f3, ["pct", "gsm8k_acc", "math_acc", "tail_loss"],
            {"gsm8k_acc": pct, "math_acc": pct}))

    f4 = read("fig4_loss_convergence.csv")
    if f4:
        # final-20-step mean per method
        per = {}
        for r in f4:
            per.setdefault(r["method"], []).append(float(r["loss"]))
        rows = [
            {"method": m, "first loss": f"{ls[0]:.3f}",
             "final-20 mean": f"{sum(ls[-20:]) / len(ls[-20:]):.3f}"}
            for m, ls in per.items()
        ]
        text = fill(text, "<!-- FIG4 -->",
                    md_table(rows, ["method", "first loss", "final-20 mean"]))

    ab = read("ablations.csv")
    if ab:
        text = fill(text, "<!-- ABLATIONS -->", md_table(
            ab, ["variant", "gsm8k_acc", "math_acc", "tail_loss", "explore_steps"],
            {"gsm8k_acc": pct, "math_acc": pct}))

    EXP.write_text(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    sys.exit(main())
