"""Generate the backend-parity golden fixtures from the JAX reference.

Produces ``rust/tests/fixtures/golden_test_tiny.json``, consumed by
``rust/tests/backend_parity.rs``:

* a 24-step full-fine-tuning loss trajectory of the ``test-tiny`` preset,
  computed with the L2 JAX model (``python/compile/model.py``, i.e. the
  ``kernels/ref.py`` semantics) + the reference AdamW update — the
  pure-Rust backend must reproduce it to 1e-4;
* step-0 per-block gradient L2 norms (same tolerance, relative);
* expected block selections for ``TopKSelector`` and ``AdaGradSelect``
  on fixed gradient-norm inputs, from a bit-exact Python port of the
  coordinator's xoshiro256++/Dirichlet/E-S sampling stack.

Initial parameters come from a bit-exact port of the Rust
``ModelState::init`` (xoshiro256++ + SplitMix64 + Box–Muller), so both
sides start from the same f32 weights.

Run from the repo root: ``python3 scripts/gen_golden.py``
"""

from __future__ import annotations

import json
import math
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "python"))

import jax
import jax.numpy as jnp

from compile import model, presets  # noqa: E402

F = np.float32
M64 = (1 << 64) - 1
MIN_POSITIVE = 2.2250738585072014e-308  # f64::MIN_POSITIVE


# ---------------------------------------------------------------------------
# bit-exact port of rust/src/util/rng.rs + selection/sampling.rs
# ---------------------------------------------------------------------------


class Rng:
    """xoshiro256++ with SplitMix64 seeding (mirrors util::rng::Rng)."""

    def __init__(self, seed: int):
        x = seed & M64
        s = []
        for _ in range(4):
            x = (x + 0x9E3779B97F4A7C15) & M64
            z = x
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self) -> int:
        s = self.s
        x = (s[0] + s[3]) & M64
        result = (((x << 23) | (x >> 41)) & M64) + s[0] & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = ((s[3] << 45) | (s[3] >> 19)) & M64
        return result

    def gen_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def gen_range_f64(self, lo: float, hi: float) -> float:
        return lo + self.gen_f64() * (hi - lo)

    def gen_bool(self, p: float) -> bool:
        return self.gen_f64() < p


def standard_normal(rng: Rng) -> float:
    u1 = rng.gen_range_f64(MIN_POSITIVE, 1.0)
    u2 = rng.gen_f64()
    return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)


def gamma(shape: float, rng: Rng) -> float:
    assert shape > 0.0
    if shape < 1.0:
        u = rng.gen_range_f64(MIN_POSITIVE, 1.0)
        return gamma(shape + 1.0, rng) * (u ** (1.0 / shape))
    d = shape - 1.0 / 3.0
    c = 1.0 / math.sqrt(9.0 * d)
    while True:
        x = standard_normal(rng)
        t = 1.0 + c * x
        if t <= 0.0:
            continue
        v = t * t * t
        u = rng.gen_range_f64(MIN_POSITIVE, 1.0)
        if math.log(u) < 0.5 * x * x + d - d * v + d * math.log(v):
            return d * v


def sample_dirichlet(alpha, rng: Rng):
    draws = [max(gamma(a, rng), 1e-300) for a in alpha]
    total = sum(draws)
    return [x / total for x in draws]


def wswor(p, k, rng: Rng):
    keyed = []
    for i, w in enumerate(p):
        u = rng.gen_range_f64(1e-12, 1.0)
        key = math.log(u) / w if w > 0.0 else float("-inf")
        keyed.append((key, i))
    keyed.sort(key=lambda kv: -kv[0])
    return sorted(i for _, i in keyed[:k])


def top_k_indices(values, k):
    idx = sorted(range(len(values)), key=lambda i: (-values[i], i))
    return sorted(idx[: min(k, len(values))])


# ---------------------------------------------------------------------------
# bit-exact port of model/state.rs ModelState::init
# ---------------------------------------------------------------------------


def init_flats(blocks, seed: int):
    flats = []
    for bi, b in enumerate(blocks):
        flat = np.zeros(b.numel, F)
        for ti, t in enumerate(b.tensors):
            if t.init == "ones":
                flat[t.offset : t.offset + t.numel] = 1.0
            elif t.init == "zeros":
                pass
            elif t.init.startswith("normal:"):
                std = np.float32(float(t.init.split(":", 1)[1]))
                s = (
                    (seed * 0x9E3779B97F4A7C15) & M64
                ) ^ ((bi * 0xD1B54A32D192ED03) & M64) ^ ((ti + 0x12345678) & M64)
                rng = Rng(s)
                vals = np.array(
                    [standard_normal(rng) for _ in range(t.numel)], dtype=F
                )
                flat[t.offset : t.offset + t.numel] = vals * std
            else:
                raise ValueError(t.init)
        flats.append(flat)
    return flats


# ---------------------------------------------------------------------------
# golden trajectory: JAX fwd/bwd + reference AdamW
# ---------------------------------------------------------------------------


def adamw_update(p, g, m, v, lr, t):
    b1, b2, eps, wd = F(0.9), F(0.999), F(1e-8), F(0.01)
    one = F(1.0)
    m = (b1 * m + (one - b1) * g).astype(F)
    v = (b2 * v + (one - b2) * g * g).astype(F)
    m_hat = (m / (one - b1 ** F(t))).astype(F)
    v_hat = (v / (one - b2 ** F(t))).astype(F)
    p = (p - F(lr) * (m_hat / (np.sqrt(v_hat) + eps) + wd * p)).astype(F)
    return p, m, v


def fixture_tokens(cfg, pad_tail=6):
    """Deterministic token/target matrices with a PAD tail per row."""
    rows = cfg.batch * cfg.seq_len
    tokens = [4 + (i * 7) % 50 for i in range(rows)]
    targets = [4 + (i * 11) % 50 for i in range(rows)]
    for r in range(cfg.batch):
        for j in range(cfg.seq_len - pad_tail, cfg.seq_len):
            targets[r * cfg.seq_len + j] = 0
    return tokens, targets


def golden_trajectory(steps=24, lr=1e-3, seed=42):
    cfg = presets.PRESETS["test-tiny"]
    blocks = presets.block_table(cfg)
    flats = init_flats(blocks, seed)
    tokens, targets = fixture_tokens(cfg)
    tok = jnp.asarray(np.array(tokens, np.int32).reshape(cfg.batch, cfg.seq_len))
    tgt = jnp.asarray(np.array(targets, np.int32).reshape(cfg.batch, cfg.seq_len))

    ts, _ = model.make_train_step(cfg, "xla")
    step_fn = jax.jit(ts)

    ms = [np.zeros_like(f) for f in flats]
    vs = [np.zeros_like(f) for f in flats]
    losses = []
    grad_norms0 = []
    for t in range(steps):
        out = step_fn(*[jnp.asarray(f) for f in flats], tok, tgt)
        loss = float(np.asarray(out[0]))
        grads = [np.asarray(g) for g in out[1:]]
        if t == 0:
            grad_norms0 = [
                float(math.sqrt(float(np.sum(g.astype(np.float64) ** 2))))
                for g in grads
            ]
        losses.append(loss)
        for i in range(len(flats)):
            flats[i], ms[i], vs[i] = adamw_update(
                flats[i], grads[i], ms[i], vs[i], lr, t + 1
            )
    return {
        "preset": "test-tiny",
        "seed": seed,
        "steps": steps,
        "lr": lr,
        "tokens": tokens,
        "targets": targets,
        "losses": losses,
        "grad_norms_step0": grad_norms0,
    }


# ---------------------------------------------------------------------------
# selector goldens (ports of selection/{grad_norm,adagrad}.rs)
# ---------------------------------------------------------------------------


def selector_goldens():
    n = 8
    # deterministic norm sequence shared with the Rust test
    norm_seq = [
        [abs(math.sin(0.37 * (step * n + i))) + 0.05 for i in range(n)]
        for step in range(20)
    ]
    topk = [top_k_indices(norms, 3) for norms in norm_seq]

    # AdaGradSelect port: seed, k=3, steps_per_epoch=10, 20 steps (2 epochs)
    seed = 7
    spe = 10
    k = 3
    eps0, delta = 1.0, 1.0
    lam = math.log(100.0) / (spe - 1.0)
    rng = Rng((seed + 0xA6A6) & M64)
    freq = [0] * n
    ags = []
    for step in range(20):
        epoch = 1 + step // spe
        if epoch <= 1:
            t_in = step % spe
            eps = eps0 * math.exp(-lam * t_in)
            if rng.gen_f64() < eps:
                sel = top_k_indices(norm_seq[step], k)
            else:
                alpha = [f + delta for f in freq]
                p = sample_dirichlet(alpha, rng)
                sel = wswor(p, k, rng)
        else:
            alpha = [f + delta for f in freq]
            p = sample_dirichlet(alpha, rng)
            sel = wswor(p, k, rng)
        for b in sel:
            freq[b] += 1
        ags.append(sel)
    return {
        "n_blocks": n,
        "k": k,
        "steps_per_epoch": spe,
        "ags_seed": seed,
        "norms": norm_seq,
        "topk_selected": topk,
        "ags_selected": ags,
    }


def main():
    out_path = os.path.join(
        os.path.dirname(__file__), "..", "rust", "tests", "fixtures",
        "golden_test_tiny.json",
    )
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    fixture = {
        "comment": "generated by scripts/gen_golden.py from the JAX reference",
        "trajectory": golden_trajectory(),
        "selectors": selector_goldens(),
    }
    with open(out_path, "w") as f:
        json.dump(fixture, f, indent=1)
    traj = fixture["trajectory"]
    print(f"wrote {out_path}")
    print(f"losses: {traj['losses'][0]:.6f} -> {traj['losses'][-1]:.6f}")
    print(f"grad norms step0: {[round(x, 4) for x in traj['grad_norms_step0']]}")


if __name__ == "__main__":
    main()
