"""Shared tokenizer specification.

Single source of truth for the char-level vocabulary used by both the
build-time Python side (only for tests) and the Rust coordinator (which
reads the vocab string out of ``artifacts/manifest.json``).  Token ids:

  0 <pad>   1 <bos>   2 <eos>   3 <unk>   4.. one per char of CHARS

The vocabulary is padded to ``VOCAB_SIZE`` (a multiple of 64 keeps the
embedding/e lm-head matmuls lane-aligned on real hardware).
"""

from __future__ import annotations

PAD, BOS, EOS, UNK = 0, 1, 2, 3
CHARS = " 0123456789abcdefghijklmnopqrstuvwxyz+-*/=().,?#:'%$\n"
VOCAB_SIZE = 64

_CHAR_TO_ID = {c: 4 + i for i, c in enumerate(CHARS)}
_ID_TO_CHAR = {4 + i: c for i, c in enumerate(CHARS)}

assert 4 + len(CHARS) <= VOCAB_SIZE


def encode(text: str, *, bos: bool = True, eos: bool = True) -> list[int]:
    ids = [BOS] if bos else []
    ids += [_CHAR_TO_ID.get(c, UNK) for c in text.lower()]
    if eos:
        ids.append(EOS)
    return ids


def decode(ids) -> str:
    return "".join(_ID_TO_CHAR.get(int(i), "") for i in ids)
