"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with plain ``jax.numpy`` ops only.  The pytest suite asserts
``assert_allclose(kernel(...), ref(...))`` across a hypothesis sweep of
shapes/dtypes; the reference is also what the L2 model uses on its
``kernel="xla"`` path (the fast path on CPU PJRT, where Pallas runs in
interpret mode).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, sm_scale: float | None = None):
    """Reference multi-head attention.

    Args:
      q, k, v: ``f32[batch, heads, seq, d_head]``.
      causal: apply a causal (lower-triangular) mask.
      sm_scale: softmax scale; defaults to ``1/sqrt(d_head)``.

    Returns:
      ``f32[batch, heads, seq, d_head]``.
    """
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * sm_scale
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def adamw_ref(p, g, m, v, lr, step, *, b1=0.9, b2=0.999, eps=1e-8, wd=0.01):
    """Reference fused AdamW update on a flat chunk.

    Matches the update AdaGradSelect's custom selective AdamW applies to a
    *selected* block (decoupled weight decay, bias-corrected moments).

    Args:
      p, g, m, v: ``f32[n]`` parameter / gradient / first / second moment.
      lr: scalar learning rate (array or python float).
      step: scalar step count **after** increment (t >= 1).

    Returns:
      ``(p_new, m_new, v_new)``.
    """
    step = jnp.asarray(step, dtype=jnp.float32)
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    m_hat = m_new / (1.0 - b1**step)
    v_hat = v_new / (1.0 - b2**step)
    p_new = p - lr * (m_hat / (jnp.sqrt(v_hat) + eps) + wd * p)
    return p_new, m_new, v_new


def grad_norm_sq_ref(g):
    """Reference blockwise squared-L2 reduction: ``sum(g*g)`` -> f32[]."""
    g = g.astype(jnp.float32)
    return jnp.sum(g * g)
