"""Flash-attention-style fused attention as a Pallas kernel (fwd + bwd).

This is the L1 compute hot-spot of the AdaGradSelect stack: the paper
fine-tunes decoder-only SLMs whose step time is dominated by attention +
MLP matmuls.  The CUDA world expresses the tiled online-softmax schedule
with threadblocks over SRAM tiles; here the same schedule is expressed
with a Pallas grid + ``BlockSpec`` over VMEM tiles (see DESIGN.md
§Hardware-Adaptation):

  * grid = (batch*heads, seq/block_q): one program instance owns one
    ``[block_q, d_head]`` query tile resident in VMEM.
  * K/V for the whole (small) sequence are staged into VMEM per instance;
    the inner ``fori_loop`` walks ``block_k`` tiles performing the online
    softmax (running max ``m``, normalizer ``l``, accumulator ``acc``) —
    the classic flash-attention recurrence.
  * matmuls accumulate in f32 and are shaped as ``[block_q, d] x [d,
    block_k]`` — multiples of the MXU 128x128 tile once block sizes are
    128 on real TPU; on CPU PJRT we run ``interpret=True`` so the kernel
    lowers to plain HLO and the same artifact executes everywhere.

VMEM footprint per instance (f32):
  q tile  block_q*d + k,v  2*seq*d + acc block_q*d + stats 2*block_q
  = (2*block_q + 2*seq)*d + 2*block_q floats; for seq=128, d=32,
  block_q=32 this is ~13 KiB — far under the ~16 MiB VMEM budget, leaving
  room to scale seq to 2k/d to 128 on real hardware.

The backward pass uses the standard recomputation scheme (Dao et al.):
the forward saves only ``o`` and the row logsumexp ``lse``; backward
recomputes P tiles and produces dq (one kernel, grid over q tiles) and
dk/dv (one kernel, grid over k tiles).  ``jax.custom_vjp`` wires both
into the L2 model so ``jax.grad`` of the whole transformer flows through
the Pallas kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, sm_scale, block_k, causal):
    """One program instance: one [block_q, d] query tile vs all K/V tiles."""
    block_q, d = q_ref.shape
    seq = k_ref.shape[0]
    q_idx = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * sm_scale

    q_pos = q_idx * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    def body(kb, carry):
        acc, m_i, l_i = carry
        k_tile = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v_tile = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        s = q @ k_tile.astype(jnp.float32).T  # [block_q, block_k]
        if causal:
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v_tile.astype(jnp.float32)
        return acc, m_new, l_new

    n_kb = seq // block_k
    if causal:
        # tiles strictly above the diagonal contribute nothing; skip them.
        n_kb = (q_idx + 1) * block_q // block_k
        n_kb = jnp.maximum(n_kb, 1)

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, n_kb, body, (acc0, m0, l0))

    o_ref[...] = (acc / l_i[:, None]).astype(o_ref.dtype)
    lse_ref[...] = m_i + jnp.log(l_i)


def _fwd(q, k, v, *, causal, sm_scale, block_q, block_k, interpret):
    b, h, s, d = q.shape
    bh = b * h
    qf = q.reshape(bh, s, d)
    kf = k.reshape(bh, s, d)
    vf = v.reshape(bh, s, d)
    grid = (bh, s // block_q)
    kernel = functools.partial(
        _fwd_kernel, sm_scale=sm_scale, block_k=block_k, causal=causal
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, block_q), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), q.dtype),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return o.reshape(b, h, s, d), lse.reshape(b, h, s)


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------


def _bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *, sm_scale, block_k, causal
):
    block_q, d = q_ref.shape
    seq = k_ref.shape[0]
    q_idx = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    lse = lse_ref[...]
    delta = delta_ref[...]
    q_pos = q_idx * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    def body(kb, dq):
        k_tile = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v_tile = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        s = (q @ k_tile.astype(jnp.float32).T) * sm_scale
        if causal:
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = do @ v_tile.astype(jnp.float32).T
        ds = p * (dp - delta[:, None]) * sm_scale
        return dq + ds @ k_tile.astype(jnp.float32)

    n_kb = seq // block_k
    if causal:
        n_kb = jnp.maximum((q_idx + 1) * block_q // block_k, 1)
    dq = jax.lax.fori_loop(0, n_kb, body, jnp.zeros((block_q, d), jnp.float32))
    dq_ref[...] = dq.astype(dq_ref.dtype)


def _bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref, *, sm_scale, block_q, causal
):
    block_k, d = k_ref.shape
    seq = q_ref.shape[0]
    k_idx = pl.program_id(1)
    k_tile = k_ref[...].astype(jnp.float32)
    v_tile = v_ref[...].astype(jnp.float32)
    k_pos = k_idx * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    def body(qb, carry):
        dk, dv = carry
        q = pl.load(q_ref, (pl.dslice(qb * block_q, block_q), slice(None))).astype(
            jnp.float32
        )
        do = pl.load(do_ref, (pl.dslice(qb * block_q, block_q), slice(None))).astype(
            jnp.float32
        )
        lse = pl.load(lse_ref, (pl.dslice(qb * block_q, block_q),))
        delta = pl.load(delta_ref, (pl.dslice(qb * block_q, block_q),))
        s = (q @ k_tile.T) * sm_scale
        if causal:
            q_pos = qb * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # [block_q, block_k]
        dv = dv + p.T @ do
        dp = do @ v_tile.T
        ds = p * (dp - delta[:, None]) * sm_scale
        dk = dk + ds.T @ q
        return dk, dv

    n_qb = seq // block_q
    start = 0
    if causal:
        # q tiles strictly before this k tile's diagonal contribute nothing.
        start = (k_idx * block_k) // block_q

    zeros = jnp.zeros((block_k, d), jnp.float32)
    dk, dv = jax.lax.fori_loop(start, n_qb, body, (zeros, zeros))
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _bwd(causal, sm_scale, block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    b, h, s, d = q.shape
    bh = b * h
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)  # [b,h,s]

    qf, kf, vf = (x.reshape(bh, s, d) for x in (q, k, v))
    dof = do.reshape(bh, s, d)
    lsef = lse.reshape(bh, s)
    deltaf = delta.reshape(bh, s)

    dq = pl.pallas_call(
        functools.partial(
            _bwd_dq_kernel, sm_scale=sm_scale, block_k=block_k, causal=causal
        ),
        grid=(bh, s // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, block_q), lambda i, j: (i, j)),
            pl.BlockSpec((None, block_q), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, deltaf)

    dk, dv = pl.pallas_call(
        functools.partial(
            _bwd_dkv_kernel, sm_scale=sm_scale, block_q=block_q, causal=causal
        ),
        grid=(bh, s // block_k),
        in_specs=[
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, block_k, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, block_k, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, s), lambda i, j: (i, 0)),
            pl.BlockSpec((None, s), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_k, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, block_k, d), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), q.dtype),
            jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        ],
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, deltaf)

    unflat = lambda x: x.reshape(b, h, s, d)
    return unflat(dq), unflat(dk), unflat(dv)


# ---------------------------------------------------------------------------
# public api
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(
    q,
    k,
    v,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 32,
    block_k: int = 32,
    interpret: bool = True,
):
    """Fused causal attention via Pallas; differentiable (custom VJP).

    Shapes: q, k, v ``f32[batch, heads, seq, d_head]`` with ``seq`` a
    multiple of ``block_q`` and ``block_k``.  ``interpret=True`` is
    mandatory on CPU PJRT (Mosaic custom-calls only run on real TPUs).
    """
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    o, _ = _fwd(
        q, k, v, causal=causal, sm_scale=sm_scale, block_q=block_q,
        block_k=block_k, interpret=interpret,
    )
    return o


def _vjp_fwd(q, k, v, causal, sm_scale, block_q, block_k, interpret):
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    o, lse = _fwd(
        q, k, v, causal=causal, sm_scale=sm_scale, block_q=block_q,
        block_k=block_k, interpret=interpret,
    )
    return o, (q, k, v, o, lse)


def _vjp_bwd(causal, sm_scale, block_q, block_k, interpret, res, do):
    if sm_scale is None:
        sm_scale = 1.0 / (res[0].shape[-1] ** 0.5)
    return _bwd(causal, sm_scale, block_q, block_k, interpret, res, do)


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
