"""Blockwise squared-L2 gradient-norm reduction as a Pallas kernel.

Algorithm 1 (Gradient-Guided Block Selection) ranks blocks by the L2 norm
of their gradients.  The coordinator accumulates ``sum(g*g)`` per block;
this kernel computes one chunk's partial sum as a tree reduction over a
VMEM-resident tile (VPU work; HBM-bandwidth bound — one read per element).

Exported standalone as ``grad_norm_sq.hlo.txt``; parity-tested against the
Rust native reduction used on the hot path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _norm_kernel(g_ref, o_ref):
    i = pl.program_id(0)
    g = g_ref[...].astype(jnp.float32)
    part = jnp.sum(g * g)

    @pl.when(i == 0)
    def _init():
        o_ref[0] = 0.0

    o_ref[0] += part


def grad_norm_sq(g, *, block: int = 65536, interpret: bool = True):
    """``sum(g*g)`` over a flat vector -> ``f32[1]``.

    Accumulates one VMEM tile per grid step into a single output cell
    (sequential grid ⇒ the read-modify-write is race-free).
    """
    (n,) = g.shape
    if n % block == 0 and n > block:
        grid = (n // block,)
        spec = pl.BlockSpec((block,), lambda i: (i,))
    else:
        grid = (1,)
        spec = pl.BlockSpec((n,), lambda i: (0,))
    return pl.pallas_call(
        _norm_kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=interpret,
    )(g)
