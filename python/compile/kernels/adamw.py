"""Fused AdamW chunk update as a Pallas kernel.

AdaGradSelect's custom selective AdamW (paper §3.3) touches only the
parameters of the selected blocks each step.  The L3 coordinator stores
each block as one flat f32 vector; updates stream through this kernel in
fixed ``CHUNK``-sized pieces (64Ki elements = 8x128-lane friendly, pure
VPU element-wise work — a single pass over p/g/m/v at HBM roofline on
real hardware).

The kernel is deliberately single-pass: m, v, bias correction, decoupled
weight decay and the parameter write all happen on one VMEM-resident
tile, so each selected parameter costs exactly 4 HBM reads + 3 writes.

Exported standalone as ``adamw_update.hlo.txt`` (one executable reused
for every block of every preset); the Rust hot path also has a native
implementation — the two are parity-tested from Rust.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 65536

# AdamW hyperparameters are baked at trace time; lr and step stay dynamic
# (the coordinator anneals lr and owns per-block step counts).
B1 = 0.9
B2 = 0.999
EPS = 1e-8
WD = 0.01


def _adamw_kernel(p_ref, g_ref, m_ref, v_ref, lr_ref, step_ref, po_ref, mo_ref, vo_ref,
                  *, b1, b2, eps, wd):
    p = p_ref[...]
    g = g_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    lr = lr_ref[0]
    step = step_ref[0]
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    m_hat = m_new / (1.0 - b1**step)
    v_hat = v_new / (1.0 - b2**step)
    po_ref[...] = p - lr * (m_hat / (jnp.sqrt(v_hat) + eps) + wd * p)
    mo_ref[...] = m_new
    vo_ref[...] = v_new


def adamw_update(p, g, m, v, lr, step, *, b1=B1, b2=B2, eps=EPS, wd=WD,
                 interpret: bool = True):
    """Fused AdamW on a flat f32 chunk.

    Args:
      p, g, m, v: ``f32[n]`` (any n; one grid step per CHUNK when n is a
        CHUNK multiple, else a single whole-array block).
      lr: ``f32[1]`` learning rate.
      step: ``f32[1]`` post-increment step count (t >= 1) for bias
        correction.

    Returns:
      ``(p_new, m_new, v_new)`` each ``f32[n]``.
    """
    (n,) = p.shape
    lr = jnp.asarray(lr, jnp.float32).reshape(1)
    step = jnp.asarray(step, jnp.float32).reshape(1)
    if n % CHUNK == 0 and n > CHUNK:
        grid = (n // CHUNK,)
        vec = pl.BlockSpec((CHUNK,), lambda i: (i,))
        scalar = pl.BlockSpec((1,), lambda i: (0,))
    else:
        grid = (1,)
        vec = pl.BlockSpec((n,), lambda i: (0,))
        scalar = pl.BlockSpec((1,), lambda i: (0,))
    kernel = functools.partial(_adamw_kernel, b1=b1, b2=b2, eps=eps, wd=wd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[vec, vec, vec, vec, scalar, scalar],
        out_specs=[vec, vec, vec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=interpret,
    )(p, g, m, v, lr, step)
