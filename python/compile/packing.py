"""Block-flat parameter packing.

The paper treats the model as a list of *blocks*: the embedding is a
block, each transformer layer is a block, and the final norm (+ LM head,
which we keep untied so the tail block carries real parameters) is a
block.  AdaGradSelect selects, updates and tracks gradient norms at block
granularity, so the whole Rust<->HLO interface is **one flat f32 vector
per block**: the coordinator never needs to know tensor shapes, and grad
norms / AdamW / residency all operate on contiguous slices.

This module defines the layout (tensor name, shape, init spec, offset
inside the flat vector) and the pack/unpack helpers used at trace time.
Offsets are static, so ``unpack`` lowers to free slices/reshapes in HLO.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class TensorSpec:
    name: str
    shape: tuple[int, ...]
    init: str  # "normal:<std>" | "ones" | "zeros"
    offset: int = 0

    @property
    def numel(self) -> int:
        return int(np.prod(self.shape))


@dataclass
class BlockSpec:
    """One paper-"block": a named list of tensors packed into a flat vector."""

    name: str
    tensors: list[TensorSpec] = field(default_factory=list)

    def add(self, name: str, shape: tuple[int, ...], init: str) -> None:
        off = self.numel
        self.tensors.append(TensorSpec(name, tuple(shape), init, off))

    @property
    def numel(self) -> int:
        return sum(t.numel for t in self.tensors)

    def unpack(self, flat):
        """flat f32[numel] -> dict name -> shaped array (static slices)."""
        out = {}
        for t in self.tensors:
            out[t.name] = jnp.reshape(
                jnp.asarray(flat)[t.offset : t.offset + t.numel], t.shape
            )
        return out

    def init_flat(self, rng: np.random.Generator) -> np.ndarray:
        """Numpy init following each tensor's init spec (tests only; the
        Rust coordinator has an equivalent seeded initializer)."""
        parts = []
        for t in self.tensors:
            if t.init == "ones":
                parts.append(np.ones(t.numel, np.float32))
            elif t.init == "zeros":
                parts.append(np.zeros(t.numel, np.float32))
            elif t.init.startswith("normal:"):
                std = float(t.init.split(":")[1])
                parts.append(rng.normal(0.0, std, t.numel).astype(np.float32))
            else:
                raise ValueError(f"unknown init {t.init}")
        return np.concatenate(parts) if parts else np.zeros(0, np.float32)

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "numel": self.numel,
            "tensors": [
                {
                    "name": t.name,
                    "shape": list(t.shape),
                    "init": t.init,
                    "offset": t.offset,
                }
                for t in self.tensors
            ],
        }
