"""Model presets mirroring the paper's three SLM families (scaled down).

The paper fine-tunes Qwen2.5-0.5B (25 transformer blocks), LLaMA3.2-1B
(18 blocks, per the paper) and Phi4-mini-3.8B (32 blocks).  Selection
behaviour depends on *block count* and the relative per-block gradient
signal, not on absolute width, so each sim preset keeps the paper's block
count and scales width to what a CPU PJRT box trains in minutes
(DESIGN.md §2 documents the substitution).

``test-tiny`` is the fast preset used by unit/integration tests;
``e2e`` is the larger model used by examples/e2e_train.rs.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict

from .packing import BlockSpec
from . import tokenizer


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int
    seq_len: int
    batch: int
    lora_rank: int  # "r=128-equivalent" scaled rank; r2 = 2*lora_rank is r=256-eq
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    init_std: float = 0.02

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def to_json(self) -> dict:
        d = asdict(self)
        d["d_head"] = self.d_head
        return d


# Attention projections adapted by LoRA in the paper: Q, K, V, O, plus the
# SwiGLU Up / Down / Gate — i.e. every weight matrix in a layer.
LORA_PROJS = ("wq", "wk", "wv", "wo", "wg", "wu", "wd")


def block_table(cfg: ModelConfig) -> list[BlockSpec]:
    """The paper's block decomposition: embed | layer 0..L-1 | final norm+head."""
    std = f"normal:{cfg.init_std}"
    # residual-branch output projections get the depth-scaled init
    out_std = f"normal:{cfg.init_std / (2 * cfg.n_layers) ** 0.5}"
    blocks = []

    emb = BlockSpec("embed")
    emb.add("tok_emb", (cfg.vocab, cfg.d_model), std)
    blocks.append(emb)

    for i in range(cfg.n_layers):
        b = BlockSpec(f"layer{i}")
        b.add("ln1", (cfg.d_model,), "ones")
        b.add("wq", (cfg.d_model, cfg.d_model), std)
        b.add("wk", (cfg.d_model, cfg.d_model), std)
        b.add("wv", (cfg.d_model, cfg.d_model), std)
        b.add("wo", (cfg.d_model, cfg.d_model), out_std)
        b.add("ln2", (cfg.d_model,), "ones")
        b.add("wg", (cfg.d_model, cfg.d_ff), std)
        b.add("wu", (cfg.d_model, cfg.d_ff), std)
        b.add("wd", (cfg.d_ff, cfg.d_model), out_std)
        blocks.append(b)

    head = BlockSpec("head")
    head.add("ln_f", (cfg.d_model,), "ones")
    head.add("w_out", (cfg.d_model, cfg.vocab), std)
    blocks.append(head)
    return blocks


def lora_block_table(cfg: ModelConfig, rank: int) -> list[BlockSpec]:
    """One LoRA block per transformer layer (adapters for all projections).

    W' = W + (alpha/rank) * A @ B with A:(in, r) ~ N(0, 1/r), B:(r, out) = 0,
    alpha = 2*rank (so the scale is the constant 2, standard practice).
    """
    dims = {
        "wq": (cfg.d_model, cfg.d_model),
        "wk": (cfg.d_model, cfg.d_model),
        "wv": (cfg.d_model, cfg.d_model),
        "wo": (cfg.d_model, cfg.d_model),
        "wg": (cfg.d_model, cfg.d_ff),
        "wu": (cfg.d_model, cfg.d_ff),
        "wd": (cfg.d_ff, cfg.d_model),
    }
    a_std = f"normal:{1.0 / rank ** 0.5}"
    blocks = []
    for i in range(cfg.n_layers):
        b = BlockSpec(f"lora{i}")
        for proj in LORA_PROJS:
            d_in, d_out = dims[proj]
            b.add(f"{proj}_a", (d_in, rank), a_std)
            b.add(f"{proj}_b", (rank, d_out), "zeros")
        blocks.append(b)
    return blocks


V = tokenizer.VOCAB_SIZE

PRESETS: dict[str, ModelConfig] = {
    # unit/integration-test preset: compiles + runs in well under a second
    "test-tiny": ModelConfig("test-tiny", d_model=32, n_layers=2, n_heads=2,
                             d_ff=96, vocab=V, seq_len=64, batch=4, lora_rank=4),
    # Qwen2.5-0.5B stand-in: 25 transformer blocks (paper: 10% => 2 blocks).
    # Widths are sized for the single-core CPU PJRT substrate (see
    # DESIGN.md §2) — block count, not width, drives selection behaviour.
    "qwen-sim": ModelConfig("qwen-sim", d_model=64, n_layers=25, n_heads=4,
                            d_ff=176, vocab=V, seq_len=128, batch=8, lora_rank=8),
    # LLaMA3.2-1B stand-in: 18 blocks (paper: 10% => a single block)
    "llama-sim": ModelConfig("llama-sim", d_model=80, n_layers=18, n_heads=4,
                             d_ff=216, vocab=V, seq_len=128, batch=8, lora_rank=10),
    # Phi4-mini-3.8B stand-in: 32 blocks
    "phi-sim": ModelConfig("phi-sim", d_model=96, n_layers=32, n_heads=4,
                           d_ff=256, vocab=V, seq_len=128, batch=8, lora_rank=12),
    # end-to-end example model (examples/e2e_train.rs): the largest model
    # this box trains in minutes
    "e2e": ModelConfig("e2e", d_model=160, n_layers=8, n_heads=5,
                       d_ff=432, vocab=V, seq_len=128, batch=8, lora_rank=20),
}

# presets that additionally export the Pallas-attention train_step variant
PALLAS_PRESETS = ("test-tiny", "qwen-sim")


def total_params(cfg: ModelConfig) -> int:
    return sum(b.numel for b in block_table(cfg))
