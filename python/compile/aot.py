"""AOT compile path: lower every L2 entrypoint to HLO text + manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the Rust ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly.

Run once via ``make artifacts``; the Rust binary is self-contained
afterwards.  Outputs in ``artifacts/``:

  {preset}_train_step.hlo.txt         loss + per-block grads (XLA attention)
  {preset}_train_step_pallas.hlo.txt  same through the Pallas kernel
  {preset}_train_step_lora.hlo.txt    loss + LoRA-adapter grads (r = preset rank)
  {preset}_train_step_lora2.hlo.txt   same at rank*2 (the paper's r=256 analogue)
  {preset}_eval_loss.hlo.txt          loss only
  {preset}_decode_step.hlo.txt        full logits for greedy decoding
  {preset}_lora_merge.hlo.txt         W += scale*A@B per layer (rank)
  {preset}_lora_merge2.hlo.txt        merge at rank*2
  adamw_update.hlo.txt                fused Pallas AdamW on a 64Ki chunk
  grad_norm_sq.hlo.txt                Pallas sum(g^2) on a 64Ki chunk
  manifest.json                       block tables, shapes, entrypoints
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, presets
from .kernels import adamw as adamw_kernel
from .kernels import grad_norm as grad_norm_kernel
from . import tokenizer

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(fn, specs, out_path: str) -> dict:
    t0 = time.time()
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return {
        "file": os.path.basename(out_path),
        "n_inputs": len(specs),
        "bytes": len(text),
        "lower_s": round(time.time() - t0, 2),
    }


def flat_specs(blocks):
    return [jax.ShapeDtypeStruct((b.numel,), F32) for b in blocks]


def export_preset(cfg: presets.ModelConfig, outdir: str, verbose: bool = True) -> dict:
    b, s = cfg.batch, cfg.seq_len
    tok = jax.ShapeDtypeStruct((b, s), I32)
    entry: dict = {}

    def log(tag, info):
        entry[tag] = info
        if verbose:
            print(f"  {cfg.name}/{tag}: {info['bytes']/1e6:.2f} MB "
                  f"({info['lower_s']}s lower)", flush=True)

    ts, blocks = model.make_train_step(cfg, "xla")
    log("train_step", export(ts, flat_specs(blocks) + [tok, tok],
                             f"{outdir}/{cfg.name}_train_step.hlo.txt"))

    if cfg.name in presets.PALLAS_PRESETS:
        tsp, _ = model.make_train_step(cfg, "pallas")
        log("train_step_pallas", export(tsp, flat_specs(blocks) + [tok, tok],
                                        f"{outdir}/{cfg.name}_train_step_pallas.hlo.txt"))

    for suffix, rank in (("", cfg.lora_rank), ("2", cfg.lora_rank * 2)):
        lts, _, lblocks = model.make_lora_train_step(cfg, rank, "xla")
        log(f"train_step_lora{suffix}",
            export(lts, flat_specs(blocks) + flat_specs(lblocks) + [tok, tok],
                   f"{outdir}/{cfg.name}_train_step_lora{suffix}.hlo.txt"))
        mg, layer_spec, lora_spec = model.make_lora_merge(cfg, rank)
        log(f"lora_merge{suffix}",
            export(mg, [jax.ShapeDtypeStruct((layer_spec.numel,), F32),
                        jax.ShapeDtypeStruct((lora_spec.numel,), F32)],
                   f"{outdir}/{cfg.name}_lora_merge{suffix}.hlo.txt"))

    ev, _ = model.make_eval_loss(cfg, "xla")
    log("eval_loss", export(ev, flat_specs(blocks) + [tok, tok],
                            f"{outdir}/{cfg.name}_eval_loss.hlo.txt"))

    dc, _ = model.make_decode_step(cfg, "xla")
    log("decode_step", export(dc, flat_specs(blocks) + [tok],
                              f"{outdir}/{cfg.name}_decode_step.hlo.txt"))

    lblocks = presets.lora_block_table(cfg, cfg.lora_rank)
    lblocks2 = presets.lora_block_table(cfg, cfg.lora_rank * 2)
    return {
        "model": cfg.to_json(),
        "blocks": [bl.to_json() for bl in presets.block_table(cfg)],
        "lora_blocks": [bl.to_json() for bl in lblocks],
        "lora_blocks2": [bl.to_json() for bl in lblocks2],
        "total_params": presets.total_params(cfg),
        "artifacts": entry,
    }


def export_shared(outdir: str) -> dict:
    c = adamw_kernel.CHUNK
    vec = jax.ShapeDtypeStruct((c,), F32)
    one = jax.ShapeDtypeStruct((1,), F32)

    def adamw_fn(p, g, m, v, lr, step):
        return adamw_kernel.adamw_update(p, g, m, v, lr, step)

    def norm_fn(g):
        return (grad_norm_kernel.grad_norm_sq(g),)

    out = {}
    out["adamw_update"] = export(adamw_fn, [vec] * 4 + [one, one],
                                 f"{outdir}/adamw_update.hlo.txt")
    out["grad_norm_sq"] = export(norm_fn, [vec], f"{outdir}/grad_norm_sq.hlo.txt")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--presets", default=",".join(presets.PRESETS),
                    help="comma-separated preset names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = [n for n in args.presets.split(",") if n]
    manifest = {
        "version": 1,
        "tokenizer": {
            "chars": tokenizer.CHARS,
            "vocab_size": tokenizer.VOCAB_SIZE,
            "pad": tokenizer.PAD, "bos": tokenizer.BOS,
            "eos": tokenizer.EOS, "unk": tokenizer.UNK,
        },
        "chunk_size": adamw_kernel.CHUNK,
        "adamw": {"b1": adamw_kernel.B1, "b2": adamw_kernel.B2,
                   "eps": adamw_kernel.EPS, "wd": adamw_kernel.WD},
        "shared": export_shared(args.out),
        "presets": {},
    }
    for name in names:
        print(f"preset {name}:", flush=True)
        manifest["presets"][name] = export_preset(presets.PRESETS[name], args.out)

    with open(f"{args.out}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {args.out}/manifest.json ({len(names)} presets)")


if __name__ == "__main__":
    main()
