"""L2: decoder-only transformer fwd/bwd over block-flat parameters.

The architecture mirrors the paper's SLM families (Qwen2.5 / LLaMA3.2 /
Phi4-mini): pre-RMSNorm, rotary attention, SwiGLU MLP, untied LM head.
Every traced entrypoint takes one flat f32 vector per block (see
``packing.py``) so the Rust coordinator stays shape-oblivious, plus i32
token/target matrices, and returns loss and per-block gradients.

Attention runs through either the Pallas flash-attention kernel
(``attn_impl="pallas"``, interpret mode — the artifact that would be the
fast path on real TPUs) or the pure-jnp reference (``attn_impl="xla"`` —
the fast path on CPU PJRT).  Both lower into the same HLO artifact
format; Rust picks which file to load.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention
from .kernels.ref import attention_ref
from .packing import BlockSpec
from .presets import ModelConfig, block_table, lora_block_table, LORA_PROJS
from .tokenizer import PAD


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, theta):
    """Rotary position embedding over [b, h, s, d_head] (d_head even)."""
    b, h, s, d = x.shape
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]  # [s, half]
    cos = jnp.cos(angles)[None, None]
    sin = jnp.sin(angles)[None, None]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(q, k, v, attn_impl):
    if attn_impl == "pallas":
        return flash_attention(q, k, v, True, None, 32, 32, True)
    return attention_ref(q, k, v, causal=True)


def layer_fwd(h, p, cfg: ModelConfig, attn_impl: str, lora=None, lora_scale=0.0):
    """One transformer layer. ``p`` is the unpacked tensor dict; ``lora``
    optionally carries adapter tensors applied as W + s*A@B."""

    def proj(x, name):
        y = x @ p[name]
        if lora is not None:
            y = y + (x @ lora[f"{name}_a"]) @ lora[f"{name}_b"] * lora_scale
        return y

    b, s, d = h.shape
    x = rms_norm(h, p["ln1"], cfg.norm_eps)
    q = proj(x, "wq").reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    k = proj(x, "wk").reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    v = proj(x, "wv").reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    q = rope(q, cfg.rope_theta)
    k = rope(k, cfg.rope_theta)
    o = _attention(q, k, v, attn_impl)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    h = h + proj(o, "wo")

    x = rms_norm(h, p["ln2"], cfg.norm_eps)
    gate = jax.nn.silu(proj(x, "wg"))
    up = proj(x, "wu")
    h = h + proj(gate * up, "wd")
    return h


def forward(cfg: ModelConfig, blocks, flats, tokens, attn_impl="xla",
            lora_blocks=None, lora_flats=None, lora_rank=0):
    """Full forward: flat block vectors + tokens -> logits [b, s, vocab]."""
    emb = blocks[0].unpack(flats[0])
    h = emb["tok_emb"][tokens]
    lora_scale = 2.0  # alpha/r with alpha=2r
    for i in range(cfg.n_layers):
        p = blocks[1 + i].unpack(flats[1 + i])
        lora = None
        if lora_flats is not None:
            lora = lora_blocks[i].unpack(lora_flats[i])
        h = layer_fwd(h, p, cfg, attn_impl, lora=lora, lora_scale=lora_scale)
    head = blocks[-1].unpack(flats[-1])
    h = rms_norm(h, head["ln_f"], cfg.norm_eps)
    return h @ head["w_out"]


def masked_ce_loss(logits, targets):
    """Mean cross-entropy over non-pad target positions."""
    mask = (targets != PAD).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# traced entrypoints (AOT-exported by aot.py)
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, attn_impl: str = "xla"):
    """(flat_0..flat_n, tokens, targets) -> (loss, grad_0..grad_n)."""
    blocks = block_table(cfg)
    n = len(blocks)

    def loss_fn(flats, tokens, targets):
        logits = forward(cfg, blocks, flats, tokens, attn_impl)
        return masked_ce_loss(logits, targets)

    def train_step(*args):
        flats = list(args[:n])
        tokens, targets = args[n], args[n + 1]
        loss, grads = jax.value_and_grad(loss_fn)(flats, tokens, targets)
        return (loss, *grads)

    return train_step, blocks


def make_lora_train_step(cfg: ModelConfig, rank: int, attn_impl: str = "xla"):
    """(base_0..base_n, lora_0..lora_L-1, tokens, targets) -> (loss, lora_grads...).

    Base blocks are frozen: no gradients are computed or emitted for them —
    exactly the LoRA training regime the paper benchmarks against."""
    blocks = block_table(cfg)
    lblocks = lora_block_table(cfg, rank)
    n, nl = len(blocks), len(lblocks)

    def loss_fn(lora_flats, base_flats, tokens, targets):
        logits = forward(cfg, blocks, base_flats, tokens, attn_impl,
                         lora_blocks=lblocks, lora_flats=lora_flats, lora_rank=rank)
        return masked_ce_loss(logits, targets)

    def train_step(*args):
        base = list(args[:n])
        lora = list(args[n : n + nl])
        tokens, targets = args[n + nl], args[n + nl + 1]
        loss, grads = jax.value_and_grad(loss_fn)(lora, base, tokens, targets)
        return (loss, *grads)

    return train_step, blocks, lblocks


def make_eval_loss(cfg: ModelConfig, attn_impl: str = "xla"):
    """(flat_0..flat_n, tokens, targets) -> loss (no gradients)."""
    blocks = block_table(cfg)
    n = len(blocks)

    def eval_loss(*args):
        flats = list(args[:n])
        tokens, targets = args[n], args[n + 1]
        logits = forward(cfg, blocks, flats, tokens, attn_impl)
        return (masked_ce_loss(logits, targets),)

    return eval_loss, blocks


def make_decode_step(cfg: ModelConfig, attn_impl: str = "xla"):
    """(flat_0..flat_n, tokens) -> logits f32[batch, seq, vocab].

    The Rust greedy decoder indexes the position it cares about; returning
    full logits keeps the artifact general (eval losses, sampling, etc.)."""
    blocks = block_table(cfg)
    n = len(blocks)

    def decode_step(*args):
        flats = list(args[:n])
        tokens = args[n]
        return (forward(cfg, blocks, flats, tokens, attn_impl),)

    return decode_step, blocks


def make_lora_merge(cfg: ModelConfig, rank: int):
    """(layer_flat, lora_flat) -> merged layer_flat (W += scale * A @ B).

    Used at eval time: the coordinator merges adapters into the base layer
    vectors, then reuses the plain decode_step artifact."""
    blocks = block_table(cfg)
    lblocks = lora_block_table(cfg, rank)
    layer_spec: BlockSpec = blocks[1]
    lora_spec: BlockSpec = lblocks[0]
    scale = 2.0

    def merge(layer_flat, lora_flat):
        p = layer_spec.unpack(layer_flat)
        l = lora_spec.unpack(lora_flat)
        pieces = []
        for t in layer_spec.tensors:
            w = p[t.name]
            if t.name in LORA_PROJS:
                w = w + scale * (l[f"{t.name}_a"] @ l[f"{t.name}_b"])
            pieces.append(w.reshape(-1))
        return (jnp.concatenate(pieces),)

    return merge, layer_spec, lora_spec
