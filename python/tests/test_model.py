"""L2 model tests: shapes, packing, loss semantics, LoRA, parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model, presets, tokenizer
from compile.packing import BlockSpec

jax.config.update("jax_platform_name", "cpu")

CFG = presets.PRESETS["test-tiny"]


@pytest.fixture(scope="module")
def flats():
    rng = np.random.default_rng(42)
    return [jnp.asarray(b.init_flat(rng)) for b in presets.block_table(CFG)]


def batch(seed=0, cfg=CFG):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(4, 50, (cfg.batch, cfg.seq_len)), jnp.int32)
    tgts = jnp.asarray(rng.integers(4, 50, (cfg.batch, cfg.seq_len)), jnp.int32)
    return toks, tgts


class TestPacking:
    def test_offsets_contiguous(self):
        for b in presets.block_table(CFG):
            off = 0
            for t in b.tensors:
                assert t.offset == off
                off += t.numel
            assert b.numel == off

    def test_unpack_roundtrip(self):
        b = presets.block_table(CFG)[1]
        rng = np.random.default_rng(0)
        flat = b.init_flat(rng)
        d = b.unpack(jnp.asarray(flat))
        rebuilt = np.concatenate([np.asarray(d[t.name]).reshape(-1) for t in b.tensors])
        assert_allclose(rebuilt, flat)

    def test_block_count_matches_paper_structure(self):
        # embed + n_layers + head, the paper's block decomposition
        assert len(presets.block_table(CFG)) == CFG.n_layers + 2

    def test_init_spec_honored(self):
        b = presets.block_table(CFG)[1]
        rng = np.random.default_rng(0)
        d = b.unpack(jnp.asarray(b.init_flat(rng)))
        assert_allclose(d["ln1"], np.ones(CFG.d_model))
        assert abs(float(jnp.std(d["wq"])) - CFG.init_std) < 0.01

    def test_layer_blocks_identical_layout(self):
        blocks = presets.block_table(CFG)
        l0, l1 = blocks[1], blocks[2]
        assert [(t.name, t.shape, t.offset) for t in l0.tensors] == [
            (t.name, t.shape, t.offset) for t in l1.tensors
        ]


class TestForward:
    def test_logits_shape(self, flats):
        toks, _ = batch()
        dc, _ = model.make_decode_step(CFG)
        (logits,) = dc(*flats, toks)
        assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
        assert np.isfinite(np.asarray(logits)).all()

    def test_causality(self, flats):
        """Changing a future token must not change past logits."""
        toks, _ = batch()
        dc, _ = model.make_decode_step(CFG)
        (a,) = dc(*flats, toks)
        toks2 = toks.at[:, -1].set((toks[:, -1] % 50) + 4)
        (b,) = dc(*flats, toks2)
        assert_allclose(a[:, :-1], b[:, :-1], atol=1e-5, rtol=1e-5)

    def test_loss_at_init_near_uniform(self, flats):
        toks, tgts = batch()
        ev, _ = model.make_eval_loss(CFG)
        (loss,) = ev(*flats, toks, tgts)
        assert abs(float(loss) - np.log(CFG.vocab)) < 0.5

    def test_pad_targets_masked(self, flats):
        toks, tgts = batch()
        ev, _ = model.make_eval_loss(CFG)
        (full,) = ev(*flats, toks, tgts)
        # padding half the targets changes the denominator, not to nan
        tgts2 = tgts.at[:, ::2].set(tokenizer.PAD)
        (half,) = ev(*flats, toks, tgts2)
        assert np.isfinite(float(half))
        # all-pad: loss must be 0 (guarded denominator), not nan
        (zero,) = ev(*flats, toks, jnp.zeros_like(tgts))
        assert float(zero) == 0.0
        assert np.isfinite(float(full))


class TestTrainStep:
    def test_grad_count_and_shapes(self, flats):
        toks, tgts = batch()
        ts, blocks = model.make_train_step(CFG)
        out = ts(*flats, toks, tgts)
        assert len(out) == 1 + len(blocks)
        for g, b in zip(out[1:], blocks):
            assert g.shape == (b.numel,)

    def test_grads_nonzero_everywhere(self, flats):
        toks, tgts = batch()
        ts, blocks = model.make_train_step(CFG)
        out = ts(*flats, toks, tgts)
        for g, b in zip(out[1:], blocks):
            assert float(jnp.sum(jnp.abs(g))) > 0, b.name

    def test_pallas_parity(self, flats):
        """Pallas-attention artifact computes identical loss and grads."""
        toks, tgts = batch()
        ts_x, _ = model.make_train_step(CFG, "xla")
        ts_p, _ = model.make_train_step(CFG, "pallas")
        ox, op = ts_x(*flats, toks, tgts), ts_p(*flats, toks, tgts)
        assert_allclose(float(ox[0]), float(op[0]), rtol=1e-6)
        for a, b in zip(ox[1:], op[1:]):
            assert_allclose(a, b, atol=1e-6, rtol=1e-5)

    def test_sgd_reduces_loss(self, flats):
        toks, tgts = batch()
        ts, _ = model.make_train_step(CFG)
        f = list(flats)
        first = float(ts(*f, toks, tgts)[0])
        for _ in range(5):
            out = ts(*f, toks, tgts)
            f = [x - 0.5 * g for x, g in zip(f, out[1:])]
        assert float(ts(*f, toks, tgts)[0]) < first - 0.1

    def test_grad_matches_finite_difference(self, flats):
        toks, tgts = batch()
        ts, blocks = model.make_train_step(CFG)
        ev, _ = model.make_eval_loss(CFG)
        out = ts(*flats, toks, tgts)
        g_head = np.asarray(out[-1])
        i = int(np.argmax(np.abs(g_head)))
        eps = 1e-3
        bump = jnp.zeros(blocks[-1].numel).at[i].set(eps)
        f_plus = flats[:-1] + [flats[-1] + bump]
        f_minus = flats[:-1] + [flats[-1] - bump]
        fd = (float(ev(*f_plus, toks, tgts)[0]) - float(ev(*f_minus, toks, tgts)[0])) / (2 * eps)
        assert_allclose(fd, g_head[i], rtol=0.05, atol=1e-4)


class TestLoRA:
    def test_zero_b_means_base_forward(self, flats):
        """With B=0 adapters, LoRA forward == base forward."""
        toks, tgts = batch()
        lts, blocks, lblocks = model.make_lora_train_step(CFG, CFG.lora_rank)
        rng = np.random.default_rng(7)
        lflats = [jnp.asarray(b.init_flat(rng)) for b in lblocks]
        ev, _ = model.make_eval_loss(CFG)
        out = lts(*flats, *lflats, toks, tgts)
        (base_loss,) = ev(*flats, toks, tgts)
        assert_allclose(float(out[0]), float(base_loss), rtol=1e-6)

    def test_lora_grads_only(self, flats):
        toks, tgts = batch()
        lts, blocks, lblocks = model.make_lora_train_step(CFG, CFG.lora_rank)
        rng = np.random.default_rng(7)
        lflats = [jnp.asarray(b.init_flat(rng)) for b in lblocks]
        out = lts(*flats, *lflats, toks, tgts)
        assert len(out) == 1 + len(lblocks)
        for g, b in zip(out[1:], lblocks):
            assert g.shape == (b.numel,)
            assert float(jnp.sum(jnp.abs(g))) > 0

    def test_lora_sgd_reduces_loss(self, flats):
        toks, tgts = batch()
        lts, _, lblocks = model.make_lora_train_step(CFG, CFG.lora_rank)
        rng = np.random.default_rng(7)
        lf = [jnp.asarray(b.init_flat(rng)) for b in lblocks]
        first = float(lts(*flats, *lf, toks, tgts)[0])
        for _ in range(5):
            out = lts(*flats, *lf, toks, tgts)
            lf = [x - 0.5 * g for x, g in zip(lf, out[1:])]
        assert float(lts(*flats, *lf, toks, tgts)[0]) < first

    def test_merge_equivalence(self, flats):
        """decode(merge(base, lora)) == lora-forward logits."""
        toks, tgts = batch()
        rank = CFG.lora_rank
        lts, blocks, lblocks = model.make_lora_train_step(CFG, rank)
        rng = np.random.default_rng(3)
        lf = [jnp.asarray(b.init_flat(rng)) for b in lblocks]
        # train adapters a bit so B != 0
        for _ in range(3):
            out = lts(*flats, *lf, toks, tgts)
            lf = [x - 1.0 * g for x, g in zip(lf, out[1:])]
        merge, _, _ = model.make_lora_merge(CFG, rank)
        merged = list(flats)
        for i in range(CFG.n_layers):
            (merged[1 + i],) = merge(flats[1 + i], lf[i])
        ev, _ = model.make_eval_loss(CFG)
        (merged_loss,) = ev(*merged, toks, tgts)
        lora_loss = float(lts(*flats, *lf, toks, tgts)[0])
        assert_allclose(float(merged_loss), lora_loss, rtol=1e-5)


class TestRope:
    def test_norm_preserved(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 2, 16, 8)), jnp.float32)
        y = model.rope(x, 10000.0)
        assert_allclose(
            jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1), rtol=1e-5
        )

    def test_position_zero_identity(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(1, 1, 4, 8)), jnp.float32)
        y = model.rope(x, 10000.0)
        assert_allclose(y[0, 0, 0], x[0, 0, 0], atol=1e-6)


class TestTokenizer:
    def test_roundtrip(self):
        s = "alice has 3 apples. #### 42\n"
        ids = tokenizer.encode(s)
        assert ids[0] == tokenizer.BOS and ids[-1] == tokenizer.EOS
        assert tokenizer.decode(ids[1:-1]) == s

    def test_unknown_maps_to_unk(self):
        assert tokenizer.encode("~", bos=False, eos=False) == [tokenizer.UNK]

    def test_vocab_fits(self):
        assert 4 + len(tokenizer.CHARS) <= tokenizer.VOCAB_SIZE

    def test_ids_in_range(self):
        ids = tokenizer.encode("9z+ #:'%$\n")
        assert all(0 <= i < tokenizer.VOCAB_SIZE for i in ids)
