"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py.  This is
the CORE correctness signal for the compute layer — everything the Rust
coordinator executes was lowered from these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.adamw import adamw_update
from compile.kernels.attention import flash_attention
from compile.kernels.grad_norm import grad_norm_sq
from compile.kernels.ref import adamw_ref, attention_ref, grad_norm_sq_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype) * scale


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


class TestAttentionForward:
    @pytest.mark.parametrize("b,h,s,d", [(1, 1, 32, 16), (2, 3, 128, 32),
                                         (1, 2, 64, 24), (2, 4, 96, 8)])
    def test_matches_ref_causal(self, b, h, s, d):
        q, k, v = rand(0, (b, h, s, d)), rand(1, (b, h, s, d)), rand(2, (b, h, s, d))
        out = flash_attention(q, k, v)
        ref = attention_ref(q, k, v)
        assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_non_causal(self):
        q, k, v = (rand(i, (2, 2, 64, 16)) for i in range(3))
        out = flash_attention(q, k, v, False)
        ref = attention_ref(q, k, v, causal=False)
        assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_custom_scale(self):
        q, k, v = (rand(i, (1, 2, 64, 16)) for i in range(3))
        out = flash_attention(q, k, v, True, 0.5)
        ref = attention_ref(q, k, v, sm_scale=0.5)
        assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_block_shape_invariance(self):
        """Output must not depend on the tiling schedule."""
        q, k, v = (rand(i, (1, 2, 128, 16)) for i in range(3))
        a = flash_attention(q, k, v, True, None, 32, 32)
        b = flash_attention(q, k, v, True, None, 64, 16)
        assert_allclose(a, b, atol=2e-5, rtol=2e-5)

    def test_large_logits_stable(self):
        """Online softmax must survive large score magnitudes."""
        q = rand(0, (1, 1, 64, 16), scale=30.0)
        k = rand(1, (1, 1, 64, 16), scale=30.0)
        v = rand(2, (1, 1, 64, 16))
        out = flash_attention(q, k, v)
        ref = attention_ref(q, k, v)
        assert np.isfinite(np.asarray(out)).all()
        assert_allclose(out, ref, atol=1e-4, rtol=1e-4)

    def test_causal_first_row_is_v0(self):
        """Row 0 of a causal attention can only attend to position 0."""
        q, k, v = (rand(i, (1, 1, 64, 16)) for i in range(3))
        out = flash_attention(q, k, v)
        assert_allclose(out[0, 0, 0], v[0, 0, 0], atol=1e-5, rtol=1e-5)

    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 2),
        h=st.integers(1, 3),
        s=st.sampled_from([32, 64, 96, 128]),
        d=st.sampled_from([8, 16, 24, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, b, h, s, d, seed):
        q = rand(seed, (b, h, s, d))
        k = rand(seed + 1, (b, h, s, d))
        v = rand(seed + 2, (b, h, s, d))
        assert_allclose(
            flash_attention(q, k, v), attention_ref(q, k, v), atol=3e-5, rtol=3e-5
        )


class TestAttentionBackward:
    def _grads(self, fn, q, k, v):
        return jax.grad(lambda *a: jnp.sum(jnp.tanh(fn(*a))), argnums=(0, 1, 2))(q, k, v)

    @pytest.mark.parametrize("b,h,s,d", [(1, 1, 32, 16), (2, 2, 128, 32), (1, 2, 64, 24)])
    def test_grads_match_ref(self, b, h, s, d):
        q, k, v = rand(0, (b, h, s, d)), rand(1, (b, h, s, d)), rand(2, (b, h, s, d))
        gk = self._grads(lambda q, k, v: flash_attention(q, k, v), q, k, v)
        gr = self._grads(lambda q, k, v: attention_ref(q, k, v), q, k, v)
        for a, b_ in zip(gk, gr):
            assert_allclose(a, b_, atol=5e-5, rtol=5e-5)

    def test_grads_noncausal(self):
        q, k, v = (rand(i, (1, 2, 64, 16)) for i in range(3))
        gk = self._grads(lambda q, k, v: flash_attention(q, k, v, False), q, k, v)
        gr = self._grads(lambda q, k, v: attention_ref(q, k, v, causal=False), q, k, v)
        for a, b_ in zip(gk, gr):
            assert_allclose(a, b_, atol=5e-5, rtol=5e-5)

    def test_grad_under_jit(self):
        q, k, v = (rand(i, (1, 1, 64, 16)) for i in range(3))
        f = jax.jit(jax.grad(lambda q: jnp.sum(flash_attention(q, k, v) ** 2)))
        g = f(q)
        gr = jax.grad(lambda q: jnp.sum(attention_ref(q, k, v) ** 2))(q)
        assert_allclose(g, gr, atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# adamw
# ---------------------------------------------------------------------------


class TestAdamW:
    def _inputs(self, n, seed=0):
        p = rand(seed, (n,))
        g = rand(seed + 1, (n,))
        m = rand(seed + 2, (n,), scale=0.1)
        v = jnp.abs(rand(seed + 3, (n,), scale=0.1))
        return p, g, m, v

    @pytest.mark.parametrize("n", [8, 1000, 65536, 65536 * 2])
    def test_matches_ref(self, n):
        p, g, m, v = self._inputs(n)
        out = adamw_update(p, g, m, v, 1e-3, 5.0)
        ref = adamw_ref(p, g, m, v, 1e-3, 5.0)
        for a, b in zip(out, ref):
            assert_allclose(a, b, atol=1e-6, rtol=1e-6)

    def test_step_one_bias_correction(self):
        """At t=1 with m=v=0 the update direction is -lr*sign(g) (+wd)."""
        n = 64
        p = jnp.zeros((n,))
        g = rand(1, (n,))
        out_p, _, _ = adamw_update(p, g, jnp.zeros((n,)), jnp.zeros((n,)), 0.01, 1.0)
        expected = -0.01 * g / (jnp.abs(g) + 1e-8)
        assert_allclose(out_p, expected, atol=1e-4, rtol=1e-3)

    def test_weight_decay_decoupled(self):
        """Zero gradient still shrinks weights by lr*wd*p."""
        n = 32
        p = rand(0, (n,))
        z = jnp.zeros((n,))
        out_p, out_m, out_v = adamw_update(p, z, z, z, 0.1, 1.0)
        assert_allclose(out_p, p * (1 - 0.1 * 0.01), atol=1e-6, rtol=1e-6)
        assert_allclose(out_m, z, atol=0)
        assert_allclose(out_v, z, atol=0)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.sampled_from([4, 128, 4096, 65536]),
        lr=st.floats(1e-5, 1e-1),
        step=st.integers(1, 10000),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, n, lr, step, seed):
        p, g, m, v = self._inputs(n, seed)
        out = adamw_update(p, g, m, v, lr, float(step))
        ref = adamw_ref(p, g, m, v, lr, float(step))
        for a, b in zip(out, ref):
            assert_allclose(a, b, atol=1e-5, rtol=1e-4)

    def test_moments_are_emas(self):
        p, g, m, v = self._inputs(256)
        _, m2, v2 = adamw_update(p, g, m, v, 1e-3, 3.0)
        assert_allclose(m2, 0.9 * m + 0.1 * g, atol=1e-6, rtol=1e-5)
        assert_allclose(v2, 0.999 * v + 0.001 * g * g, atol=1e-6, rtol=1e-5)


# ---------------------------------------------------------------------------
# grad norm
# ---------------------------------------------------------------------------


class TestGradNorm:
    @pytest.mark.parametrize("n", [4, 1000, 65536, 65536 * 4])
    def test_matches_ref(self, n):
        g = rand(7, (n,))
        assert_allclose(
            grad_norm_sq(g)[0], grad_norm_sq_ref(g), atol=1e-2, rtol=1e-5
        )

    def test_zeros(self):
        assert float(grad_norm_sq(jnp.zeros(128))[0]) == 0.0

    def test_ones(self):
        assert float(grad_norm_sq(jnp.ones(4096))[0]) == 4096.0

    @settings(max_examples=15, deadline=None)
    @given(n=st.sampled_from([16, 512, 65536]), seed=st.integers(0, 2**16),
           scale=st.floats(0.01, 10.0))
    def test_hypothesis_sweep(self, n, seed, scale):
        g = rand(seed, (n,), scale=scale)
        assert_allclose(grad_norm_sq(g)[0], grad_norm_sq_ref(g), rtol=1e-4)
