//! Shadow-state audit contracts (`--features audit` only).
//!
//! Positive half: randomized serve churn — overcommitted pool, mixed
//! priorities, preemption, prefix sharing — with the engine's internal
//! auditors armed on every step, plus a test-side shadow refcount model
//! that must match `KvPool::page_ref` after every transition.
//!
//! Negative half: each auditor is driven to fire on a deliberately
//! corrupted state, proving the validators can actually detect the class
//! of bug they claim to (a validator that never fires is dead weight).

#![cfg(feature = "audit")]

use adagradselect::audit::{check_budget, check_finite, check_kv_pool};
use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, ReferenceBackend};
use adagradselect::serve::{
    KvPool, PrefixCache, Reservation, SamplingParams, ServeConfig, ServeEngine,
};
use adagradselect::util::workspace::Workspace;

const PRESET: &str = "test-tiny";

fn prompt(len: usize, salt: u64) -> Vec<i32> {
    (0..len).map(|i| 4 + ((i as u64 * 7 + salt * 13) % 50) as i32).collect()
}

/// Minimal LCG so the churn trace is deterministic and self-contained.
struct Lcg(u64);
impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }
}

// ---------------------------------------------------------------------
// positive: auditors stay silent through heavy churn
// ---------------------------------------------------------------------

/// Randomized churn with the engine's per-step audit armed: over-
/// committed pages force preemption + prefix-cache parking, shared
/// prompt stems force refcounted pages, and `ServeEngine::step` panics
/// internally if any shadow validator reports drift. The test-side
/// check re-runs `audit_violations()` after every step as well, so a
/// violation is caught even if the internal hook were disarmed.
#[test]
fn serve_churn_under_audit_stays_sound() {
    let backend = ReferenceBackend::new();
    let state = ModelState::init(
        &backend.manifest().preset(PRESET).unwrap().blocks,
        3,
    );
    for &reservation in &[Reservation::Optimistic, Reservation::WorstCase] {
        let mut srv = ServeEngine::new(
            &backend,
            PRESET,
            &state,
            ServeConfig {
                slots: 3,
                max_new_tokens: 8,
                // small page budget: admission overcommits and decode
                // growth forces preemptions mid-run
                kv_pages: 6,
                reservation,
            },
        )
        .unwrap();

        let mut rng = Lcg(0x5EED ^ reservation as u64);
        let mut submitted = 0usize;
        let mut done = 0usize;
        let mut steps = 0usize;
        // a shared stem exercises prefix-cache refcounts on top of the
        // per-slot tables
        let stem = prompt(9, 99);
        while done < 24 && steps < 600 {
            if submitted < 24 && rng.next() % 3 != 0 {
                let mut p = if rng.next() % 2 == 0 { stem.clone() } else { Vec::new() };
                p.extend(prompt(1 + (rng.next() % 11) as usize, submitted as u64));
                let prio = (rng.next() % 3) as u8;
                srv.submit_prio(p, 0, steps as f64, prio, SamplingParams::default());
                submitted += 1;
            }
            done += srv.step().unwrap().len();
            steps += 1;
            let v = srv.audit_violations();
            assert!(
                v.is_empty(),
                "audit violations after step {steps} ({reservation:?}): {v:?}"
            );
        }
        assert_eq!(done, 24, "churn did not drain ({reservation:?})");
        assert_eq!(srv.n_active() + srv.n_pending(), 0);
    }
}

/// Standalone pool churn with a *test-side* shadow refcount model:
/// random alloc / grow / share-via-prefix-cache / release, and after
/// every transition the shadow count (recomputed from slot tables +
/// cache entries) must equal `page_ref` for every page — independently
/// of the `audit::kv` validator, which also runs each round.
#[test]
fn shadow_refcounts_match_pool_through_random_churn() {
    let backend = ReferenceBackend::new();
    let model = backend.manifest().preset(PRESET).unwrap().model.clone();
    let mut pool = KvPool::with_pages(&model, 4, 64, 10);
    let mut cache = PrefixCache::new();
    let mut rng = Lcg(42);
    let mut live: Vec<usize> = Vec::new();

    for round in 0..400 {
        match rng.next() % 4 {
            0 => {
                if let Some(slot) = pool.alloc() {
                    let rows = 1 + (rng.next() % 24) as usize;
                    if pool.ensure_room(slot, rows).is_ok() {
                        pool.set_len(slot, rows);
                        live.push(slot);
                    } else {
                        pool.release(slot);
                    }
                }
            }
            1 => {
                if let Some(&slot) = live.last() {
                    let rows = (pool.len(slot) + 1 + (rng.next() % 8) as usize).min(64);
                    if pool.ensure_room(slot, rows).is_ok() {
                        pool.set_len(slot, rows);
                    }
                }
            }
            2 => {
                if !live.is_empty() {
                    let i = (rng.next() as usize) % live.len();
                    let slot = live.swap_remove(i);
                    // park full pages in the prefix cache half the time,
                    // so some pages stay referenced after release
                    if rng.next() % 2 == 0 && pool.len(slot) >= pool.page_size() {
                        let toks = prompt(pool.len(slot), slot as u64 + round);
                        let table = pool.table(slot).to_vec();
                        cache.insert(&toks, &table, &mut pool);
                    }
                    pool.release(slot);
                }
            }
            _ => {
                // a prefix hit attaches shared pages to a fresh slot
                // (lookup itself retains nothing — attach_shared does)
                let toks = prompt(16, (rng.next() % 5) as u64 + round);
                let hit = cache.lookup(&toks, pool.page_size());
                if !hit.is_empty() {
                    if let Some(slot) = pool.alloc() {
                        let covered = hit.len() * pool.page_size();
                        pool.attach_shared(slot, &hit, covered);
                        live.push(slot);
                    }
                }
            }
        }

        // the audit-module validator must agree...
        let v = check_kv_pool(&pool, &cache);
        assert!(v.is_empty(), "round {round}: validator reported {v:?}");

        // ...and so must this test's own shadow model, built only from
        // public observers
        let mut shadow = vec![0u32; pool.n_pages()];
        for s in 0..pool.n_slots() {
            if pool.is_in_use(s) {
                for &p in pool.table(s) {
                    shadow[p as usize] += 1;
                }
            }
        }
        for p in cache.entry_pages() {
            shadow[p as usize] += 1;
        }
        for (p, &want) in shadow.iter().enumerate() {
            assert_eq!(
                pool.page_ref(p as u32),
                want,
                "round {round}: page {p} refcount drifted from shadow"
            );
        }
    }
}

// ---------------------------------------------------------------------
// negative: every auditor must fire on a corrupted state
// ---------------------------------------------------------------------

/// Corrupting a live page's refcount out from under the pool makes the
/// KV auditor report refcount drift (and the free-list/ledger checks
/// stay specific: only the drift fires).
#[test]
fn kv_auditor_fires_on_refcount_drift() {
    let backend = ReferenceBackend::new();
    let state = ModelState::init(
        &backend.manifest().preset(PRESET).unwrap().blocks,
        3,
    );
    let mut srv = ServeEngine::new(
        &backend,
        PRESET,
        &state,
        ServeConfig { slots: 2, max_new_tokens: 4, ..Default::default() },
    )
    .unwrap();
    srv.submit(prompt(6, 1), 0, 0.0);
    // run one step so a slot holds mapped pages
    srv.step().unwrap();
    assert!(srv.audit_violations().is_empty(), "engine must start sound");

    let mapped = {
        let pool = srv.kv_pool_mut();
        let slot = (0..pool.n_slots())
            .find(|&s| pool.is_in_use(s) && !pool.table(s).is_empty())
            .expect("one slot holds pages after a step");
        let page = pool.table(slot)[0];
        pool.retain_page(page); // refcount now disagrees with the tables
        page
    };
    let v = srv.audit_violations();
    assert!(
        v.iter().any(|m| m.contains("refcount drift") && m.contains(&format!("{mapped}"))),
        "expected refcount drift on page {mapped}, got {v:?}"
    );
}

/// The budget auditor fires iff reservations exceed what held + free +
/// evictable pages can cover.
#[test]
fn budget_auditor_fires_on_overpromise() {
    assert!(check_budget(6, 2, 3, 1).is_empty(), "solvent budget must be clean");
    let v = check_budget(10, 2, 3, 1);
    assert!(
        v.iter().any(|m| m.contains("10 pages promised")),
        "expected an overpromise report, got {v:?}"
    );
}

/// Feeding the workspace arena a buffer it never lent out breaks the
/// capacity ledger, which `audit_check` must flag as drift.
#[test]
fn workspace_auditor_fires_on_foreign_give() {
    let mut ws = Workspace::new();
    let a = ws.take(32);
    ws.give(a);
    assert!(ws.audit_check().is_empty(), "normal take/give must be clean");
    ws.give(vec![0.0f32; 64]);
    let v = ws.audit_check();
    assert!(
        v.iter().any(|m| m.contains("capacity drift")),
        "expected capacity drift, got {v:?}"
    );
}

/// The finite probe reports NaN/inf with the offending index.
#[test]
fn finite_probe_fires_on_nan() {
    assert!(check_finite("clean", &[0.0, -1.5, 7.25]).is_empty());
    let v = check_finite("poisoned", &[0.0, f32::NAN, f32::INFINITY]);
    assert!(
        v.iter().any(|m| m.contains("poisoned") && m.contains("index 1")),
        "expected a non-finite report naming index 1, got {v:?}"
    );
}
