//! Masked-backward contracts: the selection-gated `train_step_masked`
//! kernel must be a *pure restriction* of the full backward —
//!
//! 1. selected blocks' gradients bit-match the full-backward oracle for
//!    randomized masks (plus the adversarial corners: {first}, {last},
//!    all, singletons),
//! 2. exactly the selected gradients cross the backend boundary (output
//!    arity = 1 + |selected|),
//! 3. the masked arena path reaches a zero-allocation steady state, also
//!    when masks and full steps interleave (the trainer's explore/exploit
//!    mix),
//! 4. through the trainer, a pure-exploit run touches no gradient norms
//!    and updates only selected blocks.
//!
//! The finite-difference check through a masked step (independent of the
//! full-step oracle) lives next to the kernels in `model/forward.rs`.

use adagradselect::config::{Method, RunConfig};
use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, Manifest, ReferenceBackend};
use adagradselect::util::rng::Rng;
use adagradselect::util::workspace::Workspace;

use adagradselect::model::forward::{train_step_in, train_step_masked_in};

fn tiny() -> (adagradselect::runtime::ModelSpec, Vec<adagradselect::runtime::BlockSpec>) {
    let mut m = Manifest::builtin().preset("test-tiny").unwrap().model.clone();
    // shrink so the randomized sweep stays fast; block table follows suit
    m.d_model = 16;
    m.n_heads = 2;
    m.d_head = 8;
    m.d_ff = 24;
    m.vocab = 13;
    m.seq_len = 6;
    m.batch = 2;
    m.n_layers = 3;
    let blocks = adagradselect::runtime::presets::block_table(&m);
    (m, blocks)
}

fn batch_for(rows: usize, vocab: usize) -> (Vec<i32>, Vec<i32>) {
    let tokens: Vec<i32> = (0..rows).map(|i| 1 + (i as i32 * 3) % (vocab as i32 - 1)).collect();
    let mut targets: Vec<i32> =
        (0..rows).map(|i| 1 + (i as i32 * 5) % (vocab as i32 - 1)).collect();
    targets[rows - 1] = 0; // one pad position
    (tokens, targets)
}

#[test]
fn masked_grads_bit_match_full_oracle_over_randomized_masks() {
    let (spec, blocks) = tiny();
    let n = blocks.len();
    let state = ModelState::init(&blocks, 41);
    let refs: Vec<&[f32]> = state.flats.iter().map(|f| f.as_slice()).collect();
    let (tok, tgt) = batch_for(spec.batch * spec.seq_len, spec.vocab);

    let mut ws = Workspace::new();
    let (loss_full, grads_full) =
        train_step_in(&mut ws, &spec, &blocks, &refs, &tok, &tgt, 0).unwrap();

    // corners: every singleton (incl. first=embed, last=head), all-true
    let mut masks: Vec<Vec<bool>> = (0..n).map(|b| (0..n).map(|i| i == b).collect()).collect();
    masks.push(vec![true; n]);
    // randomized masks with at least one selected block
    let mut rng = Rng::seed_from_u64(0xA5C3);
    for _ in 0..20 {
        let mut mask: Vec<bool> = (0..n).map(|_| rng.gen_f64() < 0.5).collect();
        let force = rng.gen_range(0, n);
        mask[force] = true;
        masks.push(mask);
    }

    for mask in &masks {
        let (loss, grads) =
            train_step_masked_in(&mut ws, &spec, &blocks, &refs, &tok, &tgt, 0, mask).unwrap();
        assert_eq!(loss.to_bits(), loss_full.to_bits(), "mask {mask:?}: loss diverged");
        let selected: Vec<usize> = (0..n).filter(|&b| mask[b]).collect();
        assert_eq!(
            grads.len(),
            selected.len(),
            "mask {mask:?}: arity must be 1 + |selected|"
        );
        for (g, &b) in grads.iter().zip(&selected) {
            assert_eq!(
                g, &grads_full[b],
                "mask {mask:?}: block {b} gradient is not a bit-match of the full backward"
            );
        }
    }
}

#[test]
fn backend_boundary_carries_only_selected_gradients() {
    let engine = ReferenceBackend::new();
    let p = engine.manifest().preset("test-tiny").unwrap().clone();
    let exe = engine.load_preset_exe("test-tiny", "train_step_masked").unwrap();
    let exe_full = engine.load_preset_exe("test-tiny", "train_step").unwrap();
    let state = ModelState::init(&p.blocks, 9);
    let bufs: Vec<_> =
        state.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();
    let (b, s) = (p.model.batch, p.model.seq_len);
    let tokens: Vec<i32> = (0..b * s).map(|i| 4 + (i % 40) as i32).collect();
    let tok = engine.upload_i32(&tokens, &[b, s]).unwrap();
    let n = p.blocks.len();

    let full = {
        let mut args: Vec<_> = bufs.iter().collect();
        args.push(&tok);
        args.push(&tok);
        engine.execute_to_host(&exe_full, &args).unwrap()
    };
    assert_eq!(full.outputs.len(), 1 + n);

    // select {layer0, head}: 2 gradient outputs, matching the full ones
    let mask_vec: Vec<i32> = (0..n).map(|i| i32::from(i == 1 || i == n - 1)).collect();
    let mask = engine.upload_i32(&mask_vec, &[n]).unwrap();
    let mut args: Vec<_> = bufs.iter().collect();
    args.push(&tok);
    args.push(&tok);
    args.push(&mask);
    let out = engine.execute_to_host(&exe, &args).unwrap();
    assert_eq!(out.outputs.len(), 1 + 2, "unselected gradients crossed the boundary");
    assert_eq!(out.outputs[0], full.outputs[0], "loss diverged");
    assert_eq!(out.outputs[1], full.outputs[1 + 1], "layer0 grads diverged");
    assert_eq!(out.outputs[2], full.outputs[1 + n - 1], "head grads diverged");

    // empty and malformed masks are rejected at the boundary
    let empty = engine.upload_i32(&vec![0; n], &[n]).unwrap();
    let mut bad: Vec<_> = bufs.iter().collect();
    bad.push(&tok);
    bad.push(&tok);
    bad.push(&empty);
    assert!(engine.execute_to_host(&exe, &bad).is_err());
}

#[test]
fn masked_arena_path_reaches_zero_alloc_steady_state() {
    let (spec, blocks) = tiny();
    let n = blocks.len();
    let state = ModelState::init(&blocks, 17);
    let refs: Vec<&[f32]> = state.flats.iter().map(|f| f.as_slice()).collect();
    let (tok, tgt) = batch_for(spec.batch * spec.seq_len, spec.vocab);
    let mask: Vec<bool> = (0..n).map(|b| b == 2 || b == n - 1).collect();

    let mut ws = Workspace::new();
    // warm-up covers both step shapes (the trainer's explore/exploit mix)
    let (_, g0) = train_step_masked_in(&mut ws, &spec, &blocks, &refs, &tok, &tgt, 0, &mask)
        .unwrap();
    train_step_in(&mut ws, &spec, &blocks, &refs, &tok, &tgt, 0).unwrap();
    let warm = ws.stats();
    for _ in 0..3 {
        let (_, g) =
            train_step_masked_in(&mut ws, &spec, &blocks, &refs, &tok, &tgt, 0, &mask).unwrap();
        assert_eq!(g, g0, "arena reuse must stay bit-deterministic");
        train_step_in(&mut ws, &spec, &blocks, &refs, &tok, &tgt, 0).unwrap();
    }
    let steady = ws.stats();
    assert_eq!(steady.grows, warm.grows, "steady-state masked/full mix must not allocate");
    assert_eq!(steady.high_water_bytes, warm.high_water_bytes);

    // and the masked phase alone peaks below the full phase: fewer layer
    // caches are ever resident (measured, not modeled)
    let mut ws_masked = Workspace::new();
    let mut ws_full = Workspace::new();
    train_step_masked_in(&mut ws_masked, &spec, &blocks, &refs, &tok, &tgt, 0, &mask).unwrap();
    train_step_in(&mut ws_full, &spec, &blocks, &refs, &tok, &tgt, 0).unwrap();
    assert!(
        ws_masked.stats().high_water_bytes < ws_full.stats().high_water_bytes,
        "masked step peak {} must undercut full step peak {}",
        ws_masked.stats().high_water_bytes,
        ws_full.stats().high_water_bytes
    );
}

#[test]
fn pure_exploit_trainer_runs_masked_and_never_reduces_norms() {
    let engine = ReferenceBackend::new();
    let mut cfg = RunConfig::preset_defaults("test-tiny");
    // ε₀ = 0 ⇒ every step exploits from step 0 (Dirichlet over the flat
    // prior); clipping off ⇒ nothing else wants gradient norms
    cfg.method = Method::AdaGradSelect {
        pct: 30.0,
        eps0: 0.0,
        lambda: None,
        delta: 1.0,
        explore_after_epoch1: false,
        uniform_exploit: false,
    };
    cfg.train.steps = 12;
    cfg.train.steps_per_epoch = 6;
    cfg.train.log_every = 0;
    cfg.train.grad_clip = None;
    let mut t = adagradselect::train::Trainer::new(&engine, cfg).unwrap();
    let summary = t.run().unwrap();
    assert_eq!(summary.exploit_steps, 12);
    assert_eq!(summary.explore_steps, 0);
    assert_eq!(summary.masked_steps, 12, "every exploit step must take the masked kernel");
    assert_eq!(
        summary.norm_reduced_blocks, 0,
        "exploit steps must not reduce gradient norms (paper: exploitation avoids gradient access)"
    );
    assert!(summary.final_loss.is_finite());
}

#[test]
fn explore_steps_still_reduce_all_norms() {
    let engine = ReferenceBackend::new();
    let mut cfg = RunConfig::preset_defaults("test-tiny");
    cfg.method = Method::TopK { pct: 30.0 }; // ranks every step: all-norm reductions
    cfg.train.steps = 4;
    cfg.train.steps_per_epoch = 2;
    cfg.train.log_every = 0;
    cfg.train.grad_clip = None;
    let mut t = adagradselect::train::Trainer::new(&engine, cfg).unwrap();
    let summary = t.run().unwrap();
    assert_eq!(summary.masked_steps, 0, "norm-ranking steps cannot run masked");
    let n = summary.selection_histogram.len() as u64;
    assert_eq!(summary.norm_reduced_blocks, 4 * n);
}
