//! Device-resident training contracts:
//!
//! 1. the fused/composed device-resident trainer is a **bit-match** of
//!    the retained host-loop oracle — loss trajectory and final
//!    parameters over ≥24 steps, in base and LoRA modes, masked
//!    (exploit) and full/norm-ranking step shapes, with and without
//!    global-norm clipping;
//! 2. the observed boundary traffic equals the analytic byte count for
//!    both step shapes — an exploit step moves the batch + mask up and
//!    exactly the 4-byte loss scalar down, a norm-ranking step adds one
//!    f32 squared-norm read-back per block (never a gradient);
//! 3. host-loop gradient staging shrinks to the selected blocks after a
//!    masked step (the stale-gradient regression), and gradients never
//!    reach the host at all in device-resident mode;
//! 4. manifests without the in-place entries resolve to the host loop.

use adagradselect::config::{Method, RunConfig};
use adagradselect::runtime::{Backend, Manifest, ReferenceBackend};
use adagradselect::train::{ExecMode, Trainer};

const STEPS: u64 = 24;

fn cfg(method: Method, clip: Option<f32>) -> RunConfig {
    let mut cfg = RunConfig::preset_defaults("test-tiny");
    cfg.method = method;
    cfg.train.steps = STEPS;
    cfg.train.steps_per_epoch = STEPS / 2;
    cfg.train.log_every = 0;
    cfg.train.grad_clip = clip;
    cfg
}

/// Drive both execution modes over the same config and assert bitwise
/// identity of the per-step losses, the selection trajectory, and the
/// final (effective) parameters.
fn assert_bit_parity(method: Method, clip: Option<f32>, label: &str) {
    let engine = ReferenceBackend::new();
    let mut dev = Trainer::new(&engine, cfg(method.clone(), clip)).unwrap();
    assert_eq!(dev.exec_mode(), ExecMode::DeviceResident, "{label}");
    let mut host = Trainer::new_host_loop(&engine, cfg(method, clip)).unwrap();
    assert_eq!(host.exec_mode(), ExecMode::HostLoop, "{label}");

    for step in 0..STEPS {
        let ld = dev.step_once().unwrap();
        let lh = host.step_once().unwrap();
        assert_eq!(
            ld.to_bits(),
            lh.to_bits(),
            "{label}: loss diverged at step {step}: device {ld} vs host {lh}"
        );
        let sd = &dev.metrics.records.last().unwrap().selected;
        let sh = &host.metrics.records.last().unwrap().selected;
        assert_eq!(sd, sh, "{label}: selection diverged at step {step}");
    }

    let sd = dev.eval_state().unwrap();
    let sh = host.eval_state().unwrap();
    for (i, (a, b)) in sd.flats.iter().zip(&sh.flats).enumerate() {
        assert_eq!(a, b, "{label}: final parameters of block {i} are not a bit-match");
    }
    // gradients never reach the host in device mode
    assert_eq!(dev.host_grad_bytes(), 0, "{label}: device mode staged gradients on the host");
}

#[test]
fn fused_exploit_bit_matches_host_loop_oracle() {
    // ε₀ = 0 ⇒ every step is a pre-decided (masked) exploit step; with
    // clipping off the device path takes the fully fused entry
    let method = Method::AdaGradSelect {
        pct: 30.0,
        eps0: 0.0,
        lambda: None,
        delta: 1.0,
        explore_after_epoch1: false,
        uniform_exploit: false,
    };
    let engine = ReferenceBackend::new();
    let mut probe = Trainer::new(&engine, cfg(method.clone(), None)).unwrap();
    for _ in 0..4 {
        probe.step_once().unwrap();
    }
    assert_eq!(probe.fused_steps(), 4, "exploit steps must take the fused entry");
    assert_eq!(probe.norm_reduced_blocks(), 0);

    assert_bit_parity(method, None, "fused-exploit");
}

#[test]
fn masked_composed_with_clipping_bit_matches_host_loop() {
    // clipping forces the composed path (masked backward + selected-norm
    // read-back + scaled in-place AdamW) — still no gradient download
    let method = Method::Fixed { blocks: vec![1, 3] };
    assert_bit_parity(method, Some(1.0), "masked-composed-clip");
}

#[test]
fn norm_ranking_explore_bit_matches_host_loop() {
    // top-k ranks every step: full backward, per-block norm read-backs,
    // choose() from boundary-rounded norms
    assert_bit_parity(Method::TopK { pct: 30.0 }, None, "topk-explore");
}

#[test]
fn full_fine_tuning_with_clip_bit_matches_host_loop() {
    assert_bit_parity(Method::Full, Some(1.0), "full-clip");
}

#[test]
fn lora_bit_matches_host_loop() {
    // adapters train through the composed handle path (with the default
    // clip); eval_state merges base + read-back adapters
    assert_bit_parity(Method::Lora { double_rank: false }, Some(1.0), "lora");
}

#[test]
fn exploit_step_transfers_match_analytic_bytes() {
    let engine = ReferenceBackend::new();
    let preset = engine.manifest().preset("test-tiny").unwrap().clone();
    let n = preset.blocks.len();
    let (b, s) = (preset.model.batch, preset.model.seq_len);
    // fixed selection ⇒ identical mask and arena shape every step
    let mut t =
        Trainer::new(&engine, cfg(Method::Fixed { blocks: vec![n - 2, n - 1] }, None)).unwrap();
    assert_eq!(t.exec_mode(), ExecMode::DeviceResident);
    // warm-up: step-tensor sync + buffer-pool fill
    t.step_once().unwrap();
    t.step_once().unwrap();

    for step in 0..6u64 {
        let before = engine.transfer_stats();
        t.step_once().unwrap();
        let d = engine.transfer_stats().delta_since(&before);
        assert_eq!(
            d.h2d_bytes,
            ((2 * b * s + n) * 4) as u64,
            "step {step}: exploit h2d must be exactly tokens + targets + mask"
        );
        assert_eq!(d.d2h_bytes, 4, "step {step}: exploit d2h must be exactly the loss scalar");
        assert_eq!(d.buffer_allocs, 0, "step {step}: steady state must not allocate buffers");
    }
    assert!(t.fused_steps() >= 8);
}

#[test]
fn explore_step_transfers_match_analytic_bytes() {
    let engine = ReferenceBackend::new();
    let preset = engine.manifest().preset("test-tiny").unwrap().clone();
    let n = preset.blocks.len();
    let (b, s) = (preset.model.batch, preset.model.seq_len);
    // top-k needs norms every step: the full backward runs, one f32
    // squared norm per block is read back, lr + clip-scale scalars are
    // written — but gradients never cross
    let mut t = Trainer::new(&engine, cfg(Method::TopK { pct: 30.0 }, None)).unwrap();
    t.step_once().unwrap();
    t.step_once().unwrap();

    for step in 0..4u64 {
        let before = engine.transfer_stats();
        t.step_once().unwrap();
        let d = engine.transfer_stats().delta_since(&before);
        assert_eq!(
            d.h2d_bytes,
            ((2 * b * s) * 4 + 8) as u64,
            "step {step}: explore h2d must be tokens + targets + lr + scale"
        );
        assert_eq!(
            d.d2h_bytes,
            (4 + 4 * n) as u64,
            "step {step}: explore d2h must be the loss + one norm scalar per block"
        );
    }
    assert_eq!(t.fused_steps(), 0, "norm-ranking steps cannot fuse");
}

#[test]
fn stale_host_gradients_are_shrunk_after_masked_steps() {
    let engine = ReferenceBackend::new();
    let numels = engine.manifest().preset("test-tiny").unwrap().block_numels();
    // pure-exploit: every host-loop step is masked, so after each step
    // only the selected blocks may hold gradient staging
    let method = Method::AdaGradSelect {
        pct: 30.0,
        eps0: 0.0,
        lambda: None,
        delta: 1.0,
        explore_after_epoch1: false,
        uniform_exploit: false,
    };
    let mut t = Trainer::new_host_loop(&engine, cfg(method, None)).unwrap();
    for step in 0..8u64 {
        t.step_once().unwrap();
        let selected = t.metrics.records.last().unwrap().selected.clone();
        let expect: usize = selected.iter().map(|&b| numels[b] * 4).sum();
        assert_eq!(
            t.host_grad_bytes(),
            expect,
            "step {step}: unselected grads_host entries must be shrunk, not kept stale"
        );
        let total: usize = numels.iter().map(|&x| x * 4).sum();
        assert!(t.host_grad_bytes() < total, "step {step}: staging must shrink below full size");
    }
}

#[test]
fn manifests_without_inplace_entries_resolve_to_host_loop() {
    let mut m = Manifest::builtin();
    m.shared.remove("adamw_update_inplace");
    let engine = ReferenceBackend::with_manifest(m);
    let t = Trainer::new(&engine, cfg(Method::Full, Some(1.0))).unwrap();
    assert_eq!(t.exec_mode(), ExecMode::HostLoop, "must degrade to the host loop");
    // and asking for device residency explicitly is a clear error
    let err = Trainer::new_with_mode(
        &engine,
        cfg(Method::Full, Some(1.0)),
        ExecMode::DeviceResident,
    )
    .unwrap_err();
    assert!(format!("{err}").contains("adamw_update_inplace"), "{err}");
}
