//! Integration: full training runs on the `test-tiny` preset for every
//! method, exercising trainer × selection × optimizer × residency × eval
//! on the pure-Rust reference backend (no artifacts required).

use adagradselect::config::{Method, RunConfig};
use adagradselect::data::{MathGen, Split, Suite};
use adagradselect::eval::Evaluator;
use adagradselect::runtime::ReferenceBackend;
use adagradselect::train::Trainer;

fn engine() -> ReferenceBackend {
    ReferenceBackend::new()
}

fn cfg(method: Method, steps: u64) -> RunConfig {
    let mut cfg = RunConfig::preset_defaults("test-tiny");
    cfg.method = method;
    cfg.train.steps = steps;
    cfg.train.steps_per_epoch = steps / 2;
    cfg.train.log_every = 0;
    cfg
}

#[test]
fn every_method_reduces_loss() {
    let engine = engine();
    for method in [
        Method::Full,
        Method::ags(30.0),
        Method::TopK { pct: 30.0 },
        Method::Random { pct: 30.0 },
        Method::RoundRobin { pct: 30.0 },
        Method::Lora { double_rank: false },
        Method::Fixed { blocks: vec![0, 1] },
    ] {
        let label = method.label();
        let mut t = Trainer::new(&engine, cfg(method, 40)).unwrap();
        let first = t.step_once().unwrap();
        let summary = t.run().unwrap();
        assert!(
            summary.tail_loss < first - 0.05,
            "{label}: first {first} tail {}",
            summary.tail_loss
        );
        assert_eq!(summary.steps, 40);
    }
}

#[test]
fn selective_updates_only_touch_selected_blocks() {
    let engine = engine();
    let mut t = Trainer::new(&engine, cfg(Method::Fixed { blocks: vec![1] }, 5)).unwrap();
    let before = t.state.clone();
    t.run().unwrap();
    // block 1 changed, everything else bit-identical
    for (i, (a, b)) in before.flats.iter().zip(&t.state.flats).enumerate() {
        if i == 1 {
            assert_ne!(a, b, "selected block should move");
        } else {
            assert_eq!(a, b, "frozen block {i} moved");
        }
    }
}

#[test]
fn adagrad_select_explores_then_exploits() {
    let engine = engine();
    let mut c = cfg(Method::ags(30.0), 60);
    c.train.steps_per_epoch = 30;
    let mut t = Trainer::new(&engine, c).unwrap();
    let summary = t.run().unwrap();
    // epoch 1 starts at ε=1 (always explore at step 0); epoch 2 never
    // explores. With 30 epoch-1 steps and fast decay, explores ∈ [1, 30].
    assert!(summary.explore_steps >= 1);
    assert!(summary.explore_steps <= 30);
    assert_eq!(summary.explore_steps + summary.exploit_steps, 60);
    // every selection histogram entry counted k blocks per step
    let k = adagradselect::selection::k_from_pct(4, 30.0);
    let total: u64 = summary.selection_histogram.iter().sum();
    assert_eq!(total, 60 * k as u64);
}

#[test]
fn residency_vram_matches_selected_blocks() {
    let engine = engine();
    let mut t = Trainer::new(&engine, cfg(Method::ags(50.0), 20)).unwrap();
    let summary = t.run().unwrap();
    // observed peak optimizer VRAM ≤ the static §3.3 worst case
    assert!(summary.opt_vram_peak_bytes <= summary.memory.optimizer * 2 + 1,
            "peak {} vs static {}", summary.opt_vram_peak_bytes, summary.memory.optimizer);
    assert!(summary.opt_vram_avg_bytes > 0.0);
    // full-FT pins everything from step 0 and never transfers
    let mut tf = Trainer::new(&engine, cfg(Method::Full, 10)).unwrap();
    let sf = tf.run().unwrap();
    assert_eq!(sf.opt_vram_peak_bytes, sf.memory.optimizer);
    assert_eq!(sf.pcie_stall_s, 0.0);
}

#[test]
fn metrics_jsonl_is_written_and_parses() {
    let engine = engine();
    let path = std::env::temp_dir().join(format!("agsel-int-{}.jsonl", std::process::id()));
    let mut c = cfg(Method::ags(30.0), 8);
    c.metrics_path = Some(path.clone());
    Trainer::new(&engine, c).unwrap().run().unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(text.lines().count(), 8);
    for line in text.lines() {
        let v = adagradselect::util::json::Value::parse(line).unwrap();
        assert!(v.get("loss").unwrap().as_f64().unwrap().is_finite());
        assert!(!v.get("selected").unwrap().as_arr().unwrap().is_empty());
    }
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let engine = engine();
    let mut t = Trainer::new(&engine, cfg(Method::Full, 6)).unwrap();
    t.run().unwrap();
    let state = t.eval_state().unwrap();
    let path = std::env::temp_dir().join(format!("agsel-ck-{}.bin", std::process::id()));
    state.save(&path).unwrap();
    let loaded = adagradselect::model::ModelState::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(state.flats, loaded.flats);
}

#[test]
fn lora_eval_state_is_merged_base() {
    let engine = engine();
    let mut t = Trainer::new(&engine, cfg(Method::Lora { double_rank: false }, 10)).unwrap();
    t.run().unwrap();
    let merged = t.eval_state().unwrap();
    let base = t.base_state.as_ref().unwrap();
    // merged layers differ from frozen base (adapters trained), embed/head equal
    assert_eq!(merged.flats[0], base.flats[0]);
    assert_ne!(merged.flats[1], base.flats[1]);
    assert_eq!(merged.flats.last(), base.flats.last());
    // and its eval loss through the plain decode path must equal the
    // adapter-forward loss the trainer saw (within float tolerance):
    let ev = Evaluator::new(&engine, "test-tiny", 8).unwrap();
    let suite = Suite::Gsm8kSim;
    let mut batcher = adagradselect::data::TrainBatcher::new(
        MathGen::new(suite, Split::Train, 0),
        ev.tokenizer().clone(),
        t.preset.model.batch,
        t.preset.model.seq_len,
    );
    let loss = ev.eval_loss(&merged, &mut batcher, 2).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
}

#[test]
fn evaluator_generates_and_scores() {
    let engine = engine();
    let mut t = Trainer::new(&engine, cfg(Method::Full, 10)).unwrap();
    t.run().unwrap();
    let ev = Evaluator::new(&engine, "test-tiny", 8).unwrap();
    let probs = MathGen::new(Suite::Gsm8kSim, Split::Eval, 0).problems(0, 8);
    let res = ev.accuracy(&t.eval_state().unwrap(), &probs).unwrap();
    assert_eq!(res.n, 8);
    // untrained-ish model: accuracy is almost surely 0, but the pipeline
    // must produce a full result with all fields populated
    assert!(res.accuracy >= 0.0 && res.accuracy <= 1.0);
    assert!(res.wallclock_s > 0.0);
}

#[test]
fn pallas_kernel_flag_trains() {
    let engine = engine();
    let mut c = cfg(Method::ags(30.0), 4);
    c.pallas_kernel = true;
    let mut t = Trainer::new(&engine, c).unwrap();
    let loss = t.step_once().unwrap();
    assert!(loss.is_finite());
}

#[test]
fn deterministic_given_seed() {
    let engine = engine();
    let run = |seed: u64| {
        let mut c = cfg(Method::ags(30.0), 12);
        c.seed = seed;
        let mut t = Trainer::new(&engine, c).unwrap();
        let s = t.run().unwrap();
        (s.final_loss, s.selection_histogram.clone())
    };
    assert_eq!(run(5), run(5));
    assert_ne!(run(5).1, run(6).1);
}
