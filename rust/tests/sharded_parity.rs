//! Sharded data-parallel training contracts:
//!
//! 1. [`ShardedTrainer`] is a **bit-match** of the single-worker
//!    host-loop `Trainer` at equal effective batch — per-step loss bits
//!    and final parameter bits over ≥24 steps, across {1, 2, 4} shards ×
//!    {pure-exploit, top-k explore, masked+clip, full+clip} step shapes;
//! 2. the all-reduced per-block gradient norms bit-match the norms of
//!    the full-batch gradients (the property the explore phase's
//!    gather-then-reduce design exists to guarantee: per-shard norm
//!    scalars lose the cross terms, reduced flats don't);
//! 3. the selection-gated collective's byte accounting is exact — an
//!    exploit step moves `n_workers · selected_params · 4` bytes per
//!    all-reduce leg, an explore step gathers every block and adds one
//!    squared-norm f32 per block to the broadcast;
//! 4. the steady state allocates nothing on any worker: device-buffer
//!    allocs and workspace-arena grows are zero per step once warm.

use adagradselect::config::{Method, RunConfig};
use adagradselect::data::{MathGen, Split, Suite, Tokenizer, TrainBatcher};
use adagradselect::model::forward::{loss_from_sum, tree_add_chunks, tree_sum_f32};
use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, ReferenceBackend};
use adagradselect::selection::grad_norm::block_norm_sq;
use adagradselect::train::{ShardedTrainer, Trainer};

const STEPS: u64 = 24;

fn cfg(method: Method, clip: Option<f32>) -> RunConfig {
    let mut cfg = RunConfig::preset_defaults("test-tiny");
    cfg.method = method;
    cfg.train.steps = STEPS;
    cfg.train.steps_per_epoch = STEPS / 2;
    cfg.train.log_every = 0;
    cfg.train.grad_clip = clip;
    cfg
}

fn exploit_method() -> Method {
    // ε₀ = 0 ⇒ every step is a pre-decided (masked) exploit step
    Method::AdaGradSelect {
        pct: 30.0,
        eps0: 0.0,
        lambda: None,
        delta: 1.0,
        explore_after_epoch1: false,
        uniform_exploit: false,
    }
}

/// Drive the sharded trainer at each shard count against the
/// single-worker host-loop oracle and assert bitwise identity of the
/// per-step losses and the final parameters.
fn assert_shard_parity(method: Method, clip: Option<f32>, label: &str) {
    for n_shards in [1usize, 2, 4] {
        let engine = ReferenceBackend::new();
        let mut single = Trainer::new_host_loop(&engine, cfg(method.clone(), clip)).unwrap();
        let mut sharded = ShardedTrainer::new(cfg(method.clone(), clip), n_shards).unwrap();
        assert_eq!(sharded.n_shards(), n_shards);

        for step in 0..STEPS {
            let ls = single.step_once().unwrap();
            let ld = sharded.step_once().unwrap();
            assert_eq!(
                ld.to_bits(),
                ls.to_bits(),
                "{label}/{n_shards} shards: loss diverged at step {step}: \
                 sharded {ld} vs single {ls}"
            );
        }

        for (i, (a, b)) in sharded.state.flats.iter().zip(&single.state.flats).enumerate() {
            assert_eq!(
                a, b,
                "{label}/{n_shards} shards: final parameters of block {i} are not a bit-match"
            );
        }
    }
}

#[test]
fn exploit_bit_matches_single_worker() {
    assert_shard_parity(exploit_method(), None, "exploit");
}

#[test]
fn topk_explore_bit_matches_single_worker() {
    // top-k ranks every step: full gather, coordinator norms, broadcast
    // squared norms drive every replica's choose()
    assert_shard_parity(Method::TopK { pct: 30.0 }, None, "topk-explore");
}

#[test]
fn masked_clipped_bit_matches_single_worker() {
    // masked backward + selected-block norms + global clip: the scale
    // and the selected squared norms ride the broadcast
    assert_shard_parity(Method::Fixed { blocks: vec![1, 3] }, Some(1.0), "masked-clip");
}

#[test]
fn full_fine_tuning_with_clip_bit_matches_single_worker() {
    assert_shard_parity(Method::Full, Some(1.0), "full-clip");
}

#[test]
fn run_reproduces_across_invocations() {
    // same config, same shard count, fresh processes-worth of state:
    // identical loss trajectory (determinism across runs, not just vs
    // the single worker)
    let run = || {
        let mut t = ShardedTrainer::new(cfg(Method::TopK { pct: 30.0 }, Some(1.0)), 2).unwrap();
        (0..8).map(|_| t.step_once().unwrap().to_bits()).collect::<Vec<u32>>()
    };
    assert_eq!(run(), run());
}

#[test]
fn sharded_trainer_rejects_bad_shapes() {
    // 3 does not divide test-tiny's batch of 4 (and is not a power of two)
    assert!(ShardedTrainer::new(cfg(Method::Full, None), 3).is_err());
    assert!(ShardedTrainer::new(cfg(Method::Full, None), 0).is_err());
    // LoRA's adapter backward is not shard-decomposed
    assert!(ShardedTrainer::new(cfg(Method::Lora { double_rank: false }, None), 2).is_err());
}

/// Property: folding per-shard gradient partials through the fixed
/// floor-half tree reproduces the full-batch gradients — and therefore
/// the per-block norms — bit-for-bit, at every power-of-two shard count.
#[test]
fn all_reduced_block_norms_bit_match_full_batch_norms() {
    let engine = ReferenceBackend::new();
    let preset = engine.manifest().preset("test-tiny").unwrap().clone();
    let (b, s) = (preset.model.batch, preset.model.seq_len);
    let n_blocks = preset.blocks.len();
    let state = ModelState::init(&preset.blocks, 7);
    let blocks: Vec<_> = state
        .flats
        .iter()
        .map(|f| engine.upload_f32(f, &[f.len()]).unwrap())
        .collect();

    let tok = Tokenizer::from_spec(&engine.manifest().tokenizer);
    let pad = tok.pad;
    let mut batcher = TrainBatcher::new(MathGen::new(Suite::Gsm8kSim, Split::Train, 0), tok, b, s);
    let batch = batcher.next_batch();
    let denom = batch.targets.iter().filter(|&&t| t != pad).count();

    // full-batch oracle: the single-worker entry
    let exe_full = engine.load_preset_exe("test-tiny", "train_step").unwrap();
    let tok_buf = engine.upload_i32(&batch.tokens, &[b, s]).unwrap();
    let tgt_buf = engine.upload_i32(&batch.targets, &[b, s]).unwrap();
    let mut args: Vec<_> = blocks.iter().collect();
    args.push(&tok_buf);
    args.push(&tgt_buf);
    let mut full = engine.execute_to_host(&exe_full, &args).unwrap();
    let loss_full = full.scalar_f32(0).unwrap();
    let grads_full: Vec<Vec<f32>> =
        (1..=n_blocks).map(|i| full.take_vec(i).unwrap()).collect();

    let exe_shard = engine.load_preset_exe("test-tiny", "train_step_shard").unwrap();
    let den_buf = engine.upload_i32(&[denom as i32], &[1]).unwrap();
    for n_shards in [1usize, 2, 4] {
        let rows = b / n_shards;
        let mut loss_parts = Vec::new();
        let mut gather: Vec<Vec<f32>> =
            grads_full.iter().map(|g| vec![0.0f32; g.len() * n_shards]).collect();
        for r in 0..n_shards {
            let lo = r * rows * s;
            let hi = (r + 1) * rows * s;
            let tok_buf = engine.upload_i32(&batch.tokens[lo..hi], &[rows, s]).unwrap();
            let tgt_buf = engine.upload_i32(&batch.targets[lo..hi], &[rows, s]).unwrap();
            let mut args: Vec<_> = blocks.iter().collect();
            args.push(&tok_buf);
            args.push(&tgt_buf);
            args.push(&den_buf);
            let mut out = engine.execute_to_host(&exe_shard, &args).unwrap();
            loss_parts.push(out.scalar_f32(0).unwrap());
            for i in 0..n_blocks {
                let g = out.take_vec(1 + i).unwrap();
                let d = grads_full[i].len();
                gather[i][r * d..(r + 1) * d].copy_from_slice(&g);
            }
        }
        let loss = loss_from_sum(tree_sum_f32(&loss_parts), denom);
        assert_eq!(
            loss.to_bits(),
            loss_full.to_bits(),
            "{n_shards} shards: reduced loss is not a bit-match"
        );
        for i in 0..n_blocks {
            let d = grads_full[i].len();
            tree_add_chunks(&mut gather[i], d);
            assert_eq!(
                &gather[i][..d],
                &grads_full[i][..],
                "{n_shards} shards: reduced gradient of block {i} is not a bit-match"
            );
            assert_eq!(
                block_norm_sq(&gather[i][..d]).to_bits(),
                block_norm_sq(&grads_full[i]).to_bits(),
                "{n_shards} shards: all-reduced norm of block {i} is not a bit-match"
            );
        }
    }
}

/// The selection gate on the wire: per-step byte deltas of the
/// [`CommStats`](adagradselect::runtime::CommStats) counters equal the
/// analytic model for both step shapes.
#[test]
fn comm_bytes_match_analytic_model() {
    let engine = ReferenceBackend::new();
    let preset = engine.manifest().preset("test-tiny").unwrap().clone();
    let numels = preset.block_numels();
    let n_blocks = numels.len();
    let p_total: u64 = numels.iter().map(|&d| d as u64).sum();
    let sel = vec![n_blocks - 2, n_blocks - 1];
    let p_sel: u64 = sel.iter().map(|&b| numels[b] as u64).sum();
    let n = 2usize;

    // exploit: only the selected blocks' flats cross, each leg × workers
    let mut t = ShardedTrainer::new(cfg(Method::Fixed { blocks: sel.clone() }, None), n).unwrap();
    for step in 0..4u64 {
        let before = t.comm_stats();
        t.step_once().unwrap();
        let d = t.comm_stats().delta_since(&before);
        assert_eq!(
            d.grad_gather_bytes,
            n as u64 * p_sel * 4,
            "step {step}: exploit gather must move selected params only"
        );
        assert_eq!(d.grad_bcast_bytes, n as u64 * p_sel * 4, "step {step}: exploit bcast");
        assert_eq!(d.norm_bcast_bytes, 0, "step {step}: exploit steps broadcast no norms");
        assert_eq!(d.allreduce_ops, 1, "step {step}: one grad all-reduce");
    }

    // explore: every block is gathered; the broadcast carries the
    // selected flats plus one pre-clip squared norm per block
    let mut t = ShardedTrainer::new(cfg(Method::TopK { pct: 30.0 }, None), n).unwrap();
    for step in 0..4u64 {
        let before = t.comm_stats();
        t.step_once().unwrap();
        let d = t.comm_stats().delta_since(&before);
        assert_eq!(
            d.grad_gather_bytes,
            n as u64 * p_total * 4,
            "step {step}: explore gather must move every block"
        );
        assert_eq!(
            d.norm_bcast_bytes,
            n as u64 * n_blocks as u64 * 4,
            "step {step}: explore bcast carries one squared norm per block"
        );
        assert!(
            d.grad_bcast_bytes < n as u64 * p_total * 4,
            "step {step}: explore bcast must still be selection-gated"
        );
        assert_eq!(d.allreduce_ops, 2, "step {step}: grad + norm collectives");
    }
}

#[test]
fn steady_state_allocates_nothing_on_any_worker() {
    // fixed selection ⇒ identical upload shapes and arena footprint
    // every step, so the pools and arenas must reach a fixed point
    let mut t = ShardedTrainer::new(cfg(Method::Fixed { blocks: vec![1, 3] }, None), 2).unwrap();
    // warm-up: buffer pools and workspace arenas reach steady shape
    for _ in 0..3 {
        t.step_once().unwrap();
    }
    let before = t.worker_stats().unwrap();
    for _ in 0..4 {
        t.step_once().unwrap();
    }
    let after = t.worker_stats().unwrap();
    for (r, (a, b)) in before.iter().zip(&after).enumerate() {
        let d = b.transfers.delta_since(&a.transfers);
        assert_eq!(d.buffer_allocs, 0, "worker {r}: steady state must not allocate buffers");
        assert_eq!(b.ws_grows, a.ws_grows, "worker {r}: workspace arena must not grow");
    }
}

#[test]
fn comm_gauges_export_the_counters() {
    let mut t = ShardedTrainer::new(cfg(Method::TopK { pct: 30.0 }, Some(1.0)), 2).unwrap();
    for _ in 0..3 {
        t.step_once().unwrap();
    }
    let stats = t.comm_stats();
    let reg = &t.telemetry().registry;
    for (name, want) in [
        ("train_comm_grad_gather_bytes", stats.grad_gather_bytes as f64),
        ("train_comm_grad_bcast_bytes", stats.grad_bcast_bytes as f64),
        ("train_comm_norm_bcast_bytes", stats.norm_bcast_bytes as f64),
        ("train_comm_ctrl_bytes", stats.ctrl_bytes as f64),
        ("train_comm_allreduce_ops", stats.allreduce_ops as f64),
    ] {
        let id = reg.gauge_by_name(name).unwrap_or_else(|| panic!("{name} not registered"));
        assert_eq!(reg.gauge_value(id), want, "{name}");
        assert!(want > 0.0, "{name} must observe traffic after 3 steps");
    }
}
