//! Integration: PJRT runtime × AOT artifacts × native substrates.
//!
//! These tests exercise the real HLO artifacts through the `xla` crate —
//! the same code path the training loop uses — and cross-check the L1
//! Pallas kernels against the Rust-native implementations.

use std::path::PathBuf;

use adagradselect::model::ModelState;
use adagradselect::runtime::Engine;
use adagradselect::selection::grad_norm::block_norm_sq;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn adamw_hlo_matches_native_over_steps() {
    let engine = Engine::load(artifacts()).unwrap();
    // multi-chunk length + odd tail, several optimizer steps
    let err =
        adagradselect::optimizer::hlo_adamw_parity(&engine, 70_000, 7, 4).unwrap();
    assert!(err < 2e-6, "max diff {err}");
}

#[test]
fn adamw_hlo_chunk_exact_multiple() {
    let engine = Engine::load(artifacts()).unwrap();
    let n = engine.manifest.chunk_size * 2;
    let err = adagradselect::optimizer::hlo_adamw_parity(&engine, n, 3, 2).unwrap();
    assert!(err < 2e-6, "max diff {err}");
}

#[test]
fn grad_norm_hlo_matches_native() {
    let engine = Engine::load(artifacts()).unwrap();
    let exe = engine.load_shared_exe("grad_norm_sq").unwrap();
    let n = engine.manifest.chunk_size;
    let g: Vec<f32> = (0..n).map(|i| ((i % 31) as f32 - 15.0) * 0.05).collect();
    let buf = engine.upload_f32(&g).unwrap();
    let hlo = exe.run(&[&buf]).unwrap().vec_f32(0).unwrap()[0] as f64;
    let native = block_norm_sq(&g);
    assert!((hlo - native).abs() / native < 1e-5, "hlo {hlo} native {native}");
}

#[test]
fn train_step_loss_starts_near_uniform() {
    let engine = Engine::load(artifacts()).unwrap();
    let preset = engine.manifest.preset("test-tiny").unwrap().clone();
    let exe = engine.load_preset_exe("test-tiny", "train_step").unwrap();
    let state = ModelState::init(&preset.blocks, 0);

    let (b, s) = (preset.model.batch, preset.model.seq_len);
    let tokens: Vec<i32> = (0..b * s).map(|i| 4 + (i % 50) as i32).collect();
    let targets = tokens.clone();
    let mut args = Vec::new();
    let blocks: Vec<_> =
        state.flats.iter().map(|f| engine.upload_f32(f).unwrap()).collect();
    args.extend(blocks.iter());
    let tok = engine.upload_i32(&tokens, &[b, s]).unwrap();
    let tgt = engine.upload_i32(&targets, &[b, s]).unwrap();
    args.push(&tok);
    args.push(&tgt);

    let out = exe.run(&args).unwrap();
    let loss = out.scalar_f32(0).unwrap();
    // random init on vocab-64: CE ≈ ln(64) ≈ 4.16
    assert!((loss - 64f32.ln()).abs() < 0.6, "loss {loss}");
    // one grad per block, each with the block's numel
    assert_eq!(out.literals.len(), 1 + preset.blocks.len());
    for (i, blk) in preset.blocks.iter().enumerate() {
        assert_eq!(out.vec_f32(1 + i).unwrap().len(), blk.numel);
    }
}

#[test]
fn pallas_and_xla_train_steps_agree() {
    // The same loss + grads must come out of the Pallas-attention artifact
    // and the plain-XLA artifact — L1 kernel correctness *through the
    // whole AOT pipeline*, not just in-process jax.
    let engine = Engine::load(artifacts()).unwrap();
    let preset = engine.manifest.preset("test-tiny").unwrap().clone();
    let state = ModelState::init(&preset.blocks, 42);
    let (b, s) = (preset.model.batch, preset.model.seq_len);
    let tokens: Vec<i32> = (0..b * s).map(|i| 4 + ((i * 7) % 50) as i32).collect();
    let targets: Vec<i32> = (0..b * s).map(|i| 4 + ((i * 11) % 50) as i32).collect();

    let mut outs = Vec::new();
    for entry in ["train_step", "train_step_pallas"] {
        let exe = engine.load_preset_exe("test-tiny", entry).unwrap();
        let blocks: Vec<_> =
            state.flats.iter().map(|f| engine.upload_f32(f).unwrap()).collect();
        let mut args: Vec<&xla::PjRtBuffer> = blocks.iter().collect();
        let tok = engine.upload_i32(&tokens, &[b, s]).unwrap();
        let tgt = engine.upload_i32(&targets, &[b, s]).unwrap();
        args.push(&tok);
        args.push(&tgt);
        let out = exe.run(&args).unwrap();
        let mut all = vec![out.scalar_f32(0).unwrap()];
        for i in 0..preset.blocks.len() {
            all.extend(out.vec_f32(1 + i).unwrap());
        }
        outs.push(all);
    }
    let max_diff = outs[0]
        .iter()
        .zip(&outs[1])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 5e-5, "pallas vs xla max diff {max_diff}");
}

#[test]
fn decode_step_logits_shape_and_causality() {
    let engine = Engine::load(artifacts()).unwrap();
    let preset = engine.manifest.preset("test-tiny").unwrap().clone();
    let exe = engine.load_preset_exe("test-tiny", "decode_step").unwrap();
    let state = ModelState::init(&preset.blocks, 0);
    let (b, s, v) = (preset.model.batch, preset.model.seq_len, preset.model.vocab);

    let run = |tokens: &[i32]| {
        let blocks: Vec<_> =
            state.flats.iter().map(|f| engine.upload_f32(f).unwrap()).collect();
        let mut args: Vec<&xla::PjRtBuffer> = blocks.iter().collect();
        let tok = engine.upload_i32(tokens, &[b, s]).unwrap();
        args.push(&tok);
        exe.run(&args).unwrap().vec_f32(0).unwrap()
    };
    let tokens: Vec<i32> = (0..b * s).map(|i| 4 + (i % 40) as i32).collect();
    let logits = run(&tokens);
    assert_eq!(logits.len(), b * s * v);

    // causality through the artifact: flip the last token of row 0 — all
    // logits before the last position must be unchanged.
    let mut tokens2 = tokens.clone();
    tokens2[s - 1] = 5;
    let logits2 = run(&tokens2);
    let prefix = (s - 1) * v;
    let max_diff = logits[..prefix]
        .iter()
        .zip(&logits2[..prefix])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-5, "future token leaked into past logits: {max_diff}");
}

#[test]
fn manifest_covers_all_exported_presets() {
    let engine = Engine::load(artifacts()).unwrap();
    for name in ["test-tiny", "qwen-sim", "llama-sim", "phi-sim", "e2e"] {
        let p = engine.manifest.preset(name).unwrap();
        for entry in ["train_step", "train_step_lora", "eval_loss", "decode_step", "lora_merge"] {
            let path = p.artifact_path(engine.artifacts_dir(), entry).unwrap();
            assert!(path.exists(), "{name}/{entry} missing at {path:?}");
        }
    }
}
