//! Integration: the reference backend through the `Backend` trait — the
//! same code path the training loop uses — cross-checking the executor's
//! entrypoints against the native substrates and structural invariants
//! (loss at init, causality, manifest coverage).

use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, ReferenceBackend};
use adagradselect::selection::grad_norm::block_norm_sq;

fn backend() -> ReferenceBackend {
    ReferenceBackend::new()
}

#[test]
fn adamw_kernel_matches_native_over_steps() {
    let engine = backend();
    // multi-chunk length + odd tail, several optimizer steps
    let err = adagradselect::optimizer::hlo_adamw_parity(&engine, 70_000, 7, 4).unwrap();
    assert!(err < 2e-6, "max diff {err}");
}

#[test]
fn adamw_kernel_chunk_exact_multiple() {
    let engine = backend();
    let n = engine.manifest().chunk_size * 2;
    let err = adagradselect::optimizer::hlo_adamw_parity(&engine, n, 3, 2).unwrap();
    assert!(err < 2e-6, "max diff {err}");
}

#[test]
fn grad_norm_entry_matches_native() {
    let engine = backend();
    let exe = engine.load_shared_exe("grad_norm_sq").unwrap();
    let n = engine.manifest().chunk_size;
    let g: Vec<f32> = (0..n).map(|i| ((i % 31) as f32 - 15.0) * 0.05).collect();
    let buf = engine.upload_f32(&g, &[g.len()]).unwrap();
    let out = engine.execute_to_host(&exe, &[&buf]).unwrap();
    let kernel = out.scalar_f32(0).unwrap() as f64;
    let native = block_norm_sq(&g);
    assert!((kernel - native).abs() / native < 1e-5, "kernel {kernel} native {native}");
}

fn run_train_step(
    engine: &ReferenceBackend,
    entry: &str,
    seed: u64,
    tokens: &[i32],
    targets: &[i32],
) -> Vec<Vec<f32>> {
    let preset = engine.manifest().preset("test-tiny").unwrap().clone();
    let exe = engine.load_preset_exe("test-tiny", entry).unwrap();
    let state = ModelState::init(&preset.blocks, seed);
    let (b, s) = (preset.model.batch, preset.model.seq_len);
    assert_eq!(tokens.len(), b * s);
    let blocks: Vec<_> =
        state.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();
    let tok = engine.upload_i32(tokens, &[b, s]).unwrap();
    let tgt = engine.upload_i32(targets, &[b, s]).unwrap();
    let mut args: Vec<_> = blocks.iter().collect();
    args.push(&tok);
    args.push(&tgt);
    let out = engine.execute_to_host(&exe, &args).unwrap();
    (0..1 + preset.blocks.len()).map(|i| out.vec_f32(i).unwrap().to_vec()).collect()
}

#[test]
fn train_step_loss_starts_near_uniform() {
    let engine = backend();
    let preset = engine.manifest().preset("test-tiny").unwrap().clone();
    let (b, s) = (preset.model.batch, preset.model.seq_len);
    let tokens: Vec<i32> = (0..b * s).map(|i| 4 + (i % 50) as i32).collect();
    let out = run_train_step(&engine, "train_step", 0, &tokens, &tokens);
    let loss = out[0][0];
    // random init on vocab-64: CE ≈ ln(64) ≈ 4.16
    assert!((loss - 64f32.ln()).abs() < 0.6, "loss {loss}");
    // one grad per block, each with the block's numel
    assert_eq!(out.len(), 1 + preset.blocks.len());
    for (i, blk) in preset.blocks.iter().enumerate() {
        assert_eq!(out[1 + i].len(), blk.numel);
        let norm: f64 = out[1 + i].iter().map(|&x| (x as f64) * (x as f64)).sum();
        assert!(norm.is_finite() && norm > 0.0, "block {i} grad degenerate");
    }
}

#[test]
fn pallas_and_plain_entries_agree() {
    // The Pallas-attention entry must compute the same function as the
    // plain one — on the reference backend they share one implementation,
    // and this pins that contract for any future split.
    let engine = backend();
    let preset = engine.manifest().preset("test-tiny").unwrap().clone();
    let (b, s) = (preset.model.batch, preset.model.seq_len);
    let tokens: Vec<i32> = (0..b * s).map(|i| 4 + ((i * 7) % 50) as i32).collect();
    let targets: Vec<i32> = (0..b * s).map(|i| 4 + ((i * 11) % 50) as i32).collect();
    let a = run_train_step(&engine, "train_step", 42, &tokens, &targets);
    let c = run_train_step(&engine, "train_step_pallas", 42, &tokens, &targets);
    let max_diff = a
        .iter()
        .flatten()
        .zip(c.iter().flatten())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 5e-5, "pallas vs plain max diff {max_diff}");
}

#[test]
fn decode_step_logits_shape_and_causality() {
    let engine = backend();
    let preset = engine.manifest().preset("test-tiny").unwrap().clone();
    let exe = engine.load_preset_exe("test-tiny", "decode_step").unwrap();
    let state = ModelState::init(&preset.blocks, 0);
    let (b, s, v) = (preset.model.batch, preset.model.seq_len, preset.model.vocab);

    let run = |tokens: &[i32]| {
        let blocks: Vec<_> =
            state.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();
        let mut args: Vec<_> = blocks.iter().collect();
        let tok = engine.upload_i32(tokens, &[b, s]).unwrap();
        args.push(&tok);
        engine.execute_to_host(&exe, &args).unwrap().vec_f32(0).unwrap().to_vec()
    };
    let tokens: Vec<i32> = (0..b * s).map(|i| 4 + (i % 40) as i32).collect();
    let logits = run(&tokens);
    assert_eq!(logits.len(), b * s * v);

    // causality: flip the last token of row 0 — all logits before the
    // last position must be unchanged.
    let mut tokens2 = tokens.clone();
    tokens2[s - 1] = 5;
    let logits2 = run(&tokens2);
    let prefix = (s - 1) * v;
    let max_diff = logits[..prefix]
        .iter()
        .zip(&logits2[..prefix])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-5, "future token leaked into past logits: {max_diff}");
}

#[test]
fn eval_loss_matches_train_step_loss() {
    // the loss-only entry and the train entry must agree on the same batch
    let engine = backend();
    let preset = engine.manifest().preset("test-tiny").unwrap().clone();
    let (b, s) = (preset.model.batch, preset.model.seq_len);
    let tokens: Vec<i32> = (0..b * s).map(|i| 4 + ((i * 3) % 50) as i32).collect();
    let targets: Vec<i32> = (0..b * s).map(|i| 4 + ((i * 5) % 50) as i32).collect();
    let train_out = run_train_step(&engine, "train_step", 11, &tokens, &targets);

    let state = ModelState::init(&preset.blocks, 11);
    let exe = engine.load_preset_exe("test-tiny", "eval_loss").unwrap();
    let blocks: Vec<_> =
        state.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();
    let tok = engine.upload_i32(&tokens, &[b, s]).unwrap();
    let tgt = engine.upload_i32(&targets, &[b, s]).unwrap();
    let mut args: Vec<_> = blocks.iter().collect();
    args.push(&tok);
    args.push(&tgt);
    let eval = engine.execute_to_host(&exe, &args).unwrap().scalar_f32(0).unwrap();
    assert!((eval - train_out[0][0]).abs() < 1e-6, "{eval} vs {}", train_out[0][0]);
}

#[test]
fn manifest_covers_all_presets_and_entries() {
    let engine = backend();
    for name in ["test-tiny", "qwen-sim", "llama-sim", "phi-sim", "e2e"] {
        let p = engine.manifest().preset(name).unwrap();
        for entry in [
            "train_step",
            "train_step_masked",
            "train_step_fused",
            "train_step_lora",
            "eval_loss",
            "decode_step",
            "prefill",
            "decode_step_kv",
            "lora_merge",
        ] {
            p.artifact(entry).unwrap_or_else(|_| panic!("{name}/{entry} missing"));
            engine
                .load_preset_exe(name, entry)
                .unwrap_or_else(|_| panic!("{name}/{entry} does not load"));
        }
    }
    for shared in ["adamw_update", "adamw_update_inplace", "grad_norm_sq"] {
        engine
            .load_shared_exe(shared)
            .unwrap_or_else(|_| panic!("shared {shared} does not load"));
    }
    assert_eq!(engine.platform(), "reference-cpu");
}

#[test]
fn prefill_and_decode_kv_entries_match_decode_step() {
    // the stateless functional forms of the serving pair, through the
    // same `execute` interface a PJRT lowering would use: prefill a
    // prompt, take one KV decode step, and hold both logits rows against
    // the full-reforward `decode_step` oracle
    let engine = backend();
    let preset = engine.manifest().preset("test-tiny").unwrap().clone();
    let state = ModelState::init(&preset.blocks, 6);
    let (b, s, v) = (preset.model.batch, preset.model.seq_len, preset.model.vocab);
    let blocks: Vec<_> =
        state.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();

    let t = 7usize;
    let seq_tokens: Vec<i32> = (0..t + 1).map(|i| 4 + ((i * 5) % 40) as i32).collect();

    // oracle: full [b, s] reforward, rows beyond the sequence are pad-ish
    let mut full = seq_tokens.clone();
    full.resize(b * s, 4);
    let exe_decode = engine.load_preset_exe("test-tiny", "decode_step").unwrap();
    let tok = engine.upload_i32(&full, &[b, s]).unwrap();
    let mut args: Vec<_> = blocks.iter().collect();
    args.push(&tok);
    let oracle = engine.execute_to_host(&exe_decode, &args).unwrap().take_vec(0).unwrap();

    // prefill entry over the prompt prefix
    let exe_prefill = engine.load_preset_exe("test-tiny", "prefill").unwrap();
    let tok = engine.upload_i32(&seq_tokens[..t], &[1, t]).unwrap();
    let mut args: Vec<_> = blocks.iter().collect();
    args.push(&tok);
    let mut out = engine.execute_to_host(&exe_prefill, &args).unwrap();
    let logits = out.take_vec(0).unwrap();
    let k_cache = out.take_vec(1).unwrap();
    let v_cache = out.take_vec(2).unwrap();
    assert_eq!(logits.len(), v);
    assert_eq!(k_cache.len(), preset.model.n_layers * t * preset.model.d_model);
    let want = &oracle[(t - 1) * v..t * v];
    let diff = logits.iter().zip(want).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(diff < 1e-6, "prefill entry diverges from decode_step: {diff}");

    // decode_step_kv entry: feed the next token at position t. The
    // functional cache has capacity t, so grow it by one row per layer
    // first (the slot-pooled path pre-allocates instead).
    let plane = t * preset.model.d_model;
    let grow = |flat: &[f32]| -> Vec<f32> {
        let mut out = Vec::with_capacity(flat.len() + preset.model.n_layers * preset.model.d_model);
        for l in 0..preset.model.n_layers {
            out.extend_from_slice(&flat[l * plane..(l + 1) * plane]);
            out.resize(out.len() + preset.model.d_model, 0.0);
        }
        out
    };
    let exe_kv = engine.load_preset_exe("test-tiny", "decode_step_kv").unwrap();
    let k_grown = grow(&k_cache);
    let v_grown = grow(&v_cache);
    let k_buf = engine.upload_f32(&k_grown, &[k_grown.len()]).unwrap();
    let v_buf = engine.upload_f32(&v_grown, &[v_grown.len()]).unwrap();
    let tok = engine.upload_i32(&seq_tokens[t..t + 1], &[1]).unwrap();
    let pos = engine.upload_i32(&[t as i32], &[1]).unwrap();
    let mut args: Vec<_> = blocks.iter().collect();
    args.extend([&k_buf, &v_buf, &tok, &pos]);
    let mut out = engine.execute_to_host(&exe_kv, &args).unwrap();
    let logits = out.take_vec(0).unwrap();
    assert_eq!(logits.len(), v);
    let want = &oracle[t * v..(t + 1) * v];
    let diff = logits.iter().zip(want).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(diff < 1e-6, "decode_step_kv entry diverges from decode_step: {diff}");
}
