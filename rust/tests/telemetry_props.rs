//! Property tests for the observability layer: histogram quantile
//! accuracy against exact sorted quantiles, merge/feed equivalence, and
//! end-to-end determinism + export validity of the serve engine's
//! registry and tracer.

use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, ReferenceBackend};
use adagradselect::serve::{ServeConfig, ServeEngine};
use adagradselect::telemetry::hist::{LogHistogram, BUCKETS_PER_OCTAVE};
use adagradselect::util::json::Value;
use adagradselect::util::rng::Rng;

const PRESET: &str = "test-tiny";

/// The hand-sorted percentile the histogram is held to: rank
/// `floor((n-1)·q)` over the sorted samples.
fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
    sorted[((sorted.len() - 1) as f64 * q) as usize]
}

/// Log-uniform draws spanning 10^-6 .. 10^2 seconds — eight decades, the
/// realistic latency range, hitting many distinct buckets.
fn draws(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| 10f64.powf(rng.gen_range_f64(-6.0, 2.0))).collect()
}

#[test]
fn quantile_within_one_bucket_of_exact() {
    let mut rng = Rng::seed_from_u64(0xC0FFEE);
    for n in [1usize, 2, 7, 100, 1000] {
        let samples = draws(&mut rng, n);
        let mut h = LogHistogram::new();
        for &v in &samples {
            h.record(v);
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.0, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let e = exact_quantile(&sorted, q);
            let a = h.quantile(q);
            let width = LogHistogram::bucket_width(LogHistogram::bucket_index(e));
            assert!(
                (a - e).abs() <= width + 1e-12,
                "n={n} q={q}: hist {a} vs exact {e} (allowed width {width})"
            );
        }
        // the extremes are exact, not just bucket-accurate
        assert_eq!(h.quantile(0.0), sorted[0]);
        assert_eq!(h.quantile(1.0), sorted[n - 1]);
    }
}

#[test]
fn merge_equals_feeding_concatenation() {
    let mut rng = Rng::seed_from_u64(42);
    let xs = draws(&mut rng, 500);
    let ys = draws(&mut rng, 313);
    let (mut a, mut b, mut whole) =
        (LogHistogram::new(), LogHistogram::new(), LogHistogram::new());
    for &v in &xs {
        a.record(v);
        whole.record(v);
    }
    for &v in &ys {
        b.record(v);
        whole.record(v);
    }
    a.merge(&b);
    assert_eq!(a.counts(), whole.counts(), "bucket counts differ");
    assert_eq!(a.count(), whole.count());
    assert!((a.sum() - whole.sum()).abs() <= 1e-9 * whole.sum().abs());
    assert_eq!(a.min(), whole.min());
    assert_eq!(a.max(), whole.max());
    for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
        assert_eq!(a.quantile(q), whole.quantile(q), "quantile {q} differs");
    }
}

#[test]
fn count_and_sum_are_exact() {
    let mut rng = Rng::seed_from_u64(7);
    let samples = draws(&mut rng, 257);
    let mut h = LogHistogram::new();
    let mut sum = 0.0f64;
    for &v in &samples {
        h.record(v);
        sum += v;
    }
    assert_eq!(h.count(), samples.len() as u64);
    assert!((h.sum() - sum).abs() <= f64::EPSILON * sum.abs() * samples.len() as f64);
}

/// Deterministic prompt of `len` in-vocab tokens.
fn prompt(len: usize, salt: u64) -> Vec<i32> {
    (0..len).map(|i| 4 + ((i as u64 * 7 + salt * 13) % 50) as i32).collect()
}

fn run_workload<'e>(
    engine: &'e ReferenceBackend,
    state: &ModelState,
) -> (Vec<Vec<i32>>, ServeEngine<'e, ReferenceBackend>) {
    let mut srv = ServeEngine::new(
        engine,
        PRESET,
        state,
        ServeConfig { slots: 2, max_new_tokens: 6, kv_pages: 4, ..Default::default() },
    )
    .unwrap();
    srv.telemetry().enable_tracing(1 << 12);
    for i in 0..6u64 {
        srv.submit(prompt(12 + (i as usize % 3), i), 0, 0.0);
    }
    let mut responses = srv.run_until_idle().unwrap();
    responses.sort_by_key(|r| r.id);
    let tokens = responses.into_iter().map(|r| r.tokens).collect();
    (tokens, srv)
}

#[test]
fn serve_counters_are_deterministic_across_runs() {
    let engine = ReferenceBackend::new();
    let preset = engine.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 11);
    let (tok_a, srv_a) = run_workload(&engine, &state);
    let (tok_b, srv_b) = run_workload(&engine, &state);
    assert_eq!(tok_a, tok_b, "token streams must be bit-identical");
    // every counter (admissions, preemptions by tier, page/prefix
    // traffic, ...) and every histogram's sample count is replayable;
    // histogram *contents* are wallclock-valued and deliberately not
    // compared
    let (reg_a, reg_b) = (&srv_a.telemetry().registry, &srv_b.telemetry().registry);
    assert_eq!(reg_a.counters_snapshot(), reg_b.counters_snapshot());
    assert_eq!(reg_a.hist_counts(), reg_b.hist_counts());
}

#[test]
fn telemetry_disabled_is_output_invariant() {
    let engine = ReferenceBackend::new();
    let preset = engine.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 11);
    let (tok_on, _) = run_workload(&engine, &state);
    let mut srv = ServeEngine::new(
        &engine,
        PRESET,
        &state,
        ServeConfig { slots: 2, max_new_tokens: 6, kv_pages: 4, ..Default::default() },
    )
    .unwrap();
    srv.telemetry().set_enabled(false);
    for i in 0..6u64 {
        srv.submit(prompt(12 + (i as usize % 3), i), 0, 0.0);
    }
    let mut responses = srv.run_until_idle().unwrap();
    responses.sort_by_key(|r| r.id);
    let tok_off: Vec<Vec<i32>> = responses.into_iter().map(|r| r.tokens).collect();
    assert_eq!(tok_on, tok_off, "telemetry must never change model outputs");
}

#[test]
fn serve_exposition_and_trace_are_well_formed() {
    let engine = ReferenceBackend::new();
    let preset = engine.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 3);
    let (_, srv) = run_workload(&engine, &state);
    let tel = srv.telemetry();

    // exposition: TYPE lines, the advertised serve metric families, and
    // cumulative histogram bucket lines ending in +Inf
    let text = tel.registry.prometheus();
    for family in [
        "serve_admissions_total",
        "serve_decode_steps_total",
        "serve_kv_pages_allocated_total",
        "serve_ttft_seconds",
        "serve_itl_seconds",
    ] {
        assert!(text.contains(&format!("# TYPE {family} ")), "missing TYPE for {family}");
    }
    assert!(text.contains("serve_ttft_seconds_bucket{le=\"+Inf\"}"));
    let admissions: u64 = text
        .lines()
        .find(|l| l.starts_with("serve_admissions_total "))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
        .unwrap();
    assert!(admissions >= 6, "six requests were admitted at least once: {admissions}");

    // JSON snapshot parses and the percentile fields are ordered
    let snap = Value::parse(&tel.registry.snapshot().to_string()).unwrap();
    let ttft = snap.get("histograms").unwrap().get("serve_ttft_seconds").unwrap();
    assert_eq!(ttft.get("count").unwrap().as_u64().unwrap(), 6);
    let p50 = ttft.get("p50").unwrap().as_f64().unwrap();
    let p99 = ttft.get("p99").unwrap().as_f64().unwrap();
    assert!(p50 <= p99 && p50 > 0.0);

    // Chrome trace: parses, has spans of every serve phase, complete
    // events only, microsecond fields present
    let doc = Value::parse(&tel.tracer.chrome_trace().to_string()).unwrap();
    let events = match doc.get("traceEvents").unwrap() {
        Value::Arr(v) => v,
        other => panic!("traceEvents not an array: {other:?}"),
    };
    assert!(!events.is_empty());
    let mut names: Vec<String> = Vec::new();
    for e in events {
        assert_eq!(e.get("ph").unwrap().as_str().unwrap(), "X");
        assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
        assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        names.push(e.get("name").unwrap().as_str().unwrap().to_string());
    }
    for want in ["serve/step", "serve/admission", "serve/prefill", "serve/decode_step"] {
        assert!(names.iter().any(|n| n == want), "no {want} span in trace");
    }
}

/// The sharded trainer's communication telemetry is replayable: two runs
/// of the same config produce bit-identical `train_comm_*` gauges (the
/// collective's byte accounting is deterministic, not wallclock-shaped)
/// and identical step counters.
#[test]
fn sharded_train_comm_gauges_are_deterministic_across_runs() {
    use adagradselect::config::{Method, RunConfig};
    use adagradselect::train::ShardedTrainer;

    let run = || {
        let mut cfg = RunConfig::preset_defaults(PRESET);
        cfg.method = Method::TopK { pct: 30.0 };
        cfg.train.steps = 6;
        cfg.train.steps_per_epoch = 3;
        cfg.train.log_every = 0;
        let mut t = ShardedTrainer::new(cfg, 2).unwrap();
        for _ in 0..6 {
            t.step_once().unwrap();
        }
        t
    };
    let (a, b) = (run(), run());
    let (reg_a, reg_b) = (&a.telemetry().registry, &b.telemetry().registry);
    assert_eq!(reg_a.counters_snapshot(), reg_b.counters_snapshot());
    for name in [
        "train_comm_grad_gather_bytes",
        "train_comm_grad_bcast_bytes",
        "train_comm_norm_bcast_bytes",
        "train_comm_ctrl_bytes",
        "train_comm_allreduce_ops",
    ] {
        let ia = reg_a.gauge_by_name(name).unwrap_or_else(|| panic!("{name} not registered"));
        let ib = reg_b.gauge_by_name(name).unwrap_or_else(|| panic!("{name} not registered"));
        let (va, vb) = (reg_a.gauge_value(ia), reg_b.gauge_value(ib));
        assert_eq!(va, vb, "{name} must be replayable");
        assert!(va > 0.0, "{name} must observe traffic after 6 steps");
    }
    assert_eq!(a.comm_stats(), b.comm_stats(), "CommStats counters must be replayable");
}

/// One bucket spans a 2^(1/BUCKETS_PER_OCTAVE) factor — the resolution
/// contract the README advertises (~9%).
#[test]
fn bucket_resolution_is_about_nine_percent() {
    let step = 2f64.powf(1.0 / BUCKETS_PER_OCTAVE as f64);
    assert!((step - 1.0902).abs() < 1e-3);
    let i = LogHistogram::bucket_index(0.010);
    assert!(LogHistogram::bucket_lower(i) <= 0.010 && 0.010 < LogHistogram::bucket_upper(i));
}
