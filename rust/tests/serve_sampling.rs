//! Sampled serving and prefix-sharing contracts:
//!
//! * **Greedy degeneration** — a default (`temperature == 0`)
//!   `SamplingParams` request is token-for-token the greedy oracle;
//! * **Seeded reproducibility** — sampled output depends only on
//!   (request, seed): identical across slot counts, batch compositions
//!   and submission orders;
//! * **Stop sequences** — generation ends at the first matching tail and
//!   the matched run is trimmed from the output;
//! * **Prefix sharing** — requests with a common prompt stem prefill the
//!   stem once (the rest is served from the prefix cache), with outputs
//!   still equal to each request's isolated oracle — including the
//!   copy-on-write fork when a resubmitted prompt diverges mid-page;
//! * **Preemption** — on an overcommitted pool the page backstop preempts
//!   and later resumes running sequences, with greedy *and* seeded
//!   outputs bit-identical to an uninterrupted run, TTFT stamped at the
//!   first emission only, and no page leaked through the
//!   evict→requeue→finish churn;
//! * **NaN robustness** — NaN logits end a request cleanly instead of
//!   panicking the engine mid-batch.

use adagradselect::eval::Evaluator;
use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, RefTensor, ReferenceBackend};
use adagradselect::serve::{
    stop_len, Response, SamplingParams, ServeConfig, ServeEngine, ServeStats,
};

const PRESET: &str = "test-tiny";

fn engine() -> ReferenceBackend {
    ReferenceBackend::new()
}

/// Deterministic prompt of `len` in-vocab tokens.
fn prompt(len: usize, salt: u64) -> Vec<i32> {
    (0..len).map(|i| 4 + ((i as u64 * 7 + salt * 13) % 50) as i32).collect()
}

/// Per-request isolated greedy oracle outputs.
fn oracle_outputs(
    ev: &Evaluator<'_, ReferenceBackend>,
    device: &[RefTensor],
    prompts: &[Vec<i32>],
) -> Vec<Vec<i32>> {
    prompts
        .iter()
        .map(|p| ev.generate_oracle(device, std::slice::from_ref(p)).unwrap().remove(0))
        .collect()
}

/// Run `prompts` through a fresh engine, returning outputs by prompt
/// index. `params[i]` rides on prompt `i`; `order` permutes submission.
fn serve(
    backend: &ReferenceBackend,
    state: &ModelState,
    slots: usize,
    max_new: usize,
    prompts: &[Vec<i32>],
    params: &[SamplingParams],
    order: &[usize],
) -> (Vec<Vec<i32>>, adagradselect::serve::ServeStats) {
    let mut srv = ServeEngine::new(
        backend,
        PRESET,
        state,
        ServeConfig { slots, max_new_tokens: max_new, ..Default::default() },
    )
    .unwrap();
    let mut by_id = vec![usize::MAX; prompts.len()];
    for &pi in order {
        let id = srv.submit_sampled(prompts[pi].clone(), 0, 0.0, params[pi].clone());
        by_id[id as usize] = pi;
    }
    let responses = srv.run_until_idle().unwrap();
    assert_eq!(responses.len(), prompts.len(), "every request completes exactly once");
    let mut out = vec![Vec::new(); prompts.len()];
    let mut seen = vec![false; prompts.len()];
    for r in responses {
        let pi = by_id[r.id as usize];
        assert!(!seen[pi], "request {pi} completed twice");
        assert!(!r.truncated);
        seen[pi] = true;
        out[pi] = r.tokens;
    }
    (out, srv.stats())
}

/// Drive a (possibly page-constrained) engine to completion by manual
/// stepping, returning responses by prompt index, final stats, and the
/// engine-clock time of the first preemption (if any). All arrivals are
/// at t=0, so no idle fast-forward is needed; the step bound turns a
/// livelock bug into a test failure instead of a hang.
fn serve_steps(
    backend: &ReferenceBackend,
    state: &ModelState,
    cfg: ServeConfig,
    prompts: &[Vec<i32>],
    params: &[SamplingParams],
) -> (Vec<Response>, ServeStats, Option<f64>) {
    let mut srv = ServeEngine::new(backend, PRESET, state, cfg).unwrap();
    let mut by_id = vec![usize::MAX; prompts.len()];
    for (pi, p) in prompts.iter().enumerate() {
        let id = srv.submit_sampled(p.clone(), 0, 0.0, params[pi].clone());
        by_id[id as usize] = pi;
    }
    let mut responses: Vec<Option<Response>> = vec![None; prompts.len()];
    let mut first_preempt_s = None;
    for step in 0.. {
        assert!(step < 10_000, "engine stalled: preemption must preserve progress");
        if srv.is_idle() {
            break;
        }
        let before = srv.stats().n_preemptions;
        let done = srv.step().unwrap();
        if first_preempt_s.is_none() && srv.stats().n_preemptions > before {
            first_preempt_s = Some(srv.now_s());
        }
        for r in done {
            let pi = by_id[r.id as usize];
            assert!(responses[pi].is_none(), "request {pi} completed twice");
            assert!(!r.truncated);
            responses[pi] = Some(r);
        }
    }
    let stats = srv.stats();
    // page-leak cross-check: with every sequence drained, the only live
    // pages are the prefix cache's (one per entry); dropping the cache
    // must return the pool to empty with every slot free
    assert_eq!(
        srv.kv_pool().pages_in_use(),
        srv.prefix_cache().len(),
        "pages leaked past the prefix cache after preemption churn"
    );
    srv.clear_prefix_cache();
    assert_eq!(srv.kv_pool().pages_in_use(), 0, "cache clear must free every page");
    assert_eq!(srv.kv_pool().n_free(), cfg.slots, "a slot leaked");
    let responses =
        responses.into_iter().map(|r| r.expect("request never completed")).collect();
    (responses, stats, first_preempt_s)
}

/// Page-constrained configs that force the backstop: 31-token prompts
/// fill two pages minus one row, so every sequence claims its third page
/// two decode steps in — on a floor-sized pool the concurrent claims
/// cannot all fit.
const PRESSURE_PROMPT_LEN: usize = 31;

#[test]
fn preempted_greedy_decode_matches_the_uninterrupted_oracle() {
    let backend = engine();
    let preset = backend.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 13);
    let max_new = 8usize;
    let ev = Evaluator::new(&backend, PRESET, max_new).unwrap();
    let device = ev.upload_state(&state).unwrap();

    let mut total_preempts = 0u64;
    // three prompt sets x (slots, kv_pages) schedules: different victim
    // choices and resume interleavings, same per-request output
    for salt in [21u64, 25, 29] {
        let prompts: Vec<Vec<i32>> =
            (0..3).map(|i| prompt(PRESSURE_PROMPT_LEN, salt + i)).collect();
        let want = oracle_outputs(&ev, &device, &prompts);
        let params = vec![SamplingParams::default(); prompts.len()];
        for (slots, kv_pages) in [(2usize, 4usize), (2, 5), (3, 4)] {
            let cfg = ServeConfig {
                slots,
                max_new_tokens: max_new,
                kv_pages,
                ..Default::default()
            };
            let (responses, stats, _) =
                serve_steps(&backend, &state, cfg, &prompts, &params);
            let got: Vec<Vec<i32>> = responses.iter().map(|r| r.tokens.clone()).collect();
            assert_eq!(
                got, want,
                "salt {salt} slots {slots} kv_pages {kv_pages}: \
                 preemption changed greedy output"
            );
            total_preempts += stats.n_preemptions;
            let resumed: u32 = responses.iter().map(|r| r.n_preemptions).sum();
            assert_eq!(resumed as u64, stats.n_preemptions, "per-request counts drift");
        }
        // the same prompts on an unconstrained pool never preempt
        let cfg = ServeConfig { slots: 2, max_new_tokens: max_new, ..Default::default() };
        let (_, stats, at) = serve_steps(&backend, &state, cfg, &prompts, &params);
        assert_eq!(stats.n_preemptions, 0, "worst-case pool must never preempt");
        assert!(at.is_none());
    }
    assert!(
        total_preempts >= 1,
        "no schedule forced a preemption — the pressure configs are miscalibrated"
    );
}

#[test]
fn preempted_sampled_decode_is_bit_identical_to_uninterrupted() {
    let backend = engine();
    let preset = backend.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 13);
    let max_new = 8usize;

    let mut total_preempts = 0u64;
    for salt in [33u64, 37, 41] {
        let prompts: Vec<Vec<i32>> =
            (0..3).map(|i| prompt(PRESSURE_PROMPT_LEN, salt + i)).collect();
        let params: Vec<SamplingParams> = (0..3)
            .map(|i| SamplingParams {
                temperature: 0.9,
                top_k: 12,
                top_p: 0.95,
                seed: 500 + salt + i as u64,
                stop: Vec::new(),
            })
            .collect();
        // uninterrupted baseline: worst-case pool, same slot count
        let base_cfg = ServeConfig { slots: 2, max_new_tokens: max_new, ..Default::default() };
        let (base, base_stats, _) = serve_steps(&backend, &state, base_cfg, &prompts, &params);
        assert_eq!(base_stats.n_preemptions, 0);
        let cfg = ServeConfig {
            slots: 2,
            max_new_tokens: max_new,
            kv_pages: 4,
            ..Default::default()
        };
        let (got, stats, _) = serve_steps(&backend, &state, cfg, &prompts, &params);
        for pi in 0..prompts.len() {
            assert_eq!(
                got[pi].tokens, base[pi].tokens,
                "salt {salt} request {pi}: a resume re-entered the sampling \
                 stream at the wrong step"
            );
        }
        total_preempts += stats.n_preemptions;
    }
    assert!(total_preempts >= 1, "no sampled schedule forced a preemption");
}

#[test]
fn ttft_is_stamped_at_first_emission_never_at_resume() {
    let backend = engine();
    let preset = backend.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 13);
    let max_new = 8usize;

    let mut checked = 0usize;
    for salt in [21u64, 25, 29, 33] {
        let prompts: Vec<Vec<i32>> =
            (0..2).map(|i| prompt(PRESSURE_PROMPT_LEN, salt + i)).collect();
        let params = vec![SamplingParams::default(); prompts.len()];
        let cfg = ServeConfig {
            slots: 2,
            max_new_tokens: max_new,
            kv_pages: 4,
            ..Default::default()
        };
        let (responses, stats, first_preempt_s) =
            serve_steps(&backend, &state, cfg, &prompts, &params);
        if stats.n_preemptions == 0 {
            continue;
        }
        let t_preempt = first_preempt_s.expect("stats counted a preemption");
        for r in responses.iter().filter(|r| r.n_preemptions >= 1) {
            // the victim emitted its first token before it was preempted;
            // a requeue-time re-stamp would push first_token_s past the
            // preemption instant
            assert!(
                r.first_token_s <= t_preempt,
                "first_token_s was re-stamped on resume ({} > {t_preempt})",
                r.first_token_s
            );
            assert!(r.ttft_s() >= 0.0 && r.first_token_s >= r.arrival_s);
            assert!(
                r.finish_s >= t_preempt,
                "a preempted request can only finish after its preemption"
            );
            assert!(r.latency_s() >= r.ttft_s());
            checked += 1;
        }
    }
    assert!(checked >= 1, "no run preempted a request past its first token");
}

#[test]
// Under `--features audit` the engine's finite-logits probe traps NaN
// at the kernel boundary (by design), so graceful degradation cannot
// be observed; this test covers the production (audit-off) behavior.
#[cfg_attr(feature = "audit", ignore = "audit probes trap NaN logits before sampling")]
fn nan_logits_finish_requests_cleanly_instead_of_panicking() {
    let backend = engine();
    let preset = backend.manifest().preset(PRESET).unwrap().clone();
    // poison every weight: the forward pass yields all-NaN logits
    let mut state = ModelState::init(&preset.blocks, 3);
    for f in &mut state.flats {
        for x in f.iter_mut() {
            *x = f32::NAN;
        }
    }
    let mut srv = ServeEngine::new(
        &backend,
        PRESET,
        &state,
        ServeConfig { slots: 2, max_new_tokens: 6, ..Default::default() },
    )
    .unwrap();
    // both the sampled sort path and the greedy argmax path see the NaNs
    let sampled = srv.submit_sampled(
        prompt(5, 1),
        0,
        0.0,
        SamplingParams { temperature: 1.0, top_k: 4, ..Default::default() },
    );
    let greedy = srv.submit(prompt(7, 2), 0, 0.0);
    let responses = srv.run_until_idle().unwrap();
    assert_eq!(responses.len(), 2, "NaN rows must finish, not wedge the queue");
    for r in &responses {
        assert!(r.id == sampled || r.id == greedy);
        assert!(!r.truncated, "NaN poisoning is an empty generation, not a rejection");
        assert!(r.tokens.is_empty(), "an all-NaN row can emit nothing");
        assert!(r.finish_s >= r.arrival_s);
    }
}

#[test]
fn greedy_sampling_params_match_the_oracle() {
    let backend = engine();
    let preset = backend.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 3);
    let max_new = 8usize;
    let ev = Evaluator::new(&backend, PRESET, max_new).unwrap();
    let device = ev.upload_state(&state).unwrap();

    let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt(3 + 9 * i, i as u64)).collect();
    let want = oracle_outputs(&ev, &device, &prompts);
    let params = vec![SamplingParams::default(); prompts.len()];
    let order: Vec<usize> = (0..prompts.len()).collect();
    let (got, _) = serve(&backend, &state, 2, max_new, &prompts, &params, &order);
    assert_eq!(got, want, "temperature-0 sampling must be the greedy oracle");
}

#[test]
fn sampled_decode_is_reproducible_across_batch_compositions() {
    let backend = engine();
    let preset = backend.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 5);
    let max_new = 10usize;
    let vocab = preset.model.vocab as i32;
    let eos = backend.manifest().tokenizer.eos;

    let n = 6usize;
    let prompts: Vec<Vec<i32>> = (0..n).map(|i| prompt(4 + 5 * i, i as u64)).collect();
    let params: Vec<SamplingParams> = (0..n)
        .map(|i| SamplingParams {
            temperature: 0.8,
            top_k: 8,
            top_p: 0.95,
            seed: 100 + i as u64,
            stop: Vec::new(),
        })
        .collect();

    let fwd: Vec<usize> = (0..n).collect();
    let rev: Vec<usize> = (0..n).rev().collect();
    // one slot: strictly sequential; three slots: continuous batching with
    // churn; reversed: different batch-mates and slot assignments
    let (solo, _) = serve(&backend, &state, 1, max_new, &prompts, &params, &fwd);
    let (batched, _) = serve(&backend, &state, 3, max_new, &prompts, &params, &fwd);
    let (reversed, _) = serve(&backend, &state, 3, max_new, &prompts, &params, &rev);
    assert_eq!(solo, batched, "slot count must not change sampled output");
    assert_eq!(solo, reversed, "submission order must not change sampled output");
    for (pi, toks) in solo.iter().enumerate() {
        assert!(!toks.is_empty(), "request {pi} sampled nothing");
        assert!(toks.len() <= max_new);
        for &t in toks {
            assert!(t >= 0 && t < vocab && t != eos, "request {pi} emitted invalid {t}");
        }
    }
    // a different seed must actually change something somewhere
    let reseeded: Vec<SamplingParams> =
        params.iter().map(|p| SamplingParams { seed: p.seed + 777, ..p.clone() }).collect();
    let (other, _) = serve(&backend, &state, 3, max_new, &prompts, &reseeded, &fwd);
    assert_ne!(solo, other, "reseeding never changing output means the RNG is ignored");
}

#[test]
fn stop_sequences_trim_and_finish() {
    let backend = engine();
    let preset = backend.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 7);
    let max_new = 10usize;

    // learn the greedy continuation, then stop on a tail drawn from it
    let p = prompt(6, 3);
    let prompts = vec![p.clone()];
    let greedy = vec![SamplingParams::default()];
    let order = [0usize];
    let (full, _) = serve(&backend, &state, 1, max_new, &prompts, &greedy, &order);
    let w = &full[0];
    assert!(w.len() >= 3, "need a few greedy tokens to build a stop sequence");
    let stop = vec![w[1..3].to_vec()];

    // expected: greedy walk halted at the first matching tail, trimmed
    let mut want = Vec::new();
    for &t in w {
        want.push(t);
        if let Some(k) = stop_len(&want, &stop) {
            let keep = want.len() - k;
            want.truncate(keep);
            break;
        }
    }
    let stopped = vec![SamplingParams { stop: stop.clone(), ..Default::default() }];
    let (got, _) = serve(&backend, &state, 1, max_new, &prompts, &stopped, &order);
    assert_eq!(got[0], want, "stop sequence must trim the matched tail");
    assert!(got[0].len() < w.len(), "the stop must actually shorten the output");
}

#[test]
fn shared_prompt_stems_prefill_once_with_oracle_parity() {
    let backend = engine();
    let preset = backend.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 9);
    let max_new = 6usize;
    let ev = Evaluator::new(&backend, PRESET, max_new).unwrap();
    let device = ev.upload_state(&state).unwrap();
    let page = adagradselect::serve::DEFAULT_PAGE_SIZE;

    // 8 requests sharing a 24-token system-prompt stem, distinct suffixes
    let stem = prompt(24, 9);
    let n = 8usize;
    let prompts: Vec<Vec<i32>> = (0..n)
        .map(|i| {
            let mut p = stem.clone();
            p.extend(prompt(4, 40 + i as u64));
            p
        })
        .collect();
    let want = oracle_outputs(&ev, &device, &prompts);

    let params = vec![SamplingParams::default(); n];
    let order: Vec<usize> = (0..n).collect();
    let (got, stats) = serve(&backend, &state, 2, max_new, &prompts, &params, &order);
    assert_eq!(got, want, "prefix sharing must not change greedy output");

    // the stem's full page is prefilled by the first request only; every
    // later one serves it from the prefix cache
    let total: usize = prompts.iter().map(|p| p.len()).sum();
    assert_eq!(stats.prefix_hit_tokens, (n - 1) * page, "each follower hits the stem page");
    assert_eq!(stats.prefill_tokens, total - stats.prefix_hit_tokens);
    assert_eq!(stats.n_prefills as usize, n, "suffixes still prefill once each");
}

#[test]
fn resubmitted_prompts_fork_their_divergence_page_copy_on_write() {
    let backend = engine();
    let preset = backend.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 11);
    let max_new = 4usize;
    let ev = Evaluator::new(&backend, PRESET, max_new).unwrap();
    let device = ev.upload_state(&state).unwrap();
    let page = adagradselect::serve::DEFAULT_PAGE_SIZE;

    // a page-aligned prompt submitted twice: the rerun attaches both
    // cached pages but must fork the last one (its final row is re-run to
    // produce logits), writing without corrupting the cached copy
    let p_aligned = prompt(2 * page, 5);
    // and a mid-page prompt: the rerun attaches the full page and
    // prefills the partial tail into a fresh page (no fork needed)
    let p_partial = prompt(page + 4, 6);
    let prompts = vec![p_aligned.clone(), p_aligned, p_partial.clone(), p_partial];
    let want = oracle_outputs(&ev, &device, &prompts);

    let params = vec![SamplingParams::default(); prompts.len()];
    let order: Vec<usize> = (0..prompts.len()).collect();
    let (got, stats) = serve(&backend, &state, 1, max_new, &prompts, &params, &order);
    assert_eq!(got, want, "copy-on-write must not change greedy output");
    assert!(stats.cow_copies >= 1, "the aligned rerun must fork its last page");
    assert!(
        stats.prefix_hit_tokens >= (2 * page - 1) + page,
        "both reruns must hit the cache (got {} hit tokens)",
        stats.prefix_hit_tokens
    );
}
