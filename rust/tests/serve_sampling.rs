//! Sampled serving and prefix-sharing contracts:
//!
//! * **Greedy degeneration** — a default (`temperature == 0`)
//!   `SamplingParams` request is token-for-token the greedy oracle;
//! * **Seeded reproducibility** — sampled output depends only on
//!   (request, seed): identical across slot counts, batch compositions
//!   and submission orders;
//! * **Stop sequences** — generation ends at the first matching tail and
//!   the matched run is trimmed from the output;
//! * **Prefix sharing** — requests with a common prompt stem prefill the
//!   stem once (the rest is served from the prefix cache), with outputs
//!   still equal to each request's isolated oracle — including the
//!   copy-on-write fork when a resubmitted prompt diverges mid-page.

use adagradselect::eval::Evaluator;
use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, RefTensor, ReferenceBackend};
use adagradselect::serve::{stop_len, SamplingParams, ServeConfig, ServeEngine};

const PRESET: &str = "test-tiny";

fn engine() -> ReferenceBackend {
    ReferenceBackend::new()
}

/// Deterministic prompt of `len` in-vocab tokens.
fn prompt(len: usize, salt: u64) -> Vec<i32> {
    (0..len).map(|i| 4 + ((i as u64 * 7 + salt * 13) % 50) as i32).collect()
}

/// Per-request isolated greedy oracle outputs.
fn oracle_outputs(
    ev: &Evaluator<'_, ReferenceBackend>,
    device: &[RefTensor],
    prompts: &[Vec<i32>],
) -> Vec<Vec<i32>> {
    prompts
        .iter()
        .map(|p| ev.generate_oracle(device, std::slice::from_ref(p)).unwrap().remove(0))
        .collect()
}

/// Run `prompts` through a fresh engine, returning outputs by prompt
/// index. `params[i]` rides on prompt `i`; `order` permutes submission.
fn serve(
    backend: &ReferenceBackend,
    state: &ModelState,
    slots: usize,
    max_new: usize,
    prompts: &[Vec<i32>],
    params: &[SamplingParams],
    order: &[usize],
) -> (Vec<Vec<i32>>, adagradselect::serve::ServeStats) {
    let mut srv = ServeEngine::new(
        backend,
        PRESET,
        state,
        ServeConfig { slots, max_new_tokens: max_new },
    )
    .unwrap();
    let mut by_id = vec![usize::MAX; prompts.len()];
    for &pi in order {
        let id = srv.submit_sampled(prompts[pi].clone(), 0, 0.0, params[pi].clone());
        by_id[id as usize] = pi;
    }
    let responses = srv.run_until_idle().unwrap();
    assert_eq!(responses.len(), prompts.len(), "every request completes exactly once");
    let mut out = vec![Vec::new(); prompts.len()];
    let mut seen = vec![false; prompts.len()];
    for r in responses {
        let pi = by_id[r.id as usize];
        assert!(!seen[pi], "request {pi} completed twice");
        assert!(!r.truncated);
        seen[pi] = true;
        out[pi] = r.tokens;
    }
    (out, srv.stats())
}

#[test]
fn greedy_sampling_params_match_the_oracle() {
    let backend = engine();
    let preset = backend.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 3);
    let max_new = 8usize;
    let ev = Evaluator::new(&backend, PRESET, max_new).unwrap();
    let device = ev.upload_state(&state).unwrap();

    let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt(3 + 9 * i, i as u64)).collect();
    let want = oracle_outputs(&ev, &device, &prompts);
    let params = vec![SamplingParams::default(); prompts.len()];
    let order: Vec<usize> = (0..prompts.len()).collect();
    let (got, _) = serve(&backend, &state, 2, max_new, &prompts, &params, &order);
    assert_eq!(got, want, "temperature-0 sampling must be the greedy oracle");
}

#[test]
fn sampled_decode_is_reproducible_across_batch_compositions() {
    let backend = engine();
    let preset = backend.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 5);
    let max_new = 10usize;
    let vocab = preset.model.vocab as i32;
    let eos = backend.manifest().tokenizer.eos;

    let n = 6usize;
    let prompts: Vec<Vec<i32>> = (0..n).map(|i| prompt(4 + 5 * i, i as u64)).collect();
    let params: Vec<SamplingParams> = (0..n)
        .map(|i| SamplingParams {
            temperature: 0.8,
            top_k: 8,
            top_p: 0.95,
            seed: 100 + i as u64,
            stop: Vec::new(),
        })
        .collect();

    let fwd: Vec<usize> = (0..n).collect();
    let rev: Vec<usize> = (0..n).rev().collect();
    // one slot: strictly sequential; three slots: continuous batching with
    // churn; reversed: different batch-mates and slot assignments
    let (solo, _) = serve(&backend, &state, 1, max_new, &prompts, &params, &fwd);
    let (batched, _) = serve(&backend, &state, 3, max_new, &prompts, &params, &fwd);
    let (reversed, _) = serve(&backend, &state, 3, max_new, &prompts, &params, &rev);
    assert_eq!(solo, batched, "slot count must not change sampled output");
    assert_eq!(solo, reversed, "submission order must not change sampled output");
    for (pi, toks) in solo.iter().enumerate() {
        assert!(!toks.is_empty(), "request {pi} sampled nothing");
        assert!(toks.len() <= max_new);
        for &t in toks {
            assert!(t >= 0 && t < vocab && t != eos, "request {pi} emitted invalid {t}");
        }
    }
    // a different seed must actually change something somewhere
    let reseeded: Vec<SamplingParams> =
        params.iter().map(|p| SamplingParams { seed: p.seed + 777, ..p.clone() }).collect();
    let (other, _) = serve(&backend, &state, 3, max_new, &prompts, &reseeded, &fwd);
    assert_ne!(solo, other, "reseeding never changing output means the RNG is ignored");
}

#[test]
fn stop_sequences_trim_and_finish() {
    let backend = engine();
    let preset = backend.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 7);
    let max_new = 10usize;

    // learn the greedy continuation, then stop on a tail drawn from it
    let p = prompt(6, 3);
    let prompts = vec![p.clone()];
    let greedy = vec![SamplingParams::default()];
    let order = [0usize];
    let (full, _) = serve(&backend, &state, 1, max_new, &prompts, &greedy, &order);
    let w = &full[0];
    assert!(w.len() >= 3, "need a few greedy tokens to build a stop sequence");
    let stop = vec![w[1..3].to_vec()];

    // expected: greedy walk halted at the first matching tail, trimmed
    let mut want = Vec::new();
    for &t in w {
        want.push(t);
        if let Some(k) = stop_len(&want, &stop) {
            let keep = want.len() - k;
            want.truncate(keep);
            break;
        }
    }
    let stopped = vec![SamplingParams { stop: stop.clone(), ..Default::default() }];
    let (got, _) = serve(&backend, &state, 1, max_new, &prompts, &stopped, &order);
    assert_eq!(got[0], want, "stop sequence must trim the matched tail");
    assert!(got[0].len() < w.len(), "the stop must actually shorten the output");
}

#[test]
fn shared_prompt_stems_prefill_once_with_oracle_parity() {
    let backend = engine();
    let preset = backend.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 9);
    let max_new = 6usize;
    let ev = Evaluator::new(&backend, PRESET, max_new).unwrap();
    let device = ev.upload_state(&state).unwrap();
    let page = adagradselect::serve::DEFAULT_PAGE_SIZE;

    // 8 requests sharing a 24-token system-prompt stem, distinct suffixes
    let stem = prompt(24, 9);
    let n = 8usize;
    let prompts: Vec<Vec<i32>> = (0..n)
        .map(|i| {
            let mut p = stem.clone();
            p.extend(prompt(4, 40 + i as u64));
            p
        })
        .collect();
    let want = oracle_outputs(&ev, &device, &prompts);

    let params = vec![SamplingParams::default(); n];
    let order: Vec<usize> = (0..n).collect();
    let (got, stats) = serve(&backend, &state, 2, max_new, &prompts, &params, &order);
    assert_eq!(got, want, "prefix sharing must not change greedy output");

    // the stem's full page is prefilled by the first request only; every
    // later one serves it from the prefix cache
    let total: usize = prompts.iter().map(|p| p.len()).sum();
    assert_eq!(stats.prefix_hit_tokens, (n - 1) * page, "each follower hits the stem page");
    assert_eq!(stats.prefill_tokens, total - stats.prefix_hit_tokens);
    assert_eq!(stats.n_prefills as usize, n, "suffixes still prefill once each");
}

#[test]
fn resubmitted_prompts_fork_their_divergence_page_copy_on_write() {
    let backend = engine();
    let preset = backend.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 11);
    let max_new = 4usize;
    let ev = Evaluator::new(&backend, PRESET, max_new).unwrap();
    let device = ev.upload_state(&state).unwrap();
    let page = adagradselect::serve::DEFAULT_PAGE_SIZE;

    // a page-aligned prompt submitted twice: the rerun attaches both
    // cached pages but must fork the last one (its final row is re-run to
    // produce logits), writing without corrupting the cached copy
    let p_aligned = prompt(2 * page, 5);
    // and a mid-page prompt: the rerun attaches the full page and
    // prefills the partial tail into a fresh page (no fork needed)
    let p_partial = prompt(page + 4, 6);
    let prompts = vec![p_aligned.clone(), p_aligned, p_partial.clone(), p_partial];
    let want = oracle_outputs(&ev, &device, &prompts);

    let params = vec![SamplingParams::default(); prompts.len()];
    let order: Vec<usize> = (0..prompts.len()).collect();
    let (got, stats) = serve(&backend, &state, 1, max_new, &prompts, &params, &order);
    assert_eq!(got, want, "copy-on-write must not change greedy output");
    assert!(stats.cow_copies >= 1, "the aligned rerun must fork its last page");
    assert!(
        stats.prefix_hit_tokens >= (2 * page - 1) + page,
        "both reruns must hit the cache (got {} hit tokens)",
        stats.prefix_hit_tokens
    );
}
