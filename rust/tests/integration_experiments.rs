//! Integration: the experiment harness end-to-end at micro scale.
//!
//! Runs each paper-figure driver on `test-tiny` with a handful of steps to
//! prove the full pipeline (train → eval → CSV/markdown emission) holds
//! together; the real numbers come from `agsel exp … --steps 300` and are
//! recorded in EXPERIMENTS.md.

use std::path::PathBuf;

use adagradselect::config::Method;
use adagradselect::experiments::{run_method, ExpOptions};
use adagradselect::runtime::ReferenceBackend;

fn opts(tag: &str) -> ExpOptions {
    let out = std::env::temp_dir().join(format!("agsel-exp-{tag}-{}", std::process::id()));
    ExpOptions {
        artifacts_dir: PathBuf::from("artifacts"),
        out_dir: out,
        steps: 12,
        steps_per_epoch: 6,
        eval_problems: 8,
        seed: 0,
    }
}

#[test]
fn run_method_produces_full_result() {
    let opt = opts("rm");
    let engine = ReferenceBackend::new();
    let run = run_method(&engine, &opt, "test-tiny", Method::ags(30.0)).unwrap();
    assert_eq!(run.summary.steps, 12);
    assert!(run.summary.tail_loss.is_finite());
    assert!(run.gsm8k_acc >= 0.0 && run.math_acc >= 0.0);
    assert!(run.summary.sim_total_s > 0.0);
    std::fs::remove_dir_all(&opt.out_dir).ok();
}

#[test]
fn method_ladder_relative_properties() {
    // The three paper-shape properties that must hold *even at micro
    // scale* because they're structural, not learned:
    //  1. AGS uses less optimizer memory than FFT,
    //  2. LoRA simulated step time exceeds FFT's (adapter overhead),
    //  3. AGS simulated step time is below FFT's.
    let opt = opts("ladder");
    let engine = ReferenceBackend::new();
    let ags = run_method(&engine, &opt, "test-tiny", Method::ags(30.0)).unwrap();
    let fft = run_method(&engine, &opt, "test-tiny", Method::Full).unwrap();
    let lora = run_method(&engine, &opt, "test-tiny", Method::Lora { double_rank: false })
        .unwrap();
    assert!(ags.summary.memory.optimizer < fft.summary.memory.optimizer);
    assert!(ags.summary.memory.total() < fft.summary.memory.total());
    assert!(ags.summary.sim_total_s < fft.summary.sim_total_s);
    assert!(lora.summary.sim_total_s > fft.summary.sim_total_s);
    std::fs::remove_dir_all(&opt.out_dir).ok();
}

#[test]
fn csv_outputs_written() {
    let opt = opts("csv");
    let engine = ReferenceBackend::new();
    // fig3 micro-sweep over two points on test-tiny is the cheapest driver
    // that exercises CsvWriter + eval
    let rows = adagradselect::experiments::fig3_on(
        &engine,
        &opt,
        "test-tiny",
        &[30.0, 100.0],
    )
    .unwrap();
    assert_eq!(rows.len(), 2);
    let csv = std::fs::read_to_string(opt.out_dir.join("fig3_accuracy_vs_pct.csv")).unwrap();
    assert!(csv.lines().count() == 3, "{csv}");
    assert!(csv.starts_with("pct,"));
    std::fs::remove_dir_all(&opt.out_dir).ok();
}
