//! Property tests for the blocked GEMM kernels: every layout (`NN`,
//! `TN`, `NT`), with and without accumulate and scale, over randomized
//! shapes including ragged tails (m, k, n deliberately not multiples of
//! the register-tile or cache-block sizes), against the naive triple-loop
//! oracles that the pre-blocking reference backend used.
//!
//! k is capped at one depth block (`KC`) and operands are drawn from
//! [-0.5, 0.5], which keeps the two summation paths' rounding within a
//! few ulps — the max-abs-diff bound is a strict 1e-5.

use adagradselect::util::gemm::{gemm_nn, gemm_nt, gemm_tn, oracle, MC, MR, NR};
use adagradselect::util::rng::Rng;
use adagradselect::util::workspace::Workspace;

fn cases() -> u64 {
    std::env::var("AGSEL_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(50)
}

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gen_range_f64(-0.5, 0.5) as f32).collect()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| (x - y).abs()).fold(0.0, f32::max)
}

/// One randomized comparison of the blocked kernel against its oracle.
fn check_case(ws: &mut Workspace, rng: &mut Rng, seed: u64) {
    // shape menu: tiny degenerate, tile-exact, ragged, and block-crossing
    let m = match rng.gen_range(0, 4) {
        0 => rng.gen_range(1, 4),
        1 => MR * rng.gen_range(1, 9),            // exact MR multiples
        2 => MR * rng.gen_range(1, 9) + rng.gen_range(1, MR), // ragged tail
        _ => rng.gen_range(MC, 2 * MC + 3),       // crosses the MC row block
    };
    let k = match rng.gen_range(0, 3) {
        0 => rng.gen_range(1, 5),
        1 => rng.gen_range(5, 64),
        _ => rng.gen_range(64, 129),
    };
    let n = match rng.gen_range(0, 4) {
        0 => rng.gen_range(1, 4),
        1 => NR * rng.gen_range(1, 5),            // exact NR multiples
        2 => NR * rng.gen_range(1, 5) + rng.gen_range(1, NR), // ragged tail
        _ => rng.gen_range(1, 71),
    };
    let layout = rng.gen_range(0, 3);
    let acc = rng.gen_bool(0.5);
    let scale = match rng.gen_range(0, 4) {
        0 | 1 => 1.0f32,
        2 => 0.5,
        _ => -1.5,
    };

    let (a_len, b_len) = match layout {
        0 => (m * k, k * n), // NN
        1 => (k * m, k * n), // TN
        _ => (m * k, n * k), // NT
    };
    let a = rand_vec(rng, a_len);
    let b = rand_vec(rng, b_len);
    // acc mode starts from a shared random output; assign mode must
    // overwrite stale contents, so seed `got` with garbage
    let base = rand_vec(rng, m * n);
    let mut got = if acc { base.clone() } else { vec![f32::NAN; m * n] };
    let mut want = if acc { base } else { vec![0.0f32; m * n] };

    match layout {
        0 => {
            gemm_nn(ws, &mut got, &a, &b, m, k, n, scale, acc);
            oracle::matmul_nn(&mut want, &a, &b, m, k, n, scale, acc);
        }
        1 => {
            gemm_tn(ws, &mut got, &a, &b, m, k, n, scale, acc);
            oracle::matmul_tn(&mut want, &a, &b, m, k, n, scale, acc);
        }
        _ => {
            gemm_nt(ws, &mut got, &a, &b, m, k, n, scale, acc);
            oracle::matmul_nt(&mut want, &a, &b, m, k, n, scale, acc);
        }
    }
    let d = max_abs_diff(&got, &want);
    assert!(
        d <= 1e-5,
        "seed {seed}: layout {layout} m={m} k={k} n={n} scale={scale} acc={acc}: \
         max abs diff {d:.3e}"
    );
}

#[test]
fn prop_blocked_gemm_matches_naive_oracles() {
    let mut ws = Workspace::new();
    for seed in 0..cases() {
        let mut rng = Rng::seed_from_u64(0xb10c + seed);
        check_case(&mut ws, &mut rng, seed);
    }
}

#[test]
fn prop_parallel_path_matches_oracle() {
    // shapes big enough to cross the parallel fan-out threshold
    let mut ws = Workspace::new();
    for (seed, &(m, k, n)) in [(1024usize, 128usize, 24usize), (700, 96, 40)].iter().enumerate() {
        let mut rng = Rng::seed_from_u64(7000 + seed as u64);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut got = vec![0.0f32; m * n];
        let mut want = vec![0.0f32; m * n];
        gemm_nn(&mut ws, &mut got, &a, &b, m, k, n, 1.0, false);
        oracle::matmul_nn(&mut want, &a, &b, m, k, n, 1.0, false);
        let d = max_abs_diff(&got, &want);
        assert!(d <= 1e-5, "parallel ({m},{k},{n}): max abs diff {d:.3e}");
    }
}

#[test]
fn prop_unit_scale_single_block_is_bitwise_identical() {
    // scale=1, assign mode, k within one depth block: the blocked kernel
    // performs the exact same f32 operation sequence per output element
    // as the naive oracle, so results must match bit for bit
    let mut ws = Workspace::new();
    for seed in 0..cases().min(20) {
        let mut rng = Rng::seed_from_u64(0xe4ac7 + seed);
        let (m, k, n) = (rng.gen_range(1, 90), rng.gen_range(1, 129), rng.gen_range(1, 50));
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut got = vec![f32::NAN; m * n];
        let mut want = vec![0.0f32; m * n];
        gemm_nn(&mut ws, &mut got, &a, &b, m, k, n, 1.0, false);
        oracle::matmul_nn(&mut want, &a, &b, m, k, n, 1.0, false);
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "seed {seed}: ({m},{k},{n}) element {i}: {g} vs {w}"
            );
        }
    }
}

#[test]
fn prop_gemm_steady_state_is_allocation_free() {
    let mut ws = Workspace::new();
    let mut rng = Rng::seed_from_u64(99);
    let (m, k, n) = (96usize, 64usize, 48usize);
    let a = rand_vec(&mut rng, m * k);
    let b = rand_vec(&mut rng, k * n);
    let mut out = vec![0.0f32; m * n];
    gemm_nn(&mut ws, &mut out, &a, &b, m, k, n, 1.0, false);
    // prime a second, smaller shape so the pool holds mixed slab sizes
    let mut out2 = vec![0.0f32; 32 * 8];
    gemm_nn(&mut ws, &mut out2, &a[..32 * 16], &b[..16 * 8], 32, 16, 8, 1.0, false);
    let grows = ws.stats().grows;
    for _ in 0..10 {
        gemm_nn(&mut ws, &mut out, &a, &b, m, k, n, 1.0, false);
    }
    assert_eq!(ws.stats().grows, grows, "repeat GEMMs must recycle pack buffers");
}
