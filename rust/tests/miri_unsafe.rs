//! Miri-targeted exercises of every unsafe hot path, through public
//! APIs only.
//!
//! This file is the curated subset the `soundness` CI workflow runs
//! under Miri: small shapes (Miri is ~3 orders of magnitude slower than
//! native), no clocks, no filesystem — just the pointer discipline:
//!
//! ```text
//! MIRIFLAGS="-Zmiri-strict-provenance -Zmiri-num-cpus=4" \
//!     cargo +nightly miri test --test miri_unsafe
//! ```
//!
//! `-Zmiri-num-cpus=4` matters: Miri reports one CPU by default, which
//! would route `util::par` onto its serial path and leave the SendPtr
//! stripe-disjointness logic unexecuted. The flag makes the workers
//! actually spawn, so Miri's data-race detector sees the real
//! concurrent writes. `-Zmiri-strict-provenance` keeps the raw-pointer
//! arithmetic in `KvView` honest.
//!
//! These tests also run natively in the default lane (they are ordinary
//! `#[test]`s), where the new `debug_assert` disjointness rails in
//! `par_map` / `attention_bwd` fire on any overlap.

use adagradselect::model::forward::KvView;
use adagradselect::runtime::Backend;
use adagradselect::runtime::ReferenceBackend;
use adagradselect::serve::KvPool;
use adagradselect::util::gemm::{gemm_nn, gemm_tn, oracle};
use adagradselect::util::par::{par_for_each_index, par_for_each_mut, par_map};
use adagradselect::util::workspace::Workspace;

use std::sync::atomic::{AtomicU32, Ordering};

// ---------------------------------------------------------------------
// util::par — SendPtr stripes under real threads
// ---------------------------------------------------------------------

#[test]
fn par_map_matches_serial_map() {
    let items: Vec<u64> = (0..23).collect();
    let par: Vec<u64> = par_map(&items, |i, &x| x * x + i as u64);
    let ser: Vec<u64> = items.iter().enumerate().map(|(i, &x)| x * x + i as u64).collect();
    assert_eq!(par, ser);
}

#[test]
fn par_map_handles_empty_and_single() {
    let empty: Vec<u32> = par_map(&[] as &[u32], |_, &x| x);
    assert!(empty.is_empty());
    let one = par_map(&[7u32], |i, &x| x + i as u32);
    assert_eq!(one, vec![7]);
}

#[test]
fn par_for_each_mut_touches_every_item_once() {
    let mut xs: Vec<u64> = vec![0; 29];
    par_for_each_mut(&mut xs, |i, x| *x += i as u64 + 1);
    for (i, &x) in xs.iter().enumerate() {
        assert_eq!(x, i as u64 + 1);
    }
}

#[test]
fn par_for_each_index_counts_exactly_once() {
    let hits: Vec<AtomicU32> = (0..31).map(|_| AtomicU32::new(0)).collect();
    par_for_each_index(hits.len(), true, |i| {
        hits[i].fetch_add(1, Ordering::Relaxed);
    });
    for (i, h) in hits.iter().enumerate() {
        assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
    }
}

// ---------------------------------------------------------------------
// KvView / KvPool — raw-pointer paged cache access
// ---------------------------------------------------------------------

#[test]
fn kv_views_roundtrip_disjoint_slots() {
    let backend = ReferenceBackend::new();
    let model = backend.manifest().preset("test-tiny").unwrap().model.clone();
    let mut pool = KvPool::new(&model, 2);
    let d = model.n_heads * model.d_head;
    let rows = pool.page_size(); // one full page per slot
    let a = pool.alloc().unwrap();
    let b = pool.alloc().unwrap();
    pool.ensure_room(a, rows).unwrap();
    pool.ensure_room(b, rows).unwrap();

    let ka: Vec<f32> = (0..rows * d).map(|i| i as f32).collect();
    let kb: Vec<f32> = (0..rows * d).map(|i| -(i as f32)).collect();
    {
        let mut views = pool.views(&[a, b]).unwrap();
        views[0].write_rows(0, 0, &ka, &ka).unwrap();
        views[1].write_rows(0, 0, &kb, &kb).unwrap();
    }
    // re-view and read back: each slot sees only its own rows
    let views = pool.views(&[a, b]).unwrap();
    let mut got_k = vec![0.0f32; rows * d];
    let mut got_v = vec![0.0f32; rows * d];
    views[0].read_rows(0, rows, &mut got_k, &mut got_v).unwrap();
    assert_eq!(got_k, ka);
    views[1].read_rows(0, rows, &mut got_k, &mut got_v).unwrap();
    assert_eq!(got_k, kb);
    pool.release(a);
    pool.release(b);
}

#[test]
fn kv_view_contiguous_roundtrip() {
    let (n_layers, d, rows) = (2usize, 4usize, 3usize);
    let mut k = vec![0.0f32; n_layers * rows * d];
    let mut v = vec![0.0f32; n_layers * rows * d];
    let src_k: Vec<f32> = (0..rows * d).map(|i| 1.0 + i as f32).collect();
    let src_v: Vec<f32> = (0..rows * d).map(|i| -1.0 - i as f32).collect();
    let mut view = KvView::contiguous(&mut k, &mut v, n_layers, d, 0).unwrap();
    for layer in 0..n_layers {
        view.write_rows(layer, 0, &src_k, &src_v).unwrap();
    }
    let mut got_k = vec![0.0f32; rows * d];
    let mut got_v = vec![0.0f32; rows * d];
    for layer in 0..n_layers {
        view.read_rows(layer, rows, &mut got_k, &mut got_v).unwrap();
        assert_eq!(got_k, src_k, "layer {layer} K");
        assert_eq!(got_v, src_v, "layer {layer} V");
    }
}

// ---------------------------------------------------------------------
// workspace arena + gemm — slab reuse and the byte-cast kernels
// ---------------------------------------------------------------------

#[test]
fn workspace_reuse_stays_sound() {
    let mut ws = Workspace::new();
    let a = ws.take(64);
    assert_eq!(a.len(), 64);
    ws.give(a);
    let b = ws.take_zeroed(64); // reuses the slab, must come back zeroed
    assert!(b.iter().all(|&x| x == 0.0));
    ws.give(b);
    assert!(ws.audit_check().is_empty(), "{:?}", ws.audit_check());
}

#[test]
fn gemm_matches_oracle_on_small_shapes() {
    let mut ws = Workspace::new();
    let (m, k, n) = (3usize, 4usize, 5usize);
    let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
    let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.61).cos()).collect();

    let mut fast = vec![0.0f32; m * n];
    let mut slow = vec![0.0f32; m * n];
    gemm_nn(&mut ws, &mut fast, &a, &b, m, k, n, 1.0, false);
    oracle::matmul_nn(&mut slow, &a, &b, m, k, n, 1.0, false);
    for (x, y) in fast.iter().zip(&slow) {
        assert!((x - y).abs() <= 1e-5, "gemm_nn {x} vs oracle {y}");
    }

    // transposed-A variant: a is [k, m]
    let at: Vec<f32> = (0..k * m).map(|i| (i as f32 * 0.23).sin()).collect();
    let mut fast_t = vec![0.0f32; m * n];
    let mut slow_t = vec![0.0f32; m * n];
    gemm_tn(&mut ws, &mut fast_t, &at, &b, m, k, n, 1.0, false);
    oracle::matmul_tn(&mut slow_t, &at, &b, m, k, n, 1.0, false);
    for (x, y) in fast_t.iter().zip(&slow_t) {
        assert!((x - y).abs() <= 1e-5, "gemm_tn {x} vs oracle {y}");
    }
}
