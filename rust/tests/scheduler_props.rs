//! Scheduler admission properties, fuzzed over random queues:
//!
//! * **Never over-admits** — a batch never exceeds the free slots, and
//!   its summed page demand never exceeds the page budget (so a request
//!   whose prompt cannot be paged in is never started) — across random
//!   priority tiers;
//! * **Deterministic order among equals** — candidates with equal page
//!   demand are admitted in arrival order (ids as the final tiebreak);
//! * **No starvation under churn** — with an endless stream of short
//!   jobs and a budget that can only fit the long head alone, every
//!   request still completes within a bounded number of rounds;
//! * **Forward progress under preemption** — an engine on an
//!   overcommitted page pool drains every random workload (lengths,
//!   priorities, two arrival waves) within a bounded number of steps:
//!   preemption recycles work but can never live-lock or drop a request.

use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, ReferenceBackend};
use adagradselect::serve::scheduler::STARVATION_ROUNDS;
use adagradselect::serve::{
    Request, SamplingParams, Scheduler, ServeConfig, ServeEngine,
};
use adagradselect::util::rng::Rng;

/// Worst-case page demand mirroring the engine's closure: one page per
/// 16 tokens of prompt + generation budget, 0 for rejected prompts.
fn page_need(r: &Request) -> usize {
    if r.prompt.is_empty() || r.prompt.len() > 256 {
        0
    } else {
        (r.prompt.len() + r.max_new).min(256).div_ceil(16)
    }
}

#[test]
fn admission_never_exceeds_slots_or_page_budget() {
    let mut rng = Rng::seed_from_u64(0xC0FFEE);
    for trial in 0..200 {
        let mut s = Scheduler::new();
        let n = 1 + rng.gen_range(0, 12);
        for _ in 0..n {
            let len = rng.gen_range(0, 300); // includes empty + over-long
            let arrival = rng.gen_range(0, 10) as f64;
            let prio = rng.gen_range(0, 4) as u8;
            s.submit_prio(
                vec![7; len],
                1 + rng.gen_range(0, 32),
                arrival,
                prio,
                SamplingParams::default(),
            );
        }
        let mut admitted = 0usize;
        let mut rounds = 0usize;
        while s.n_pending() > 0 {
            let free_slots = 1 + rng.gen_range(0, 4);
            let budget = rng.gen_range(0, 40);
            let now = rng.gen_range(0, 12) as f64;
            let got = s.admit(now, free_slots, budget, &page_need);
            assert!(got.len() <= free_slots, "trial {trial}: admitted past free slots");
            let spent: usize = got.iter().map(page_need).sum();
            assert!(
                spent <= budget,
                "trial {trial}: admitted {spent} pages against a {budget}-page budget"
            );
            for r in &got {
                assert!(r.arrival_s <= now, "trial {trial}: admitted a future arrival");
            }
            admitted += got.len();
            rounds += 1;
            assert!(rounds < 10_000, "trial {trial}: queue never drained");
        }
        assert_eq!(admitted, n, "trial {trial}: requests were dropped or duplicated");
    }
}

#[test]
fn equal_demand_requests_keep_arrival_order() {
    let mut rng = Rng::seed_from_u64(9);
    for _ in 0..50 {
        let mut s = Scheduler::new();
        // same prompt length + max_new => identical page demand
        let n = 3 + rng.gen_range(0, 6);
        let ids: Vec<u64> =
            (0..n).map(|i| s.submit(vec![3; 20], 4, i as f64 * 0.25)).collect();
        let got = s.admit(100.0, n, usize::MAX, &page_need);
        assert_eq!(
            got.iter().map(|r| r.id).collect::<Vec<_>>(),
            ids,
            "equal-demand admission must preserve arrival order"
        );
    }
}

#[test]
fn churn_of_short_jobs_cannot_starve_a_long_request() {
    // budget of 4 pages; the long head needs all 4, short jobs need 1.
    // Keep two short jobs arriving per round — SJF alone would bypass the
    // head forever; the starvation guard must force it through.
    let mut s = Scheduler::new();
    let long = s.submit(vec![5; 60], 4, 0.0);
    let mut completed = Vec::new();
    let mut long_done_round = None;
    for round in 0..(4 * STARVATION_ROUNDS as usize) {
        s.submit(vec![5; 8], 8, 0.0);
        s.submit(vec![5; 8], 8, 0.0);
        for r in s.admit(1.0, 2, 4, &page_need) {
            if r.id == long {
                long_done_round = Some(round);
            }
            completed.push(r.id);
        }
        if long_done_round.is_some() {
            break;
        }
    }
    let round = long_done_round.expect("the long request starved");
    assert!(
        round <= STARVATION_ROUNDS as usize + 1,
        "head admitted only after {round} rounds"
    );
    // afterwards the queue drains normally
    while s.n_pending() > 0 {
        let got = s.admit(1.0, 4, 16, &page_need);
        assert!(!got.is_empty());
        completed.extend(got.iter().map(|r| r.id));
    }
    completed.sort_unstable();
    completed.dedup();
    assert_eq!(completed.len() as u64, s.n_submitted(), "every request completed once");
}

#[test]
fn overcommitted_engine_drains_every_random_workload() {
    // end-to-end forward progress: random prompt lengths, priorities and
    // a second arrival wave on a pool provisioned well below the
    // worst case. Preemption may recycle work indefinitely in principle —
    // the step bound asserts it cannot in practice, and the refcount
    // check asserts the churn leaks no page.
    let backend = ReferenceBackend::new();
    let state =
        ModelState::init(&backend.manifest().preset("test-tiny").unwrap().blocks, 17);
    let mut rng = Rng::seed_from_u64(0xBADD_CAFE);
    for trial in 0..4usize {
        let slots = 2 + rng.gen_range(0, 2);
        let kv_pages = 4 + rng.gen_range(0, 2);
        let mut srv = ServeEngine::new(
            &backend,
            "test-tiny",
            &state,
            ServeConfig { slots, max_new_tokens: 8, kv_pages, ..Default::default() },
        )
        .unwrap();
        let submit_wave = |srv: &mut ServeEngine<'_, ReferenceBackend>,
                           rng: &mut Rng,
                           at: f64,
                           n: usize| {
            for _ in 0..n {
                let len = 1 + rng.gen_range(0, 48);
                let p: Vec<i32> =
                    (0..len).map(|i| 4 + ((i * 7 + trial * 13) % 50) as i32).collect();
                srv.submit_prio(
                    p,
                    1 + rng.gen_range(0, 8),
                    at,
                    rng.gen_range(0, 3) as u8,
                    SamplingParams::default(),
                );
            }
        };
        let n_first = 4 + rng.gen_range(0, 4);
        submit_wave(&mut srv, &mut rng, 0.0, n_first);
        let mut n_done = 0usize;
        let mut second_wave = false;
        let mut total = n_first;
        for step in 0.. {
            assert!(step < 5_000, "trial {trial}: the engine live-locked");
            if srv.is_idle() {
                break;
            }
            n_done += srv.step().unwrap().len();
            if !second_wave && step >= 2 {
                second_wave = true;
                let n = 2 + rng.gen_range(0, 3);
                submit_wave(&mut srv, &mut rng, srv.now_s(), n);
                total += n;
            }
        }
        assert_eq!(n_done, total, "trial {trial}: requests dropped or duplicated");
        // drained: only prefix-cache references may hold pages
        assert_eq!(
            srv.kv_pool().pages_in_use(),
            srv.prefix_cache().len(),
            "trial {trial}: pages leaked after preemption churn"
        );
        srv.clear_prefix_cache();
        assert_eq!(srv.kv_pool().pages_in_use(), 0, "trial {trial}: cache held leaks");
        assert_eq!(srv.kv_pool().n_pages(), kv_pages, "the overcommit knob was ignored");
    }
}
