//! Scheduler admission properties, fuzzed over random queues:
//!
//! * **Never over-admits** — a batch never exceeds the free slots, and
//!   its summed page demand never exceeds the page budget (so a request
//!   whose prompt cannot be paged in is never started);
//! * **Deterministic order among equals** — candidates with equal page
//!   demand are admitted in arrival order (ids as the final tiebreak);
//! * **No starvation under churn** — with an endless stream of short
//!   jobs and a budget that can only fit the long head alone, every
//!   request still completes within a bounded number of rounds.

use adagradselect::serve::scheduler::STARVATION_ROUNDS;
use adagradselect::serve::{Request, Scheduler};
use adagradselect::util::rng::Rng;

/// Worst-case page demand mirroring the engine's closure: one page per
/// 16 tokens of prompt + generation budget, 0 for rejected prompts.
fn page_need(r: &Request) -> usize {
    if r.prompt.is_empty() || r.prompt.len() > 256 {
        0
    } else {
        (r.prompt.len() + r.max_new).min(256).div_ceil(16)
    }
}

#[test]
fn admission_never_exceeds_slots_or_page_budget() {
    let mut rng = Rng::seed_from_u64(0xC0FFEE);
    for trial in 0..200 {
        let mut s = Scheduler::new();
        let n = 1 + rng.gen_range(0, 12);
        for _ in 0..n {
            let len = rng.gen_range(0, 300); // includes empty + over-long
            let arrival = rng.gen_range(0, 10) as f64;
            s.submit(vec![7; len], 1 + rng.gen_range(0, 32), arrival);
        }
        let mut admitted = 0usize;
        let mut rounds = 0usize;
        while s.n_pending() > 0 {
            let free_slots = 1 + rng.gen_range(0, 4);
            let budget = rng.gen_range(0, 40);
            let now = rng.gen_range(0, 12) as f64;
            let got = s.admit(now, free_slots, budget, &page_need);
            assert!(got.len() <= free_slots, "trial {trial}: admitted past free slots");
            let spent: usize = got.iter().map(page_need).sum();
            assert!(
                spent <= budget,
                "trial {trial}: admitted {spent} pages against a {budget}-page budget"
            );
            for r in &got {
                assert!(r.arrival_s <= now, "trial {trial}: admitted a future arrival");
            }
            admitted += got.len();
            rounds += 1;
            assert!(rounds < 10_000, "trial {trial}: queue never drained");
        }
        assert_eq!(admitted, n, "trial {trial}: requests were dropped or duplicated");
    }
}

#[test]
fn equal_demand_requests_keep_arrival_order() {
    let mut rng = Rng::seed_from_u64(9);
    for _ in 0..50 {
        let mut s = Scheduler::new();
        // same prompt length + max_new => identical page demand
        let n = 3 + rng.gen_range(0, 6);
        let ids: Vec<u64> =
            (0..n).map(|i| s.submit(vec![3; 20], 4, i as f64 * 0.25)).collect();
        let got = s.admit(100.0, n, usize::MAX, &page_need);
        assert_eq!(
            got.iter().map(|r| r.id).collect::<Vec<_>>(),
            ids,
            "equal-demand admission must preserve arrival order"
        );
    }
}

#[test]
fn churn_of_short_jobs_cannot_starve_a_long_request() {
    // budget of 4 pages; the long head needs all 4, short jobs need 1.
    // Keep two short jobs arriving per round — SJF alone would bypass the
    // head forever; the starvation guard must force it through.
    let mut s = Scheduler::new();
    let long = s.submit(vec![5; 60], 4, 0.0);
    let mut completed = Vec::new();
    let mut long_done_round = None;
    for round in 0..(4 * STARVATION_ROUNDS as usize) {
        s.submit(vec![5; 8], 8, 0.0);
        s.submit(vec![5; 8], 8, 0.0);
        for r in s.admit(1.0, 2, 4, &page_need) {
            if r.id == long {
                long_done_round = Some(round);
            }
            completed.push(r.id);
        }
        if long_done_round.is_some() {
            break;
        }
    }
    let round = long_done_round.expect("the long request starved");
    assert!(
        round <= STARVATION_ROUNDS as usize + 1,
        "head admitted only after {round} rounds"
    );
    // afterwards the queue drains normally
    while s.n_pending() > 0 {
        let got = s.admit(1.0, 4, 16, &page_need);
        assert!(!got.is_empty());
        completed.extend(got.iter().map(|r| r.id));
    }
    completed.sort_unstable();
    completed.dedup();
    assert_eq!(completed.len() as u64, s.n_submitted(), "every request completed once");
}
