//! Integration tests for the step workspace arena wired through the
//! reference backend: steady-state execution must be allocation-free and
//! bit-deterministic across every entrypoint, the high-water mark must be
//! stable (no per-step ratchet), and the arena-backed path must agree
//! exactly with the one-shot public API that allocates a private arena.

use adagradselect::model::ModelState;
use adagradselect::model::forward;
use adagradselect::runtime::{Backend, ReferenceBackend};
use adagradselect::util::workspace::Workspace;

fn tokens_for(b: usize, s: usize) -> Vec<i32> {
    (0..b * s).map(|i| 4 + ((i * 7) % 45) as i32).collect()
}

/// Run a set of preset entrypoints once each; returns the raw outputs.
fn run_entries(engine: &ReferenceBackend, entries: &[&str]) -> Vec<Vec<Vec<f32>>> {
    let p = engine.manifest().preset("test-tiny").unwrap().clone();
    let (b, s) = (p.model.batch, p.model.seq_len);
    let state = ModelState::init(&p.blocks, 11);
    let lora = ModelState::init(&p.lora_blocks, 12);
    let base_bufs: Vec<_> =
        state.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();
    let lora_bufs: Vec<_> =
        lora.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();
    let tokens = tokens_for(b, s);
    let tok = engine.upload_i32(&tokens, &[b, s]).unwrap();

    let mut outs = Vec::new();
    for entry in entries {
        let exe = engine.load_preset_exe("test-tiny", entry).unwrap();
        let mut args: Vec<_> = base_bufs.iter().collect();
        if *entry == "train_step_lora" {
            args.extend(lora_bufs.iter());
        }
        args.push(&tok);
        if *entry != "decode_step" {
            args.push(&tok);
        }
        let out = engine.execute_to_host(&exe, &args).unwrap();
        outs.push(out.outputs);
    }
    outs
}

/// Entrypoints whose outputs are copied out of the arena: after warm-up
/// the mix must run with ZERO slab allocations and a frozen high-water
/// mark, while staying bit-deterministic.
#[test]
fn decode_free_entry_mix_is_exactly_steady() {
    const MIX: &[&str] = &["train_step", "eval_loss", "train_step_lora"];
    let engine = ReferenceBackend::new();
    let first = run_entries(&engine, MIX); // warm-up: slabs get allocated
    let warm = engine.workspace_stats();
    assert!(warm.high_water_bytes > 0);
    assert!(warm.grows > 0);
    for pass in 0..3 {
        let outs = run_entries(&engine, MIX);
        assert_eq!(outs, first, "pass {pass}: arena reuse must not change any output bit");
        let st = engine.workspace_stats();
        assert_eq!(st.grows, warm.grows, "pass {pass}: mix must be allocation-free");
        assert_eq!(
            st.high_water_bytes, warm.high_water_bytes,
            "pass {pass}: high-water mark must not creep"
        );
        assert_eq!(st.outstanding_bytes, 0, "pass {pass}: every buffer returned");
    }
}

/// `decode_step`'s logits leave the arena each call (disowned outputs are
/// the API boundary), so passes containing decode may refill the pool —
/// but the growth must stay bounded per pass and the high-water mark must
/// never exceed the warm peak (no ratchet).
#[test]
fn decode_outputs_leave_the_arena_without_ratchet() {
    const MIX: &[&str] = &["train_step", "eval_loss", "decode_step", "train_step_lora"];
    let engine = ReferenceBackend::new();
    let first = run_entries(&engine, MIX);
    let warm = engine.workspace_stats();
    let mut prev_grows = warm.grows;
    for pass in 0..4 {
        let outs = run_entries(&engine, MIX);
        assert_eq!(outs, first, "pass {pass}: outputs must stay bit-identical");
        let st = engine.workspace_stats();
        // at most the disowned-logits refill (plus one best-fit
        // substitution ripple) per pass
        assert!(
            st.grows - prev_grows <= 2,
            "pass {pass}: grew {} slabs, expected <= 2",
            st.grows - prev_grows
        );
        // best-fit substitution after a disown can momentarily serve a
        // request from a larger slab; allow that jitter but no ratchet
        assert!(
            st.high_water_bytes <= warm.high_water_bytes + warm.high_water_bytes / 10,
            "pass {pass}: high-water ratcheted {} -> {}",
            warm.high_water_bytes,
            st.high_water_bytes
        );
        assert_eq!(st.outstanding_bytes, 0, "pass {pass}: every buffer returned");
        prev_grows = st.grows;
    }
}

#[test]
fn train_step_alone_is_allocation_free_after_warmup() {
    let engine = ReferenceBackend::new();
    let p = engine.manifest().preset("test-tiny").unwrap().clone();
    let (b, s) = (p.model.batch, p.model.seq_len);
    let state = ModelState::init(&p.blocks, 3);
    let bufs: Vec<_> =
        state.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();
    let tokens = tokens_for(b, s);
    let tok = engine.upload_i32(&tokens, &[b, s]).unwrap();
    let exe = engine.load_preset_exe("test-tiny", "train_step").unwrap();
    let mut args: Vec<_> = bufs.iter().collect();
    args.push(&tok);
    args.push(&tok);

    engine.execute(&exe, &args).unwrap();
    let warm = engine.workspace_stats();
    for _ in 0..5 {
        engine.execute(&exe, &args).unwrap();
    }
    let steady = engine.workspace_stats();
    assert_eq!(steady.grows, warm.grows, "train_step must be slab-allocation-free when warm");
    assert_eq!(steady.high_water_bytes, warm.high_water_bytes);
    assert!(steady.takes > warm.takes, "the arena is actually being used");
}

#[test]
fn shared_arena_matches_one_shot_api_bitwise() {
    let engine = ReferenceBackend::new();
    let p = engine.manifest().preset("test-tiny").unwrap().clone();
    let (b, s) = (p.model.batch, p.model.seq_len);
    let state = ModelState::init(&p.blocks, 21);
    let flats: Vec<&[f32]> = state.flats.iter().map(|f| f.as_slice()).collect();
    let tokens = tokens_for(b, s);

    // one-shot API: private arena per call
    let (loss_one, grads_one) =
        forward::train_step(&p.model, &p.blocks, &flats, &tokens, &tokens, 0).unwrap();
    // shared arena, called twice (second call runs on recycled slabs)
    let mut ws = Workspace::new();
    let (l1, g1) =
        forward::train_step_in(&mut ws, &p.model, &p.blocks, &flats, &tokens, &tokens, 0).unwrap();
    let (l2, g2) =
        forward::train_step_in(&mut ws, &p.model, &p.blocks, &flats, &tokens, &tokens, 0).unwrap();
    assert_eq!(loss_one.to_bits(), l1.to_bits());
    assert_eq!(l1.to_bits(), l2.to_bits());
    assert_eq!(grads_one, g1);
    assert_eq!(g1, g2);

    let el =
        forward::eval_loss_in(&mut ws, &p.model, &p.blocks, &flats, &tokens, &tokens, 0).unwrap();
    let el_one = forward::eval_loss(&p.model, &p.blocks, &flats, &tokens, &tokens, 0).unwrap();
    assert_eq!(el.to_bits(), el_one.to_bits());

    let dl =
        forward::decode_logits_in(&mut ws, &p.model, &p.blocks, &flats, &tokens).unwrap();
    let dl_one = forward::decode_logits(&p.model, &p.blocks, &flats, &tokens).unwrap();
    assert_eq!(dl, dl_one);
}

#[test]
fn workspace_public_api_contract() {
    let mut ws = Workspace::new();
    let a = ws.take(1000);
    assert_eq!(a.len(), 1000);
    let z = ws.take_zeroed(500);
    assert!(z.iter().all(|&x| x == 0.0));
    let peak = ws.stats().high_water_bytes;
    assert_eq!(peak, (a.capacity() + z.capacity()) * 4);
    ws.give(a);
    ws.give(z);
    assert_eq!(ws.stats().outstanding_bytes, 0);
    assert_eq!(ws.stats().high_water_bytes, peak);
    assert_eq!(ws.stats().grows, 2);
    // recycled takes do not grow
    let b = ws.take(900);
    assert_eq!(ws.stats().grows, 2);
    ws.give(b);
}
