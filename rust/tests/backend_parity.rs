//! Backend parity: the pure-Rust reference executor against golden values
//! lowered from the JAX reference (`python/compile/model.py` with the
//! `kernels/ref.py` semantics), plus selector-determinism contracts.
//!
//! `rust/tests/fixtures/golden_test_tiny.json` is produced by
//! `scripts/gen_golden.py`, which ports the coordinator's seeded init
//! bit-exactly and then drives the JAX model: if the reference backend's
//! fwd/bwd or AdamW drifted from the paper's math, the 24-step loss
//! trajectory here would catch it at 1e-4.

use adagradselect::model::ModelState;
use adagradselect::optimizer::{AdamWParams, SelectiveAdamW};
use adagradselect::runtime::{Backend, ReferenceBackend};
use adagradselect::selection::grad_norm::block_norm;
use adagradselect::selection::{
    AdaGradSelect, AdaGradSelectParams, SelectionCtx, SelectionStrategy, TopKSelector,
};
use adagradselect::util::json::Value;

fn fixture() -> Value {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/golden_test_tiny.json"
    );
    let text = std::fs::read_to_string(path).expect("golden fixture present");
    Value::parse(&text).expect("golden fixture parses")
}

fn f64_arr(v: &Value) -> Vec<f64> {
    v.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect()
}

fn i32_arr(v: &Value) -> Vec<i32> {
    v.as_arr().unwrap().iter().map(|x| x.as_i64().unwrap() as i32).collect()
}

fn usize_arr(v: &Value) -> Vec<usize> {
    v.as_arr().unwrap().iter().map(|x| x.as_usize().unwrap()).collect()
}

#[test]
fn golden_loss_trajectory_matches_jax_reference() {
    let fix = fixture();
    let traj = fix.get("trajectory").unwrap();
    let steps = traj.get("steps").unwrap().as_usize().unwrap();
    let seed = traj.get("seed").unwrap().as_u64().unwrap();
    let lr = traj.get("lr").unwrap().as_f64().unwrap() as f32;
    let tokens = i32_arr(traj.get("tokens").unwrap());
    let targets = i32_arr(traj.get("targets").unwrap());
    let golden_losses = f64_arr(traj.get("losses").unwrap());
    let golden_norms = f64_arr(traj.get("grad_norms_step0").unwrap());
    assert_eq!(golden_losses.len(), steps);

    let engine = ReferenceBackend::new();
    let preset = engine.manifest().preset("test-tiny").unwrap().clone();
    let (b, s) = (preset.model.batch, preset.model.seq_len);
    assert_eq!(tokens.len(), b * s);
    let exe = engine.load_preset_exe("test-tiny", "train_step").unwrap();

    let mut state = ModelState::init(&preset.blocks, seed);
    let numels = preset.block_numels();
    let mut opt = SelectiveAdamW::new(&numels, AdamWParams::default());
    let all: Vec<usize> = (0..numels.len()).collect();
    let tok = engine.upload_i32(&tokens, &[b, s]).unwrap();
    let tgt = engine.upload_i32(&targets, &[b, s]).unwrap();

    let mut max_diff = 0.0f64;
    for t in 0..steps {
        let blocks: Vec<_> =
            state.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();
        let mut args: Vec<_> = blocks.iter().collect();
        args.push(&tok);
        args.push(&tgt);
        let mut out = engine.execute_to_host(&exe, &args).unwrap();
        let loss = out.scalar_f32(0).unwrap() as f64;
        let diff = (loss - golden_losses[t]).abs();
        max_diff = max_diff.max(diff);
        assert!(
            diff <= 1e-4,
            "step {t}: reference loss {loss:.6} vs jax golden {:.6} (diff {diff:.2e})",
            golden_losses[t]
        );

        let grads: Vec<Vec<f32>> =
            (0..numels.len()).map(|i| out.take_vec(1 + i).unwrap()).collect();
        if t == 0 {
            for (i, g) in grads.iter().enumerate() {
                let norm = block_norm(g);
                let rel = (norm - golden_norms[i]).abs() / golden_norms[i].max(1e-9);
                assert!(
                    rel <= 1e-4,
                    "block {i} grad norm {norm:.6} vs golden {:.6} (rel {rel:.2e})",
                    golden_norms[i]
                );
            }
        }
        opt.update_selected(&all, &mut state.flats, &grads, lr);
    }
    // the trajectory must actually train, not just match
    assert!(
        golden_losses[steps - 1] < golden_losses[0] - 0.5,
        "golden trajectory is not decreasing"
    );
    eprintln!("golden trajectory max |Δloss| = {max_diff:.2e} over {steps} steps");
}

#[test]
fn topk_selector_matches_reference_fixture() {
    let fix = fixture();
    let sel = fix.get("selectors").unwrap();
    let n = sel.get("n_blocks").unwrap().as_usize().unwrap();
    let k = sel.get("k").unwrap().as_usize().unwrap();
    let norms: Vec<Vec<f64>> =
        sel.get("norms").unwrap().as_arr().unwrap().iter().map(f64_arr).collect();
    let expected: Vec<Vec<usize>> = sel
        .get("topk_selected")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(usize_arr)
        .collect();

    let mut topk = TopKSelector::new(n, k);
    for (step, (row, want)) in norms.iter().zip(&expected).enumerate() {
        let got = topk.select(&SelectionCtx { step: step as u64, epoch: 1, grad_norms: row });
        assert_eq!(&got, want, "step {step}");
    }
}

#[test]
fn adagrad_select_matches_reference_fixture() {
    let fix = fixture();
    let sel = fix.get("selectors").unwrap();
    let n = sel.get("n_blocks").unwrap().as_usize().unwrap();
    let k = sel.get("k").unwrap().as_usize().unwrap();
    let spe = sel.get("steps_per_epoch").unwrap().as_u64().unwrap();
    let seed = sel.get("ags_seed").unwrap().as_u64().unwrap();
    let norms: Vec<Vec<f64>> =
        sel.get("norms").unwrap().as_arr().unwrap().iter().map(f64_arr).collect();
    let expected: Vec<Vec<usize>> = sel
        .get("ags_selected")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(usize_arr)
        .collect();

    let mut params = AdaGradSelectParams::new(k, spe);
    params.seed = seed;
    let mut ags = AdaGradSelect::new(n, params);
    for (step, (row, want)) in norms.iter().zip(&expected).enumerate() {
        let got = ags.select(&SelectionCtx {
            step: step as u64,
            epoch: 1 + (step as u64 / spe) as u32,
            grad_norms: row,
        });
        assert_eq!(
            &got, want,
            "step {step}: Rust bandit diverged from the reference sampling stack"
        );
    }
}

#[test]
fn identical_grad_norms_give_identical_selections_across_code_paths() {
    // Run the same batch through the reference backend twice: gradients,
    // norms, and therefore both selectors' picks must be bit-identical —
    // the "same selection on either code path" contract the PJRT engine
    // is held to as well (its artifact path is exercised under --features
    // pjrt on artifact-equipped hosts).
    let engine = ReferenceBackend::new();
    let preset = engine.manifest().preset("test-tiny").unwrap().clone();
    let (b, s) = (preset.model.batch, preset.model.seq_len);
    let tokens: Vec<i32> = (0..b * s).map(|i| 4 + ((i * 13) % 50) as i32).collect();
    let exe = engine.load_preset_exe("test-tiny", "train_step").unwrap();
    let state = ModelState::init(&preset.blocks, 3);

    let norms_of = || {
        let blocks: Vec<_> =
            state.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();
        let tok = engine.upload_i32(&tokens, &[b, s]).unwrap();
        let mut args: Vec<_> = blocks.iter().collect();
        args.push(&tok);
        args.push(&tok);
        let out = engine.execute_to_host(&exe, &args).unwrap();
        (0..preset.blocks.len())
            .map(|i| block_norm(out.vec_f32(1 + i).unwrap()))
            .collect::<Vec<f64>>()
    };
    let a = norms_of();
    let c = norms_of();
    assert_eq!(a, c, "reference backend grads must be deterministic");

    let n = a.len();
    let ctx = SelectionCtx { step: 0, epoch: 1, grad_norms: &a };
    let ctx2 = SelectionCtx { step: 0, epoch: 1, grad_norms: &c };
    let mut t1 = TopKSelector::new(n, 2);
    let mut t2 = TopKSelector::new(n, 2);
    assert_eq!(t1.select(&ctx), t2.select(&ctx2));
    let mut p = AdaGradSelectParams::new(2, 10);
    p.seed = 99;
    let mut a1 = AdaGradSelect::new(n, p.clone());
    let mut a2 = AdaGradSelect::new(n, p);
    for step in 0..20u64 {
        let c1 = SelectionCtx { step, epoch: 1 + (step / 10) as u32, grad_norms: &a };
        let c2 = SelectionCtx { step, epoch: 1 + (step / 10) as u32, grad_norms: &c };
        assert_eq!(a1.select(&c1), a2.select(&c2), "step {step}");
    }
}
