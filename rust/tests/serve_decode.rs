//! Serving-path contracts:
//!
//! * **Decode parity** — KV-cached greedy decode is token-for-token
//!   identical to the retained full-reforward oracle
//!   (`Evaluator::generate_oracle`) on the `test-tiny` golden preset;
//! * **Scheduler properties** — random arrivals and slot churn never mix
//!   rows or drop requests, and each request's output is independent of
//!   arrival interleaving;
//! * **Steady-state allocations** — repeated decode steps through the
//!   backend's warm workspace arena perform zero slab allocations.

use adagradselect::data::Problem;
use adagradselect::eval::Evaluator;
use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, RefTensor, ReferenceBackend};
use adagradselect::serve::{KvBackend, KvPool, ServeConfig, ServeEngine};
use adagradselect::util::rng::Rng;

const PRESET: &str = "test-tiny";

fn engine() -> ReferenceBackend {
    ReferenceBackend::new()
}

/// Deterministic prompt of `len` in-vocab tokens.
fn prompt(len: usize, salt: u64) -> Vec<i32> {
    (0..len).map(|i| 4 + ((i as u64 * 7 + salt * 13) % 50) as i32).collect()
}

#[test]
fn kv_generate_matches_oracle_token_for_token() {
    let engine = engine();
    let state = ModelState::init(
        &engine.manifest().preset(PRESET).unwrap().blocks,
        3,
    );
    let ev = Evaluator::new(&engine, PRESET, 16).unwrap();
    let device = ev.upload_state(&state).unwrap();
    let s = engine.manifest().preset(PRESET).unwrap().model.seq_len;

    // varied lengths, including a full-context prompt (nothing to
    // generate) and an over-long one (skipped by both paths)
    let lengths = [1usize, 3, 9, 30, s - 1, s, s + 8];
    for chunk in lengths.chunks(4) {
        // the oracle runs one preset batch at a time
        let prompts: Vec<Vec<i32>> =
            chunk.iter().enumerate().map(|(i, &l)| prompt(l, i as u64)).collect();
        let cached = ev.generate(&device, &prompts).unwrap();
        let oracle = ev.generate_oracle(&device, &prompts).unwrap();
        assert_eq!(
            cached, oracle,
            "KV-cached decode diverged from the reforward oracle for lengths {chunk:?}"
        );
    }
}

/// Per-request oracle outputs keyed by prompt, for the engine tests.
fn oracle_outputs(
    ev: &Evaluator<'_, ReferenceBackend>,
    device: &[RefTensor],
    prompts: &[Vec<i32>],
) -> Vec<Vec<i32>> {
    prompts
        .iter()
        .map(|p| ev.generate_oracle(device, std::slice::from_ref(p)).unwrap().remove(0))
        .collect()
}

#[test]
fn serve_engine_never_mixes_rows_and_is_interleaving_independent() {
    let engine = engine();
    let preset = engine.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 5);
    let max_new = 8usize;
    let ev = Evaluator::new(&engine, PRESET, max_new).unwrap();
    let device = ev.upload_state(&state).unwrap();

    // 12 requests over 3 slots forces mid-decode admission (slot churn)
    let mut rng = Rng::seed_from_u64(41);
    let prompts: Vec<Vec<i32>> =
        (0..12).map(|i| prompt(1 + rng.gen_range(0, preset.model.seq_len - 1), i)).collect();
    let want = oracle_outputs(&ev, &device, &prompts);

    // interleaving A: submission order; interleaving B: reversed order
    // (same arrival time ⇒ reversed admission, different batch-mates and
    // slot assignments throughout)
    for reversed in [false, true] {
        let mut srv = ServeEngine::new(
            &engine,
            PRESET,
            &state,
            ServeConfig { slots: 3, max_new_tokens: max_new, ..Default::default() },
        )
        .unwrap();
        let order: Vec<usize> =
            if reversed { (0..12).rev().collect() } else { (0..12).collect() };
        // id -> prompt index
        let mut by_id = vec![usize::MAX; 12];
        for &pi in &order {
            let id = srv.submit(prompts[pi].clone(), 0, 0.0);
            by_id[id as usize] = pi;
        }
        let responses = srv.run_until_idle().unwrap();
        assert_eq!(responses.len(), 12, "every request completes exactly once");
        let mut seen = vec![false; 12];
        for r in &responses {
            let pi = by_id[r.id as usize];
            assert!(!seen[pi], "request {pi} completed twice");
            seen[pi] = true;
            assert!(!r.truncated);
            assert_eq!(
                r.tokens, want[pi],
                "request {pi} (reversed={reversed}) diverged from its isolated oracle"
            );
            assert!(r.finish_s >= r.first_token_s && r.first_token_s >= r.arrival_s);
        }
        assert!(seen.iter().all(|&x| x), "no request may be dropped");
        let stats = srv.stats();
        assert_eq!(stats.n_prefills, 12);
        assert!(stats.peak_active <= 3, "never more sequences than slots");
        assert!(stats.kv_bytes > 0);
    }
}

#[test]
fn serve_engine_respects_staggered_arrivals() {
    let engine = engine();
    let state =
        ModelState::init(&engine.manifest().preset(PRESET).unwrap().blocks, 7);
    let mut srv = ServeEngine::new(
        &engine,
        PRESET,
        &state,
        ServeConfig { slots: 2, max_new_tokens: 4, ..Default::default() },
    )
    .unwrap();
    // one immediate, one far-future arrival: the idle engine must
    // fast-forward its clock rather than dropping or reordering
    srv.submit(prompt(5, 0), 0, 0.0);
    srv.submit(prompt(5, 1), 0, 3600.0);
    let responses = srv.run_until_idle().unwrap();
    assert_eq!(responses.len(), 2);
    assert!(srv.is_idle());
    assert!(srv.now_s() >= 3600.0, "clock fast-forwarded across the idle gap");
    let late = responses.iter().find(|r| r.arrival_s == 3600.0).unwrap();
    assert!(late.ttft_s() < 3600.0, "ttft measured from arrival, not engine start");
}

#[test]
fn truncated_and_empty_prompts_are_flagged_not_scored() {
    let engine = engine();
    let preset = engine.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 9);
    let mut srv = ServeEngine::new(
        &engine,
        PRESET,
        &state,
        ServeConfig { slots: 2, max_new_tokens: 4, ..Default::default() },
    )
    .unwrap();
    let long = srv.submit(prompt(preset.model.seq_len + 40, 0), 0, 0.0);
    let empty = srv.submit(Vec::new(), 0, 0.0);
    let ok = srv.submit(prompt(6, 1), 0, 0.0);
    let responses = srv.run_until_idle().unwrap();
    assert_eq!(responses.len(), 3);
    for r in &responses {
        if r.id == long || r.id == empty {
            assert!(r.truncated, "over-long/empty prompts must be flagged");
            assert!(r.tokens.is_empty());
        } else {
            assert_eq!(r.id, ok);
            assert!(!r.truncated);
        }
    }

    // ...and the evaluator surfaces the count instead of silently scoring
    let ev = Evaluator::new(&engine, PRESET, 4).unwrap();
    let problems = vec![
        Problem {
            question: "x".repeat(4 * preset.model.seq_len),
            reasoning: String::new(),
            answer: 1,
        },
        Problem { question: "1+1".into(), reasoning: String::new(), answer: 2 },
    ];
    let res = ev.accuracy(&state, &problems).unwrap();
    assert_eq!(res.n, 2);
    assert_eq!(res.n_truncated, 1, "the over-long prompt must be counted");
    assert!(res.accuracy <= 0.5, "a truncated prompt can never score correct");
}

#[test]
fn rejected_prompts_do_not_consume_admission_slots() {
    // a burst of over-length prompts ahead of a valid one must not delay
    // its admission: rejections never occupy a slot, so the same step()
    // keeps admitting until the free slots are actually spent
    let engine = engine();
    let preset = engine.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 11);
    let mut srv = ServeEngine::new(
        &engine,
        PRESET,
        &state,
        ServeConfig { slots: 1, max_new_tokens: 4, ..Default::default() },
    )
    .unwrap();
    srv.submit(prompt(preset.model.seq_len + 5, 0), 0, 0.0);
    srv.submit(prompt(preset.model.seq_len + 6, 1), 0, 0.0);
    let good = srv.submit(prompt(4, 2), 0, 0.0);
    let done = srv.step().unwrap();
    let rejected = done.iter().filter(|r| r.truncated).count();
    assert_eq!(rejected, 2, "both bad prompts rejected in the first step");
    let good_started = srv.n_active() == 1
        || done.iter().any(|r| r.id == good && !r.truncated);
    assert!(good_started, "the valid prompt must be admitted in the same step");
}

#[test]
fn steady_state_decode_performs_zero_slab_allocations() {
    let engine = engine();
    let preset = engine.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 1);
    let blocks: Vec<RefTensor> =
        state.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();

    let n = 4usize;
    let mut pool = KvPool::new(&preset.model, n);
    let slots: Vec<usize> = (0..n).map(|_| pool.alloc().unwrap()).collect();
    let p = prompt(8, 2);
    for &s in &slots {
        let mut views = pool.views(&[s]).unwrap();
        engine.kv_prefill(&preset, &blocks, &p, &mut views[0]).unwrap();
        pool.set_len(s, p.len());
    }
    let feed = |pool: &mut KvPool, tok: i32| {
        let toks = vec![tok; n];
        let mut views = pool.views(&slots).unwrap();
        engine.kv_decode_step(&preset, &blocks, &toks, &mut views).unwrap();
        drop(views);
        for &s in &slots {
            pool.advance(s);
        }
    };
    feed(&mut pool, 5); // warm: first decode step may grow the arena
    let warm = engine.workspace_stats();
    for step in 0..20 {
        feed(&mut pool, 6 + (step % 40));
    }
    let steady = engine.workspace_stats();
    assert_eq!(
        steady.grows, warm.grows,
        "decode steps after warm-up must not allocate arena slabs (even as positions grow)"
    );
    assert!(steady.takes > warm.takes, "the steps did run through the arena");

    // shrinking the active batch must also stay allocation-free
    let toks = vec![7i32; 2];
    let two = [slots[0], slots[2]];
    let mut views = pool.views(&two).unwrap();
    engine.kv_decode_step(&preset, &blocks, &toks, &mut views).unwrap();
    drop(views);
    assert_eq!(engine.workspace_stats().grows, steady.grows);
}
