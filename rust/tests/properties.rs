//! Property-based tests over the coordinator's invariants.
//!
//! The offline build image has no proptest crate, so these are hand-rolled
//! property tests: each property is checked over a few hundred randomized
//! cases drawn from the in-tree seeded PRNG, with the failing seed printed
//! on assertion failure (set `AGSEL_PROP_CASES` to widen the sweep).

use adagradselect::optimizer::{
    AdamWParams, PcieModel, ResidencyManager, SelectiveAdamW,
};
use adagradselect::selection::sampling::{gamma, standard_normal};
use adagradselect::selection::{
    k_from_pct, sample_dirichlet, weighted_sample_without_replacement, AdaGradSelect,
    AdaGradSelectParams, SelectionCtx, SelectionStrategy,
};
use adagradselect::selection::grad_norm::{block_norm_sq, top_k_indices};
use adagradselect::util::json::Value;
use adagradselect::util::rng::Rng;

fn cases() -> u64 {
    std::env::var("AGSEL_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(300)
}

#[test]
fn prop_dirichlet_always_on_simplex() {
    for seed in 0..cases() {
        let mut rng = Rng::seed_from_u64(seed);
        let n = rng.gen_range(1, 40);
        let alpha: Vec<f64> =
            (0..n).map(|_| rng.gen_range_f64(1e-3, 50.0)).collect();
        let p = sample_dirichlet(&alpha, &mut rng);
        let sum: f64 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "seed {seed}: sum {sum}");
        assert!(p.iter().all(|&x| x > 0.0 && x <= 1.0), "seed {seed}: {p:?}");
    }
}

#[test]
fn prop_wswor_k_distinct_in_range() {
    for seed in 0..cases() {
        let mut rng = Rng::seed_from_u64(seed ^ 0xBEEF);
        let n = rng.gen_range(1, 30);
        let k = rng.gen_range(1, n + 1);
        let p: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(0.0, 1.0)).collect();
        // guarantee at least k strictly-positive weights
        let mut p = p;
        for i in 0..k {
            p[i] = p[i].max(1e-6);
        }
        let s = weighted_sample_without_replacement(&p, k, &mut rng);
        assert_eq!(s.len(), k, "seed {seed}");
        assert!(s.windows(2).all(|w| w[0] < w[1]), "seed {seed}: not sorted/distinct");
        assert!(s.iter().all(|&i| i < n), "seed {seed}: out of range");
    }
}

#[test]
fn prop_topk_returns_largest() {
    for seed in 0..cases() {
        let mut rng = Rng::seed_from_u64(seed ^ 0x70D0);
        let n = rng.gen_range(1, 50);
        let k = rng.gen_range(0, n + 1);
        let v: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(-10.0, 10.0)).collect();
        let sel = top_k_indices(&v, k);
        assert_eq!(sel.len(), k.min(n));
        if k > 0 && k < n {
            let min_sel = sel.iter().map(|&i| v[i]).fold(f64::INFINITY, f64::min);
            let max_unsel = (0..n)
                .filter(|i| !sel.contains(i))
                .map(|i| v[i])
                .fold(f64::NEG_INFINITY, f64::max);
            assert!(min_sel >= max_unsel, "seed {seed}: {v:?} -> {sel:?}");
        }
    }
}

#[test]
fn prop_adagrad_selects_exactly_k_valid_blocks() {
    for seed in 0..cases() / 3 {
        let mut rng = Rng::seed_from_u64(seed ^ 0xA6);
        let n = rng.gen_range(2, 40);
        let k = rng.gen_range(1, n + 1);
        let mut params = AdaGradSelectParams::new(k, 20);
        params.seed = seed;
        let mut s = AdaGradSelect::new(n, params);
        let norms: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(0.0, 5.0)).collect();
        for step in 0..40u64 {
            let sel = s.select(&SelectionCtx {
                step,
                epoch: 1 + (step / 20) as u32,
                grad_norms: &norms,
            });
            assert_eq!(sel.len(), k, "seed {seed} step {step}");
            assert!(sel.windows(2).all(|w| w[0] < w[1]), "distinct+sorted");
            assert!(sel.iter().all(|&b| b < n));
        }
        // frequencies must total k per step
        assert_eq!(s.frequencies().unwrap().iter().sum::<u64>(), 40 * k as u64);
    }
}

#[test]
fn prop_residency_ledger_consistent_under_random_sequences() {
    for seed in 0..cases() / 3 {
        let mut rng = Rng::seed_from_u64(seed ^ 0x4E5);
        let n = rng.gen_range(1, 12);
        let numels: Vec<usize> = (0..n).map(|_| rng.gen_range(10, 5000)).collect();
        let mut m = ResidencyManager::new(&numels, 2, PcieModel::default(), true);
        let mut h2d_total = 0u64;
        let mut d2h_total = 0u64;
        for _ in 0..30 {
            let k = rng.gen_range(0, n + 1);
            let mut sel: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = rng.gen_range(i, n);
                sel.swap(i, j);
            }
            let mut sel = sel[..k].to_vec();
            sel.sort_unstable();
            let t = m.step(&sel, rng.gen_range_f64(0.0, 0.01));
            h2d_total += t.h2d_bytes as u64;
            d2h_total += t.d2h_bytes as u64;
            // resident set equals the selected set after the step
            assert_eq!(m.resident_blocks(), sel, "seed {seed}");
            // ledger equals sum of resident block bytes
            let expect: usize = sel.iter().map(|&b| 2 * 2 * numels[b]).sum();
            assert_eq!(m.vram_used(), expect, "seed {seed}");
        }
        // conservation: everything uploaded was either evicted or resident
        assert_eq!(h2d_total, d2h_total + m.vram_used() as u64, "seed {seed}");
        assert_eq!(m.stats.h2d_bytes, h2d_total);
    }
}

#[test]
fn prop_adamw_matches_scalar_reference() {
    // fused kernel == straightforward scalar AdamW on random inputs
    for seed in 0..cases() / 3 {
        let mut rng = Rng::seed_from_u64(seed ^ 0xADA);
        let n = rng.gen_range(1, 300);
        let lr = rng.gen_range_f64(1e-5, 1e-1) as f32;
        let hp = AdamWParams::default();
        let mut p: Vec<f32> = (0..n).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect();
        let g: Vec<f32> = (0..n).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect();
        let mut opt = SelectiveAdamW::new(&[n], hp);
        let p0 = p.clone();
        opt.update_block(0, &mut p, &g, lr);
        for i in 0..n {
            let m = 0.1 * g[i];
            let v = 0.001 * g[i] * g[i];
            let m_hat = m / (1.0 - 0.9f32);
            let v_hat = v / (1.0 - 0.999f32);
            let expect = p0[i] - lr * (m_hat / (v_hat.sqrt() + hp.eps) + hp.wd * p0[i]);
            assert!((p[i] - expect).abs() < 1e-5, "seed {seed} i {i}: {} vs {expect}", p[i]);
        }
    }
}

#[test]
fn prop_block_norm_matches_f64_reference() {
    for seed in 0..cases() {
        let mut rng = Rng::seed_from_u64(seed ^ 0x4042);
        let n = rng.gen_range(0, 10_000);
        let g: Vec<f32> = (0..n).map(|_| rng.gen_range_f64(-2.0, 2.0) as f32).collect();
        let naive: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum();
        let fast = block_norm_sq(&g);
        let tol = naive.max(1.0) * 1e-6;
        assert!((fast - naive).abs() <= tol, "seed {seed}: {fast} vs {naive}");
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth > 3 { rng.gen_range(0, 4) } else { rng.gen_range(0, 6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.gen_bool(0.5)),
            2 => Value::Num((rng.gen_range_i64(-1_000_000, 1_000_000) as f64) / 8.0),
            3 => {
                let len = rng.gen_range(0, 12);
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.gen_range(0, 96) as u8 + 32;
                        if c == b'\\' { 'x' } else { c as char }
                    })
                    .collect();
                Value::Str(s + "\"\n\\é")
            }
            4 => Value::Arr((0..rng.gen_range(0, 5)).map(|_| gen_value(rng, depth + 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.gen_range(0, 5) {
                    m.insert(format!("k{i}"), gen_value(rng, depth + 1));
                }
                Value::Obj(m)
            }
        }
    }
    for seed in 0..cases() {
        let mut rng = Rng::seed_from_u64(seed ^ 0x1503);
        let v = gen_value(&mut rng, 0);
        let text = v.to_string();
        let back = Value::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, v, "seed {seed}");
    }
}

#[test]
fn prop_k_from_pct_bounds() {
    for seed in 0..cases() {
        let mut rng = Rng::seed_from_u64(seed ^ 0x46);
        let n = rng.gen_range(1, 200);
        let pct = rng.gen_range_f64(0.1, 100.0);
        let k = k_from_pct(n, pct);
        assert!(k >= 1 && k <= n, "n={n} pct={pct} k={k}");
    }
}

#[test]
fn prop_dirichlet_deterministic_under_fixed_seed() {
    // same util::rng seed ⇒ bit-identical draws, run after run — the
    // property every "deterministic given seed" trainer guarantee rests on
    for seed in 0..cases() / 10 {
        let alpha: Vec<f64> = (1..=12).map(|i| 0.25 * i as f64).collect();
        let draw = |s: u64| {
            let mut rng = Rng::seed_from_u64(s);
            (0..5).map(|_| sample_dirichlet(&alpha, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(draw(seed), draw(seed), "seed {seed}");
        assert_ne!(draw(seed), draw(seed ^ 0xFFFF), "seed {seed}: distinct seeds collide");
    }
}

#[test]
fn prop_wswor_deterministic_under_fixed_seed() {
    for seed in 0..cases() / 10 {
        let p: Vec<f64> = (1..=20).map(|i| i as f64 / 210.0).collect();
        let draw = |s: u64| {
            let mut rng = Rng::seed_from_u64(s);
            (0..8)
                .map(|k| weighted_sample_without_replacement(&p, 1 + (k % 5), &mut rng))
                .collect::<Vec<_>>()
        };
        assert_eq!(draw(seed), draw(seed), "seed {seed}");
    }
}

#[test]
fn prop_adagrad_full_trajectory_deterministic() {
    // the composed bandit (dirichlet + wswor + ε decisions) must replay
    // exactly from its seed, including across explore/exploit boundaries
    let norms: Vec<f64> = (0..10).map(|i| (i as f64 * 0.37).sin().abs() + 0.1).collect();
    let run = |seed: u64| {
        let mut params = AdaGradSelectParams::new(3, 25);
        params.seed = seed;
        let mut s = AdaGradSelect::new(10, params);
        (0..75u64)
            .map(|t| {
                s.select(&SelectionCtx {
                    step: t,
                    epoch: 1 + (t / 25) as u32,
                    grad_norms: &norms,
                })
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(run(123), run(123));
    assert_ne!(run(123), run(124));
}

#[test]
fn prop_samplers_produce_finite_values() {
    let mut rng = Rng::seed_from_u64(99);
    for _ in 0..20_000 {
        assert!(standard_normal(&mut rng).is_finite());
        let a = rng.gen_range_f64(0.01, 100.0);
        let g = gamma(a, &mut rng);
        assert!(g.is_finite() && g > 0.0);
    }
}
