//! API stub for the `xla` PJRT bindings.
//!
//! The offline build image has no PJRT runtime, but the coordinator's
//! `pjrt` cargo feature must always *type-check* so the engine code can't
//! rot. This crate mirrors the subset of the real `xla` crate's API that
//! `runtime::engine` uses; every entrypoint that would touch PJRT returns
//! [`Error::Unavailable`] and every runtime value type is uninhabited, so
//! the stub compiles everywhere and can never be executed by accident.
//!
//! On a PJRT-enabled host, point the `xla` path dependency in
//! `rust/Cargo.toml` at the real bindings instead; no coordinator code
//! changes are needed.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error`.
#[derive(Debug)]
pub enum Error {
    /// The stub build has no PJRT runtime behind it.
    Unavailable,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable => write!(
                f,
                "xla stub: PJRT runtime not available in this build \
                 (point the `xla` path dependency at the real bindings)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Uninhabited marker: stub runtime values can never exist.
enum Void {}

impl Void {
    fn unreachable(&self) -> ! {
        match *self {}
    }
}

/// Element types accepted by [`PjRtClient::buffer_from_host_buffer`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// PJRT client handle (stub: cannot be constructed).
pub struct PjRtClient(Void);

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable)
    }

    pub fn platform_name(&self) -> String {
        self.0.unreachable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        self.0.unreachable()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        self.0.unreachable()
    }
}

/// Parsed HLO module (stub: cannot be constructed).
pub struct HloModuleProto(Void);

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(Error::Unavailable)
    }
}

/// XLA computation wrapper.
pub struct XlaComputation(Void);

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        proto.0.unreachable()
    }
}

/// Compiled executable handle (stub: cannot be constructed).
pub struct PjRtLoadedExecutable(Void);

impl PjRtLoadedExecutable {
    /// Execute with device-resident buffers; outputs stay on device.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        self.0.unreachable()
    }
}

/// Device buffer handle (stub: cannot be constructed).
pub struct PjRtBuffer(Void);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        self.0.unreachable()
    }
}

/// Host-side tensor value (stub: cannot be constructed).
pub struct Literal(Void);

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        self.0.unreachable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.0.unreachable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_constructors_report_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
        let msg = format!("{}", Error::Unavailable);
        assert!(msg.contains("stub"));
    }
}
