//! In-tree stand-in for the `anyhow` crate.
//!
//! The offline build image vendors no registry crates, so the subset of
//! `anyhow` this repository uses is implemented here with the same
//! surface: `Error`, `Result`, the `anyhow!` / `bail!` / `ensure!`
//! macros, and the `Context` extension trait for `Result`. Error values
//! carry a context chain that `{:?}` prints `anyhow`-style
//! ("Caused by:" sections), which is what `fn main() -> Result<()>`
//! shows on failure.

use std::fmt;

/// A context-carrying error. Deliberately does **not** implement
/// `std::error::Error`, so the blanket `From<E: Error>` conversion below
/// stays coherent (the same trick the real crate uses).
pub struct Error(Box<ErrorImpl>);

struct ErrorImpl {
    msg: String,
    cause: Option<Error>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Error(Box::new(ErrorImpl { msg: message.to_string(), cause: None }))
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, context: impl fmt::Display) -> Self {
        Error(Box::new(ErrorImpl { msg: context.to_string(), cause: Some(self) }))
    }

    /// The outermost message plus each `Caused by` message, outer first.
    pub fn chain_messages(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.0.msg.as_str());
            cur = e.0.cause.as_ref();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0.msg)?;
        let mut cause = self.0.cause.as_ref();
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cause {
            write!(f, "\n    {}", e.0.msg)?;
            cause = e.0.cause.as_ref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    /// Sealed conversion used by [`super::Context`]: implemented for both
    /// standard errors and [`super::Error`] itself, mirroring the real
    /// crate's `ext::StdError` arrangement.
    pub trait IntoError {
        fn into_err(self) -> super::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_err(self) -> super::Error {
            super::Error::msg(self)
        }
    }

    impl IntoError for super::Error {
        fn into_err(self) -> super::Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: private::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_err().context(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_err().context(f())),
        }
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e)?;
        Ok(())
    }

    #[test]
    fn context_chains_and_debug_prints_causes() {
        let err = io_fail().context("reading config").unwrap_err();
        assert_eq!(err.chain_messages(), vec!["reading config", "gone"]);
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("gone"), "{dbg}");
    }

    #[test]
    fn with_context_on_anyhow_result() {
        let base: Result<()> = Err(anyhow!("inner {}", 7));
        let err = base.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{err}"), "outer 1");
        assert_eq!(err.chain_messages().last().copied(), Some("inner 7"));
    }

    #[test]
    fn macros_build_errors() {
        let a = anyhow!("plain");
        assert_eq!(format!("{a}"), "plain");
        let n = 3;
        let b = anyhow!("value {n} and {}", 4);
        assert_eq!(format!("{b}"), "value 3 and 4");
        fn bails() -> Result<()> {
            bail!("stop {}", "now");
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "stop now");
        fn ensures(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(ensures(1).is_ok());
        assert!(ensures(-1).is_err());
    }
}
