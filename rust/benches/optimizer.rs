//! Optimizer benchmarks: fused native AdamW throughput (the L3 hot path),
//! parallel selective updates, and the HLO/Pallas kernel path.

use std::time::Duration;

use adagradselect::optimizer::{AdamWParams, HloAdamW, SelectiveAdamW};
use adagradselect::runtime::{Backend, ReferenceBackend};
use adagradselect::util::bench::{bench, header};

fn main() {
    header("optimizer");
    // CI's bench-smoke job shrinks the measurement budget via
    // AGSEL_BENCH_BUDGET_MS (same contract as the other bench targets)
    let budget_ms: u64 = std::env::var("AGSEL_BENCH_BUDGET_MS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let budget = Duration::from_millis(budget_ms);

    // native fused kernel across block sizes
    for n in [6_144usize, 110_000, 1 << 20] {
        let mut p = vec![0.1f32; n];
        let g = vec![0.01f32; n];
        let mut opt = SelectiveAdamW::new(&[n], AdamWParams::default());
        let r = bench(&format!("adamw_native/n={n}"), budget, || {
            opt.update_block(0, &mut p, &g, 1e-3);
        });
        println!(
            "    -> {:.2} Gparam/s",
            n as f64 / r.mean_s() / 1e9
        );
    }

    // parallel selective update at qwen-sim shape: 8 of 27 blocks
    let numels: Vec<usize> =
        (0..27).map(|i| if i == 0 || i == 26 { 6_144 } else { 110_000 }).collect();
    let mut flats: Vec<Vec<f32>> = numels.iter().map(|&n| vec![0.1; n]).collect();
    let grads: Vec<Vec<f32>> = numels.iter().map(|&n| vec![0.01; n]).collect();
    let mut opt = SelectiveAdamW::new(&numels, AdamWParams::default());
    let selected: Vec<usize> = (0..8).collect();
    bench("adamw_update_selected/8of27-blocks", budget, || {
        opt.update_selected(&selected, &mut flats, &grads, 1e-3);
    });
    let all: Vec<usize> = (0..27).collect();
    bench("adamw_update_selected/27of27-blocks(FFT)", budget, || {
        opt.update_selected(&all, &mut flats, &grads, 1e-3);
    });

    // kernel-entrypoint path through the Backend trait (chunked driver +
    // upload/download overhead vs the in-place native loop)
    let engine = ReferenceBackend::new();
    let hlo = HloAdamW::new(&engine).unwrap();
    let n = engine.manifest().chunk_size;
    let mut p = vec![0.1f32; n];
    let g = vec![0.01f32; n];
    let mut m = vec![0.0f32; n];
    let mut v = vec![0.0f32; n];
    let mut step = 0u64;
    bench(&format!("adamw_kernel_entry/n={n}(chunk)"), budget, || {
        step += 1;
        hlo.update_block(&engine, &mut p, &g, &mut m, &mut v, 1e-3, step).unwrap();
    });
}
