//! End-to-end paged-serving benchmark — the before/after for the paged
//! KV pool + prefix sharing rewrite:
//!
//! * **churn throughput**: 24 short requests over 4 slots through the
//!   full engine loop (admission → prefill → batched decode → release),
//!   greedy and sampled — the sampled/greedy latency ratio is the
//!   sampling overhead;
//! * **paged vs slot-model memory**: the measured peak of pages in use
//!   vs the old slot-model backing store (`slots × seq_len`), recorded
//!   as a machine-independent invariant (`slot_model/paged_peak >= 1`)
//!   that `scripts/bench_compare` enforces unconditionally;
//! * **prefix sharing**: 8 requests behind one 32-token system-prompt
//!   stem must prefill the stem **once** (every follower serves it from
//!   the prefix cache) — invariant `prefix_stem_prefilled_once`;
//! * **steady-state page allocations**: decode steps inside a page must
//!   claim zero fresh pages and zero arena slabs — invariant
//!   `steady_state_zero_page_allocs` plus the shared
//!   `workspace.steady_state_grows_10_steps` gate;
//! * **preemption under burst**: the same contended trace (8 requests,
//!   an overcommitted 4-page pool) replayed under optimistic vs
//!   worst-case reservation — optimistic admission must preempt at least
//!   once yet keep mean decode batch occupancy at or above the
//!   worst-case baseline (`preempt/bursty_utilization_vs_worst_case`),
//!   while the uncontended churn trace must never preempt
//!   (`churn/zero_preemptions_uncontended`);
//! * **telemetry overhead**: the churn trace replayed with the metric
//!   registry + span tracer fully on vs disabled — invariant
//!   `telemetry/overhead_ratio` (value = off/on wall, best of 3, min
//!   0.95 ⇒ at most ~5% instrumentation overhead) plus
//!   `telemetry/steady_state_zero_allocs` (the telemetry allocation
//!   fingerprint is unchanged across 10 instrumented decode steps).
//!   A sample span trace of the probe run is written to `trace.json`
//!   (override with `AGSEL_BENCH_TRACE_JSON`) for chrome://tracing.
//!
//! Writes `BENCH_serve.json` (override with `AGSEL_BENCH_SERVE_JSON`);
//! CI uploads it next to `BENCH_decode.json` and gates it through
//! `scripts/bench_compare` against
//! `rust/benches/baselines/BENCH_serve.baseline.json`.

use std::time::{Duration, Instant};

use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, RefTensor, ReferenceBackend};
use adagradselect::serve::{
    KvBackend, KvPool, Reservation, SamplingParams, ServeConfig, ServeEngine, ServeStats,
};
use adagradselect::util::bench::{bench, header, BenchResult};
use adagradselect::util::json::Value;

const PRESET: &str = "test-tiny";

fn result_row(r: &BenchResult) -> Value {
    Value::obj(vec![
        ("name", Value::str(&r.name)),
        ("mean_ns", Value::num(r.mean_ns)),
        ("p50_ns", Value::num(r.p50_ns)),
        ("p95_ns", Value::num(r.p95_ns)),
        ("iters", Value::num(r.iters as f64)),
    ])
}

/// Deterministic prompt of `len` in-vocab tokens.
fn prompt(len: usize, salt: u64) -> Vec<i32> {
    (0..len).map(|i| 4 + ((i as u64 * 7 + salt * 13) % 50) as i32).collect()
}

/// Replay one bursty contended trace — 8 requests arriving at once on a
/// 2-slot engine whose pool is overcommitted to 4 pages — under the given
/// reservation policy. Returns (mean decode batch occupancy, stats):
/// occupancy is `decode_tokens / decode_steps`, a machine-independent
/// utilization measure (worst-case reservation serializes this trace, so
/// its occupancy pins the baseline at 1.0).
fn bursty(
    backend: &ReferenceBackend,
    state: &ModelState,
    reservation: Reservation,
) -> (f64, ServeStats) {
    let mut srv = ServeEngine::new(
        backend,
        PRESET,
        state,
        ServeConfig { slots: 2, max_new_tokens: 8, kv_pages: 4, reservation },
    )
    .unwrap();
    let n = 8u64;
    for i in 0..n {
        srv.submit(prompt(31, 300 + i), 0, 0.0);
    }
    let responses = srv.run_until_idle().unwrap();
    assert_eq!(responses.len() as u64, n, "every bursty request completes");
    let stats = srv.stats();
    (stats.decode_tokens as f64 / stats.decode_steps.max(1) as f64, stats)
}

/// Run `n` requests through a fresh engine; returns (wall seconds,
/// generated tokens, stats). `telemetry` false disables the metric
/// registry; true keeps it on **and** enables span tracing, so the two
/// settings bracket the full instrumentation cost.
fn churn(
    backend: &ReferenceBackend,
    state: &ModelState,
    n: u64,
    params: Option<&SamplingParams>,
    telemetry: bool,
) -> (f64, usize, ServeStats) {
    let mut srv = ServeEngine::new(
        backend,
        PRESET,
        state,
        ServeConfig { slots: 4, max_new_tokens: 8, ..Default::default() },
    )
    .unwrap();
    if telemetry {
        srv.telemetry().enable_tracing(8192);
    } else {
        srv.telemetry().set_enabled(false);
    }
    for i in 0..n {
        let p = prompt(10, 100 + i);
        match params {
            Some(sp) => {
                let mut sp = sp.clone();
                sp.seed = i; // per-request stream, like a real server
                srv.submit_sampled(p, 0, 0.0, sp)
            }
            None => srv.submit(p, 0, 0.0),
        };
    }
    let t0 = Instant::now();
    let responses = srv.run_until_idle().unwrap();
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(responses.len() as u64, n, "every request completes");
    assert!(responses.iter().all(|r| !r.truncated));
    let generated: usize = responses.iter().map(|r| r.tokens.len()).sum();
    (dt, generated, srv.stats())
}

fn main() {
    header("serve");
    let quick = std::env::var_os("AGSEL_BENCH_QUICK").is_some();
    let budget_ms: u64 = std::env::var("AGSEL_BENCH_BUDGET_MS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick { 150 } else { 1000 });
    let budget = Duration::from_millis(budget_ms);
    let engine = ReferenceBackend::new();
    let preset = engine.manifest().preset(PRESET).unwrap().clone();
    let state = ModelState::init(&preset.blocks, 13);
    let mut results: Vec<BenchResult> = Vec::new();
    let mut invariants = Vec::new();

    // --- churn: full engine loop, greedy vs sampled -------------------
    let n_req = if quick { 16 } else { 24 };
    let (greedy_s, greedy_toks, stats) = churn(&engine, &state, n_req, None, true);
    let sp = SamplingParams { temperature: 0.9, top_k: 16, top_p: 0.95, ..Default::default() };
    let (sampled_s, sampled_toks, sampled_stats) = churn(&engine, &state, n_req, Some(&sp), true);
    let sampling_overhead = sampled_s / greedy_s;
    let slot_model_bytes = stats.kv_bytes; // slots × seq_len provisioning
    let paged_peak_bytes = stats.kv_peak_bytes.max(1);
    let mem_ratio = slot_model_bytes as f64 / paged_peak_bytes as f64;
    println!(
        "    -> churn: {n_req} reqs, greedy {:.1} ms ({greedy_toks} toks), sampled {:.1} ms \
         ({sampled_toks} toks); paged peak {:.1} KiB vs slot-model {:.1} KiB ({mem_ratio:.1}x)",
        greedy_s * 1e3,
        sampled_s * 1e3,
        paged_peak_bytes as f64 / 1024.0,
        slot_model_bytes as f64 / 1024.0,
    );
    invariants.push(Value::obj(vec![
        ("name", Value::str("churn/slot_model_vs_paged_peak_bytes")),
        ("value", Value::num(mem_ratio)),
        ("min", Value::num(1.0)),
    ]));
    // the churn trace is uncontended (worst-case-sized pool): the
    // preemption backstop must never fire on it
    let no_preempt =
        if stats.n_preemptions == 0 && sampled_stats.n_preemptions == 0 { 1.0 } else { 0.0 };
    invariants.push(Value::obj(vec![
        ("name", Value::str("churn/zero_preemptions_uncontended")),
        ("value", Value::num(no_preempt)),
        ("min", Value::num(1.0)),
    ]));

    // --- bursty contention: optimistic + preemption vs worst case -----
    let (wc_util, wc_stats) = bursty(&engine, &state, Reservation::WorstCase);
    let (opt_util, opt_stats) = bursty(&engine, &state, Reservation::Optimistic);
    println!(
        "    -> bursty: occupancy {opt_util:.2} optimistic ({} preemptions, {} tokens \
         at risk) vs {wc_util:.2} worst-case ({} preemptions)",
        opt_stats.n_preemptions, opt_stats.preempted_tokens, wc_stats.n_preemptions,
    );
    invariants.push(Value::obj(vec![
        ("name", Value::str("preempt/bursty_utilization_vs_worst_case")),
        ("value", Value::num(opt_util / wc_util.max(1e-9))),
        ("min", Value::num(1.0)),
    ]));
    invariants.push(Value::obj(vec![
        ("name", Value::str("preempt/bursty_preemptions")),
        ("value", Value::num(opt_stats.n_preemptions as f64)),
        ("min", Value::num(1.0)),
    ]));
    invariants.push(Value::obj(vec![
        ("name", Value::str("preempt/worst_case_never_preempts")),
        ("value", Value::num(if wc_stats.n_preemptions == 0 { 1.0 } else { 0.0 })),
        ("min", Value::num(1.0)),
    ]));

    // --- prefix sharing: one stem, many followers ---------------------
    let page = adagradselect::serve::DEFAULT_PAGE_SIZE;
    let stem = prompt(2 * page, 9);
    let n_shared = 8usize;
    let mut srv = ServeEngine::new(
        &engine,
        PRESET,
        &state,
        ServeConfig { slots: 2, max_new_tokens: 4, ..Default::default() },
    )
    .unwrap();
    for i in 0..n_shared {
        let mut p = stem.clone();
        p.extend(prompt(4, 40 + i as u64));
        srv.submit(p, 0, 0.0);
    }
    srv.run_until_idle().unwrap();
    let shared = srv.stats();
    // every follower must cover the whole stem from the cache
    let want_hits = (n_shared - 1) * stem.len();
    let stem_once = if shared.prefix_hit_tokens == want_hits { 1.0 } else { 0.0 };
    println!(
        "    -> prefix: {} hit tokens (want {want_hits}), {} prefilled, {} cow copies",
        shared.prefix_hit_tokens, shared.prefill_tokens, shared.cow_copies,
    );
    invariants.push(Value::obj(vec![
        ("name", Value::str("prefix/stem_prefilled_once")),
        ("value", Value::num(stem_once)),
        ("min", Value::num(1.0)),
    ]));

    // --- steady state: decode inside a page allocates nothing ---------
    let blocks: Vec<RefTensor> =
        state.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();
    let mut pool = KvPool::new(&preset.model, 4);
    let slots: Vec<usize> = (0..4).map(|_| pool.alloc().unwrap()).collect();
    let p4 = prompt(4, 3);
    for &slot in &slots {
        let mut views = pool.views(&[slot]).unwrap();
        engine.kv_prefill(&preset, &blocks, &p4, &mut views[0]).unwrap();
        pool.set_len(slot, p4.len());
    }
    let toks = vec![6i32; slots.len()];
    let mut feed = |pool: &mut KvPool| {
        let mut views = pool.views(&slots).unwrap();
        engine.kv_decode_step(&preset, &blocks, &toks, &mut views).unwrap();
        drop(views);
        for &slot in &slots {
            pool.advance(slot);
        }
    };
    feed(&mut pool); // warm the arena
    let (pages0, grows0) = (pool.pages_allocated(), engine.workspace_stats().grows);
    for _ in 0..10 {
        feed(&mut pool);
    }
    let page_allocs = pool.pages_allocated() - pages0;
    let steady_grows = engine.workspace_stats().grows - grows0;
    println!("    -> steady: {page_allocs} page allocs, {steady_grows} arena grows (want 0)");
    invariants.push(Value::obj(vec![
        ("name", Value::str("steady_state_zero_page_allocs")),
        ("value", Value::num(if page_allocs == 0 { 1.0 } else { 0.0 })),
        ("min", Value::num(1.0)),
    ]));

    // --- telemetry: instrumentation overhead + zero-allocation probe --
    let reps = if quick { 2 } else { 3 };
    let (mut on_best, mut off_best) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..reps {
        on_best = on_best.min(churn(&engine, &state, n_req, None, true).0);
        off_best = off_best.min(churn(&engine, &state, n_req, None, false).0);
    }
    let tel_ratio = off_best / on_best.max(1e-12);
    println!(
        "    -> telemetry: churn {:.1} ms instrumented vs {:.1} ms off (off/on {tel_ratio:.3})",
        on_best * 1e3,
        off_best * 1e3,
    );
    invariants.push(Value::obj(vec![
        ("name", Value::str("telemetry/overhead_ratio")),
        ("value", Value::num(tel_ratio)),
        ("min", Value::num(0.95)),
    ]));
    // the audit feature must be compiled out of bench builds: a bench
    // binary carrying shadow-state validators would silently measure
    // the audited hot path (value 1.0 iff audit is off; min 1.0 makes
    // an audited bench run fail bench_compare loudly)
    invariants.push(Value::obj(vec![
        ("name", Value::str("audit/compiled_out")),
        ("value", Value::num(if cfg!(feature = "audit") { 0.0 } else { 1.0 })),
        ("min", Value::num(1.0)),
    ]));
    // instrumented steady-state decode must not grow any telemetry
    // allocation: counters/gauges are cells, histogram buckets and the
    // span ring are preallocated — the combined fingerprint is identity-
    // based, so any reallocation flips it
    let mut srv = ServeEngine::new(
        &engine,
        PRESET,
        &state,
        ServeConfig { slots: 1, max_new_tokens: 64, ..Default::default() },
    )
    .unwrap();
    srv.telemetry().enable_tracing(4096);
    srv.submit(prompt(8, 77), 0, 0.0);
    for _ in 0..4 {
        srv.step().unwrap(); // admission + prefill + warm decode steps
    }
    let fp0 = srv.telemetry().fingerprint();
    for _ in 0..10 {
        srv.step().unwrap();
    }
    let tel_no_alloc = if srv.telemetry().fingerprint() == fp0 { 1.0 } else { 0.0 };
    println!(
        "    -> telemetry: allocation fingerprint {} across 10 instrumented decode steps",
        if tel_no_alloc == 1.0 { "stable" } else { "CHANGED" },
    );
    invariants.push(Value::obj(vec![
        ("name", Value::str("telemetry/steady_state_zero_allocs")),
        ("value", Value::num(tel_no_alloc)),
        ("min", Value::num(1.0)),
    ]));
    let trace_path =
        std::env::var("AGSEL_BENCH_TRACE_JSON").unwrap_or_else(|_| "trace.json".to_string());
    adagradselect::telemetry::write_chrome_trace(&trace_path, &srv.telemetry().tracer)
        .expect("write sample trace");
    println!("    -> telemetry: sample span trace at {trace_path}");

    // --- sampling micro-latency: argmax vs full top-k/top-p draw ------
    let logits: Vec<f32> =
        (0..preset.model.vocab).map(|i| ((i * 37 % 101) as f32) / 7.0 - 5.0).collect();
    let greedy_p = SamplingParams::default();
    results.push(bench("sample/greedy_argmax", budget, || {
        std::hint::black_box(adagradselect::serve::sample_token(&logits, &greedy_p, 0));
    }));
    let mut step = 0u64;
    results.push(bench("sample/top_k16_top_p95", budget, || {
        step += 1;
        std::hint::black_box(adagradselect::serve::sample_token(&logits, &sp, step));
    }));

    let ws = engine.workspace_stats();
    let serve_rows = vec![Value::obj(vec![
        ("preset", Value::str(PRESET)),
        ("n_requests", Value::num(n_req as f64)),
        ("greedy_wall_s", Value::num(greedy_s)),
        ("sampled_wall_s", Value::num(sampled_s)),
        ("sampling_overhead", Value::num(sampling_overhead)),
        ("greedy_tokens_per_s", Value::num(greedy_toks as f64 / greedy_s.max(1e-9))),
        ("slot_model_bytes", Value::num(slot_model_bytes as f64)),
        ("paged_peak_bytes", Value::num(paged_peak_bytes as f64)),
        ("pages_allocated", Value::num(stats.pages_allocated as f64)),
        ("cow_copies", Value::num(sampled_stats.cow_copies as f64)),
        ("prefix_hit_tokens", Value::num(shared.prefix_hit_tokens as f64)),
        ("prefix_prefill_tokens", Value::num(shared.prefill_tokens as f64)),
        ("bursty_util_optimistic", Value::num(opt_util)),
        ("bursty_util_worst_case", Value::num(wc_util)),
        ("bursty_preemptions", Value::num(opt_stats.n_preemptions as f64)),
        ("bursty_preempted_tokens", Value::num(opt_stats.preempted_tokens as f64)),
        ("telemetry_on_wall_s", Value::num(on_best)),
        ("telemetry_off_wall_s", Value::num(off_best)),
    ])];

    let summary = Value::obj(vec![
        ("schema", Value::num(1.0)),
        ("quick", Value::Bool(quick)),
        ("budget_ms", Value::num(budget_ms as f64)),
        ("calibrated", Value::Bool(false)),
        ("results", Value::Arr(results.iter().map(result_row).collect())),
        ("serve", Value::Arr(serve_rows)),
        ("invariants", Value::Arr(invariants)),
        (
            "workspace",
            Value::obj(vec![
                ("high_water_bytes", Value::num(ws.high_water_bytes as f64)),
                ("capacity_bytes", Value::num(ws.capacity_bytes as f64)),
                ("grows_total", Value::num(ws.grows as f64)),
                ("takes_total", Value::num(ws.takes as f64)),
                ("steady_state_grows_10_steps", Value::num(steady_grows as f64)),
            ]),
        ),
    ]);
    let path = std::env::var("AGSEL_BENCH_SERVE_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    std::fs::write(&path, format!("{summary}\n")).expect("write bench summary");
    println!("\nwrote {path}");
}
