//! Selection-path micro-benchmarks: the per-step coordinator overhead the
//! paper's method adds over plain full fine-tuning must be negligible
//! relative to the train-step HLO (§Perf target: ≪ 1% of step time).

use std::time::Duration;

use adagradselect::selection::grad_norm::{top_k_indices, GradNormTracker};
use adagradselect::selection::{
    sample_dirichlet, weighted_sample_without_replacement, AdaGradSelect,
    AdaGradSelectParams, SelectionCtx, SelectionStrategy,
};
use adagradselect::util::bench::{bench, header};
use adagradselect::util::rng::Rng;

fn main() {
    header("selection");
    // CI's bench-smoke job shrinks the measurement budget via
    // AGSEL_BENCH_BUDGET_MS and collects JSONL rows via BENCH_JSON.
    let budget_ms = std::env::var("AGSEL_BENCH_BUDGET_MS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let budget = Duration::from_millis(budget_ms);

    for n_blocks in [27usize, 34, 128] {
        let mut rng = Rng::seed_from_u64(0);
        let alpha: Vec<f64> = (0..n_blocks).map(|_| rng.gen_range_f64(0.5, 50.0)).collect();
        bench(&format!("dirichlet_sample/n={n_blocks}"), budget, || {
            std::hint::black_box(sample_dirichlet(&alpha, &mut rng));
        });

        let p = vec![1.0 / n_blocks as f64; n_blocks];
        let k = (n_blocks * 3 / 10).max(1);
        bench(&format!("wswor/n={n_blocks},k={k}"), budget, || {
            std::hint::black_box(weighted_sample_without_replacement(&p, k, &mut rng));
        });

        let norms: Vec<f64> = (0..n_blocks).map(|_| rng.gen_range_f64(0.0, 5.0)).collect();
        bench(&format!("top_k/n={n_blocks},k={k}"), budget, || {
            std::hint::black_box(top_k_indices(&norms, k));
        });

        let mut params = AdaGradSelectParams::new(k, 100);
        params.seed = 1;
        let mut ags = AdaGradSelect::new(n_blocks, params);
        let mut step = 0u64;
        bench(&format!("adagradselect_step/n={n_blocks},k={k}"), budget, || {
            let ctx = SelectionCtx { step, epoch: 1 + (step / 100) as u32, grad_norms: &norms };
            std::hint::black_box(ags.select(&ctx));
            step += 1;
        });
    }

    // per-block grad-norm reduction at qwen-sim scale (27 blocks, ~2.8M params)
    let grads: Vec<Vec<f32>> = (0..27)
        .map(|i| vec![0.01 * (i as f32 + 1.0); if i == 0 || i == 26 { 6144 } else { 110_000 }])
        .collect();
    let mut tracker = GradNormTracker::new(27);
    bench("grad_norm_tracker/qwen-sim-shape (2.8M params)", budget, || {
        std::hint::black_box(tracker.observe(&grads));
    });
}
