//! Serving-path benchmarks — the before/after for the KV-cache rewrite:
//!
//! * **prefill latency** per preset (one full prompt forward, cache fill);
//! * **per-token decode latency**: one batched `decode_step_kv` over a
//!   full slot set vs. the oracle `decode_step` full reforward, both
//!   normalized to per-generated-token cost;
//! * **cached-vs-reforward speedup** (the asymptotic win: O(s·layers) per
//!   token instead of O(s²·layers)) — recorded as a machine-independent
//!   invariant (`>= 5x at seq_len >= 128`) that `scripts/bench_compare`
//!   enforces unconditionally;
//! * **KV bytes**: pool backing store + the §capacity formula;
//! * **steady-state allocation probe**: 10 decode steps through the warm
//!   arena must perform zero slab allocations (same key the train-step
//!   gate uses, enforced by `scripts/bench_compare`).
//!
//! Writes `BENCH_decode.json` (override with `AGSEL_BENCH_DECODE_JSON`);
//! CI uploads it next to `BENCH_train_step.json` and diffs it against
//! `rust/benches/baselines/BENCH_decode.baseline.json`.

use std::time::Duration;

use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, RefTensor, ReferenceBackend};
use adagradselect::serve::{KvBackend, KvPool};
use adagradselect::util::bench::{bench, header, BenchResult};
use adagradselect::util::json::Value;

fn result_row(r: &BenchResult) -> Value {
    Value::obj(vec![
        ("name", Value::str(&r.name)),
        ("mean_ns", Value::num(r.mean_ns)),
        ("p50_ns", Value::num(r.p50_ns)),
        ("p95_ns", Value::num(r.p95_ns)),
        ("iters", Value::num(r.iters as f64)),
    ])
}

struct DecodeCase {
    row: Value,
    speedup: f64,
    seq_len: usize,
    steady_grows: u64,
}

/// Bench one preset end to end; returns its JSON row and the measured
/// cached-vs-reforward per-token speedup.
fn bench_preset(
    engine: &ReferenceBackend,
    name: &str,
    budget: Duration,
    results: &mut Vec<BenchResult>,
) -> DecodeCase {
    let p = engine.manifest().preset(name).unwrap().clone();
    let (b, s, d) = (p.model.batch, p.model.seq_len, p.model.n_heads * p.model.d_head);
    let state = ModelState::init(&p.blocks, 0);
    let blocks: Vec<RefTensor> =
        state.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();

    let prompt_len = s / 2;
    let prompt: Vec<i32> = (0..prompt_len).map(|i| 4 + (i % 50) as i32).collect();

    // --- prefill: prompt forward + cache fill (slot reset each iter by
    // --- never committing a length, so pos stays 0)
    let mut pool = KvPool::new(&p.model, b);
    let slots: Vec<usize> = (0..b).map(|_| pool.alloc().unwrap()).collect();
    for &slot in &slots {
        // views() only auto-maps the next row; a whole prompt needs its
        // pages mapped up front
        pool.ensure_room(slot, prompt_len).unwrap();
    }
    let prefill = bench(&format!("prefill/{name}/t{prompt_len}"), budget, || {
        let mut views = pool.views(&slots[..1]).unwrap();
        std::hint::black_box(
            engine.kv_prefill(&p, &blocks, &prompt, &mut views[0]).unwrap(),
        );
    });

    // --- cached decode: one batched step over b resident sequences
    // (positions frozen mid-context so every iteration costs the same)
    for &slot in &slots {
        let mut views = pool.views(&[slot]).unwrap();
        engine.kv_prefill(&p, &blocks, &prompt, &mut views[0]).unwrap();
        pool.set_len(slot, prompt_len);
    }
    let toks: Vec<i32> = (0..b as i32).map(|i| 5 + i).collect();
    let cached = bench(&format!("decode_kv/{name}/b{b}"), budget, || {
        let mut views = pool.views(&slots).unwrap();
        std::hint::black_box(
            engine.kv_decode_step(&p, &blocks, &toks, &mut views).unwrap(),
        );
    });

    // --- steady-state allocation probe: 10 further decode steps, with
    // positions actually advancing, must not grow the arena
    let warm = engine.workspace_stats();
    for _ in 0..10 {
        {
            let mut views = pool.views(&slots).unwrap();
            std::hint::black_box(
                engine.kv_decode_step(&p, &blocks, &toks, &mut views).unwrap(),
            );
        }
        for &slot in &slots {
            pool.advance(slot);
        }
    }
    let steady_grows = engine.workspace_stats().grows - warm.grows;

    // --- oracle: the pre-KV path, one full [b, s] reforward per token
    let exe = engine.load_preset_exe(name, "decode_step").unwrap();
    let tokens: Vec<i32> = (0..b * s).map(|i| 4 + (i % 50) as i32).collect();
    let tok = engine.upload_i32(&tokens, &[b, s]).unwrap();
    let mut args: Vec<&RefTensor> = blocks.iter().collect();
    args.push(&tok);
    let oracle = bench(&format!("decode_reforward/{name}/b{b}"), budget, || {
        std::hint::black_box(engine.execute(&exe, &args).unwrap());
    });

    // per generated token: both paths produce one token per sequence per
    // call, so per-token cost = call latency / batch
    let per_token_cached = cached.mean_ns / b as f64;
    let per_token_oracle = oracle.mean_ns / b as f64;
    let speedup = per_token_oracle / per_token_cached;
    let kv_pool_bytes = pool.capacity_bytes();
    let kv_in_use = pool.bytes();
    let kv_modeled = adagradselect::memory::kv_cache_bytes(&p.model, b, 4);
    println!(
        "    -> {name}: cached {:.1} µs/token vs reforward {:.1} µs/token = {speedup:.1}x; \
         kv {:.2} MiB; steady-state decode grows {steady_grows}",
        per_token_cached / 1e3,
        per_token_oracle / 1e3,
        kv_pool_bytes as f64 / (1024.0 * 1024.0),
    );

    let row = Value::obj(vec![
        ("preset", Value::str(name)),
        ("batch", Value::num(b as f64)),
        ("seq_len", Value::num(s as f64)),
        ("d", Value::num(d as f64)),
        ("prompt_len", Value::num(prompt_len as f64)),
        ("prefill_mean_ns", Value::num(prefill.mean_ns)),
        ("decode_step_mean_ns", Value::num(cached.mean_ns)),
        ("per_token_ns_cached", Value::num(per_token_cached)),
        ("per_token_ns_reforward", Value::num(per_token_oracle)),
        ("tokens_per_s_cached", Value::num(1e9 / per_token_cached)),
        ("tokens_per_s_reforward", Value::num(1e9 / per_token_oracle)),
        ("cached_vs_reforward_speedup", Value::num(speedup)),
        ("kv_bytes_pool", Value::num(kv_pool_bytes as f64)),
        ("kv_bytes_in_use", Value::num(kv_in_use as f64)),
        ("kv_bytes_modeled", Value::num(kv_modeled as f64)),
        ("steady_state_decode_grows_10_steps", Value::num(steady_grows as f64)),
    ]);
    results.push(prefill);
    results.push(cached);
    results.push(oracle);
    DecodeCase { row, speedup, seq_len: s, steady_grows }
}

fn main() {
    header("decode");
    let quick = std::env::var_os("AGSEL_BENCH_QUICK").is_some();
    let budget_ms: u64 = std::env::var("AGSEL_BENCH_BUDGET_MS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick { 150 } else { 1500 });
    let budget = Duration::from_millis(budget_ms);
    let engine = ReferenceBackend::new();
    let mut results: Vec<BenchResult> = Vec::new();

    // qwen-sim (seq_len 128) runs even in quick mode: it carries the
    // >= 5x-at-seq>=128 acceptance invariant
    let presets: &[&str] =
        if quick { &["test-tiny", "qwen-sim"] } else { &["test-tiny", "qwen-sim", "e2e"] };
    let mut rows = Vec::new();
    let mut invariants = Vec::new();
    let mut total_steady_grows = 0.0f64;
    for name in presets {
        let case = bench_preset(&engine, name, budget, &mut results);
        if case.seq_len >= 128 {
            invariants.push(Value::obj(vec![
                ("name", Value::str(format!("{name}/cached_vs_reforward_speedup"))),
                ("value", Value::num(case.speedup)),
                ("min", Value::num(5.0)),
            ]));
        }
        total_steady_grows += case.steady_grows as f64;
        rows.push(case.row);
    }

    let ws = engine.workspace_stats();
    let summary = Value::obj(vec![
        ("schema", Value::num(1.0)),
        ("quick", Value::Bool(quick)),
        ("budget_ms", Value::num(budget_ms as f64)),
        ("calibrated", Value::Bool(false)),
        ("results", Value::Arr(results.iter().map(result_row).collect())),
        ("decode", Value::Arr(rows)),
        ("invariants", Value::Arr(invariants)),
        (
            "workspace",
            Value::obj(vec![
                ("high_water_bytes", Value::num(ws.high_water_bytes as f64)),
                ("capacity_bytes", Value::num(ws.capacity_bytes as f64)),
                ("grows_total", Value::num(ws.grows as f64)),
                ("takes_total", Value::num(ws.takes as f64)),
                ("steady_state_grows_10_steps", Value::num(total_steady_grows)),
            ]),
        ),
    ]);
    let path = std::env::var("AGSEL_BENCH_DECODE_JSON")
        .unwrap_or_else(|_| "BENCH_decode.json".to_string());
    std::fs::write(&path, format!("{summary}\n")).expect("write bench summary");
    println!("\nwrote {path}");
}
