//! Residency-manager benchmarks + the §6 PCIe-bottleneck sweep: simulated
//! transfer/stall times per link model (PCIe Gen4 vs NVLink vs a starved
//! Gen3 x4 link), the quantitative version of the paper's limitation
//! analysis.

use std::time::Duration;

use adagradselect::optimizer::{PcieModel, ResidencyManager};
use adagradselect::util::bench::{bench, header};
use adagradselect::util::rng::Rng;

fn qwen_numels() -> Vec<usize> {
    (0..27).map(|i| if i == 0 || i == 26 { 6_144 } else { 110_000 }).collect()
}

fn main() {
    header("residency");
    let budget = Duration::from_millis(300);

    // state-machine overhead per step (pure bookkeeping)
    let numels = qwen_numels();
    let mut mgr = ResidencyManager::new(&numels, 2, PcieModel::default(), true);
    let mut rng = Rng::seed_from_u64(0);
    bench("residency_step/27-blocks-random-k8", budget, || {
        let mut sel: Vec<usize> = (0..27).collect();
        for i in 0..8 {
            let j = rng.gen_range(i, 27);
            sel.swap(i, j);
        }
        let mut sel = sel[..8].to_vec();
        sel.sort_unstable();
        std::hint::black_box(mgr.step(&sel, 0.01));
    });

    // stable selection: the hit path (no transfers)
    let mut mgr2 = ResidencyManager::new(&numels, 2, PcieModel::default(), true);
    let stable: Vec<usize> = (0..8).collect();
    mgr2.step(&stable, 0.01);
    bench("residency_step/stable-selection-hit-path", budget, || {
        std::hint::black_box(mgr2.step(&stable, 0.01));
    });

    // §6 sweep: how much stall each link model induces for a paper-scale
    // model (Qwen2.5-0.5B: ~494M params, 27 blocks, bf16 states) under a
    // worst-case selection churn (full turnover every step).
    println!("\n-- §6 PCIe-bottleneck sweep (paper-scale 0.5B model, full churn) --");
    let paper_numels: Vec<usize> = (0..27).map(|_| 494_000_000 / 27).collect();
    for (name, link) in [
        ("pcie4", PcieModel::default()),
        ("nvlink", PcieModel::nvlink()),
        ("pcie3x4", PcieModel::slow_gen3_x4()),
    ] {
        let mut m = ResidencyManager::new(&paper_numels, 2, link, true);
        let compute_s = 0.150; // measured-regime step time
        let mut total_stall = 0.0;
        for step in 0..100u64 {
            let sel: Vec<usize> = (0..8).map(|i| ((step as usize * 8) + i) % 27).collect();
            let mut sel = sel;
            sel.sort_unstable();
            sel.dedup();
            let t = m.step(&sel, compute_s);
            total_stall += t.stall_s;
        }
        println!(
            "  {name:<8} transfer {:>8.2} s  stall {:>8.3} s over 100 steps (hit rate {:.0}%)",
            m.stats.transfer_s,
            total_stall,
            m.stats.hit_rate() * 100.0
        );
    }
}
