//! End-to-end step benchmarks — one per paper table/figure row:
//!
//! * HLO execute latency per preset and entrypoint (the Fig. 1 wallclock
//!   numerator on this substrate);
//! * full trainer step per method on qwen-sim (measured CPU wallclock +
//!   modeled accelerator time side by side — the Fig. 1 / §5.3 source);
//! * decode-step latency (the serving path).

use std::path::PathBuf;
use std::time::Duration;

use adagradselect::config::{Method, RunConfig};
use adagradselect::model::ModelState;
use adagradselect::runtime::Engine;
use adagradselect::train::Trainer;
use adagradselect::util::bench::{bench, header};

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn bench_exe(engine: &Engine, preset: &str, entry: &str, budget: Duration) {
    let p = engine.manifest.preset(preset).unwrap().clone();
    let exe = match engine.load_preset_exe(preset, entry) {
        Ok(e) => e,
        Err(_) => return, // entrypoint not exported for this preset
    };
    let state = ModelState::init(&p.blocks, 0);
    let mut blocks: Vec<xla::PjRtBuffer> =
        state.flats.iter().map(|f| engine.upload_f32(f).unwrap()).collect();
    if entry.starts_with("train_step_lora") {
        // adapter inputs follow the base blocks
        let lora = ModelState::init(&p.lora_blocks, 1);
        blocks.extend(lora.flats.iter().map(|f| engine.upload_f32(f).unwrap()));
    }
    let (b, s) = (p.model.batch, p.model.seq_len);
    let tokens: Vec<i32> = (0..b * s).map(|i| 4 + (i % 50) as i32).collect();
    let tok = engine.upload_i32(&tokens, &[b, s]).unwrap();
    let tgt = engine.upload_i32(&tokens, &[b, s]).unwrap();
    let mut args: Vec<&xla::PjRtBuffer> = blocks.iter().collect();
    args.push(&tok);
    if entry != "decode_step" {
        args.push(&tgt);
    }
    bench(&format!("hlo_execute/{preset}/{entry}"), budget, || {
        std::hint::black_box(exe.run(&args).unwrap());
    });
}

fn main() {
    header("train_step");
    let budget = Duration::from_millis(1500);
    let engine = Engine::load(artifacts()).expect("run `make artifacts` first");

    for preset in ["test-tiny", "qwen-sim", "llama-sim", "phi-sim", "e2e"] {
        bench_exe(&engine, preset, "train_step", budget);
    }
    bench_exe(&engine, "qwen-sim", "train_step_pallas", budget);
    bench_exe(&engine, "qwen-sim", "train_step_lora", budget);
    bench_exe(&engine, "qwen-sim", "eval_loss", budget);
    bench_exe(&engine, "qwen-sim", "decode_step", budget);

    // §Perf before/after: literal inputs (host->device copy of *all*
    // params every call — the naive loop) vs device-resident buffers with
    // dirty-block re-upload (the trainer's hot path).
    {
        let p = engine.manifest.preset("qwen-sim").unwrap().clone();
        let exe = engine.load_preset_exe("qwen-sim", "train_step").unwrap();
        let state = ModelState::init(&p.blocks, 0);
        let (b, s) = (p.model.batch, p.model.seq_len);
        let tokens: Vec<i32> = (0..b * s).map(|i| 4 + (i % 50) as i32).collect();
        let mut lits: Vec<xla::Literal> = state
            .flats
            .iter()
            .map(|f| xla::Literal::vec1(f))
            .collect();
        lits.push(
            xla::Literal::vec1(&tokens).reshape(&[b as i64, s as i64]).unwrap(),
        );
        lits.push(
            xla::Literal::vec1(&tokens).reshape(&[b as i64, s as i64]).unwrap(),
        );
        bench("hlo_execute/qwen-sim/train_step_literal_inputs", budget, || {
            std::hint::black_box(exe.run_literals(&lits).unwrap());
        });
    }

    // full coordinator step per method (the Fig. 1 comparison, measured)
    println!("\n-- trainer step per method (qwen-sim): measured CPU + modeled accel --");
    for method in [
        Method::Full,
        Method::ags(10.0),
        Method::ags(30.0),
        Method::TopK { pct: 30.0 },
        Method::Lora { double_rank: false },
        Method::Lora { double_rank: true },
    ] {
        let mut cfg = RunConfig::preset_defaults("qwen-sim");
        cfg.method = method.clone();
        cfg.train.steps = u64::MAX;
        cfg.train.log_every = 0;
        cfg.artifacts_dir = artifacts();
        let mut t = Trainer::new(&engine, cfg).unwrap();
        t.step_once().unwrap(); // warm
        let r = bench(&format!("trainer_step/{}", method.label()), budget, || {
            t.step_once().unwrap();
        });
        let recs = &t.metrics.records;
        let sim: f64 =
            recs.iter().map(|x| x.t_step_sim).sum::<f64>() / recs.len() as f64;
        println!(
            "    -> measured {:.2} ms/step, modeled accel {:.2} ms/step",
            r.mean_s() * 1e3,
            sim * 1e3
        );
    }
}
