//! End-to-end step benchmarks — one per paper table/figure row:
//!
//! * reference-backend execute latency per preset and entrypoint (the
//!   Fig. 1 wallclock numerator on this substrate);
//! * full trainer step per method on qwen-sim (measured CPU wallclock +
//!   modeled accelerator time side by side — the Fig. 1 / §5.3 source);
//! * decode-step latency (the serving path).
//!
//! Runs on the default (reference) backend; point the harness at a PJRT
//! `Engine` under `--features pjrt` for artifact timings.

use std::time::Duration;

use adagradselect::config::{Method, RunConfig};
use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, ReferenceBackend};
use adagradselect::train::Trainer;
use adagradselect::util::bench::{bench, header};

fn bench_exe<B: Backend>(engine: &B, preset: &str, entry: &str, budget: Duration) {
    let p = engine.manifest().preset(preset).unwrap().clone();
    let exe = match engine.load_preset_exe(preset, entry) {
        Ok(e) => e,
        Err(_) => return, // entrypoint not exported for this preset
    };
    let state = ModelState::init(&p.blocks, 0);
    let mut blocks: Vec<B::Buffer> =
        state.flats.iter().map(|f| engine.upload_f32(f).unwrap()).collect();
    if entry.starts_with("train_step_lora") {
        // adapter inputs follow the base blocks
        let lora = ModelState::init(&p.lora_blocks, 1);
        blocks.extend(lora.flats.iter().map(|f| engine.upload_f32(f).unwrap()));
    }
    let (b, s) = (p.model.batch, p.model.seq_len);
    let tokens: Vec<i32> = (0..b * s).map(|i| 4 + (i % 50) as i32).collect();
    let tok = engine.upload_i32(&tokens, &[b, s]).unwrap();
    let tgt = engine.upload_i32(&tokens, &[b, s]).unwrap();
    let mut args: Vec<&B::Buffer> = blocks.iter().collect();
    args.push(&tok);
    if entry != "decode_step" {
        args.push(&tgt);
    }
    bench(&format!("execute/{preset}/{entry}"), budget, || {
        std::hint::black_box(engine.execute(&exe, &args).unwrap());
    });
}

fn main() {
    header("train_step");
    let quick = std::env::var_os("AGSEL_BENCH_QUICK").is_some();
    let budget = Duration::from_millis(if quick { 150 } else { 1500 });
    let engine = ReferenceBackend::new();

    let presets: &[&str] = if quick {
        &["test-tiny"]
    } else {
        &["test-tiny", "qwen-sim", "llama-sim", "phi-sim", "e2e"]
    };
    for preset in presets {
        bench_exe(&engine, preset, "train_step", budget);
    }
    let heavy = if quick { "test-tiny" } else { "qwen-sim" };
    bench_exe(&engine, heavy, "train_step_pallas", budget);
    bench_exe(&engine, heavy, "train_step_lora", budget);
    bench_exe(&engine, heavy, "eval_loss", budget);
    bench_exe(&engine, heavy, "decode_step", budget);

    // full coordinator step per method (the Fig. 1 comparison, measured)
    println!("\n-- trainer step per method ({heavy}): measured CPU + modeled accel --");
    for method in [
        Method::Full,
        Method::ags(10.0),
        Method::ags(30.0),
        Method::TopK { pct: 30.0 },
        Method::Lora { double_rank: false },
        Method::Lora { double_rank: true },
    ] {
        let mut cfg = RunConfig::preset_defaults(heavy);
        cfg.method = method.clone();
        cfg.train.steps = u64::MAX;
        cfg.train.log_every = 0;
        let mut t = Trainer::new(&engine, cfg).unwrap();
        t.step_once().unwrap(); // warm
        let r = bench(&format!("trainer_step/{}", method.label()), budget, || {
            t.step_once().unwrap();
        });
        let recs = &t.metrics.records;
        let sim: f64 =
            recs.iter().map(|x| x.t_step_sim).sum::<f64>() / recs.len() as f64;
        println!(
            "    -> measured {:.2} ms/step, modeled accel {:.2} ms/step",
            r.mean_s() * 1e3,
            sim * 1e3
        );
    }
}
