//! End-to-end step benchmarks — one per paper table/figure row:
//!
//! * naive-oracle vs blocked GEMM kernels at the `test-tiny` (golden
//!   parity) projection shapes and a paper-scale (`qwen-sim`) shape — the
//!   "before/after" for the kernel rewrite;
//! * reference-backend execute latency per preset and entrypoint (the
//!   Fig. 1 wallclock numerator on this substrate);
//! * full trainer step per method on qwen-sim (measured CPU wallclock +
//!   modeled accelerator time side by side — the Fig. 1 / §5.3 source);
//! * masked (exploit) vs full train step — the selection-gated backward's
//!   speedup and its reduced arena high-water mark, both recorded as
//!   machine-independent `invariants` that `scripts/bench_compare`
//!   enforces on every run (plus a trainer-level probe that a
//!   pure-exploit run performs zero gradient-norm reductions);
//! * device-resident fused exploit steps — observed boundary traffic at
//!   the backend's transfer counters, pinned as exact invariants:
//!   `d2h_bytes` == one 4-byte loss scalar per step, `h2d_bytes` == the
//!   batch + mask upload, zero steady-state device-buffer allocations and
//!   zero arena growth;
//! * sharded data-parallel steps (2 workers) in both collective shapes —
//!   the selection-gated all-reduce's byte counts pinned as exact
//!   invariants (exploit legs move selected params only, explore gathers
//!   every block plus one squared norm per block on the broadcast, both
//!   agreeing with the `CostModel` communication terms) along with zero
//!   steady-state allocations on every worker backend;
//! * decode-step latency (the serving path);
//! * a steady-state allocation probe over the backend's workspace arena;
//! * telemetry cost: fixed-selection trainer steps with the metric
//!   registry + span tracer fully on vs disabled — invariant
//!   `telemetry/overhead_ratio` (value = off/on wall, best of 3, min
//!   0.95) — and `telemetry/steady_state_zero_allocs` (the telemetry
//!   allocation fingerprint is unchanged across 10 instrumented steps).
//!
//! Besides the human-readable rows, the run writes a machine-readable
//! summary to `BENCH_train_step.json` (override with
//! `AGSEL_BENCH_TRAIN_JSON`): per-case mean/p50/p95 latency, the
//! kernel-level speedups, and the arena's high-water bytes plus the
//! number of slab allocations performed by the steady-state step loop
//! (expected: 0). CI uploads the file next to `BENCH_selection.json`, and
//! `scripts/bench_compare` diffs it against the checked-in baseline.
//!
//! Runs on the default (reference) backend; point the harness at a PJRT
//! `Engine` under `--features pjrt` for artifact timings.

use std::time::Duration;

use adagradselect::config::{Method, RunConfig};
use adagradselect::model::ModelState;
use adagradselect::runtime::{Backend, ReferenceBackend};
use adagradselect::train::{CostModel, CostModelParams, ExecMode, ShardedTrainer, Trainer};
use adagradselect::util::bench::{bench, header, BenchResult};
use adagradselect::util::gemm::{gemm_nn, gemm_tn, oracle};
use adagradselect::util::json::Value;
use adagradselect::util::rng::Rng;
use adagradselect::util::workspace::Workspace;

fn bench_exe<B: Backend>(
    engine: &B,
    preset: &str,
    entry: &str,
    budget: Duration,
) -> Option<BenchResult> {
    let p = engine.manifest().preset(preset).unwrap().clone();
    let exe = match engine.load_preset_exe(preset, entry) {
        Ok(e) => e,
        Err(_) => return None, // entrypoint not exported for this preset
    };
    let state = ModelState::init(&p.blocks, 0);
    let mut blocks: Vec<B::Buffer> =
        state.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();
    if entry.starts_with("train_step_lora") {
        // adapter inputs follow the base blocks
        let lora = ModelState::init(&p.lora_blocks, 1);
        blocks.extend(lora.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()));
    }
    let (b, s) = (p.model.batch, p.model.seq_len);
    let tokens: Vec<i32> = (0..b * s).map(|i| 4 + (i % 50) as i32).collect();
    let tok = engine.upload_i32(&tokens, &[b, s]).unwrap();
    let tgt = engine.upload_i32(&tokens, &[b, s]).unwrap();
    let mut args: Vec<&B::Buffer> = blocks.iter().collect();
    args.push(&tok);
    if entry != "decode_step" {
        args.push(&tgt);
    }
    Some(bench(&format!("execute/{preset}/{entry}"), budget, || {
        std::hint::black_box(engine.execute(&exe, &args).unwrap());
    }))
}

/// Naive-oracle vs blocked kernel at one GEMM shape; returns a JSON row.
/// The oracle preserves the pre-PR kernel's exact loop semantics but runs
/// single-threaded; at the test-tiny shapes the blocked kernel is below
/// its parallel threshold too, so that comparison is apples-to-apples.
#[allow(clippy::too_many_arguments)]
fn bench_gemm_pair(
    label: &str,
    tn: bool,
    m: usize,
    k: usize,
    n: usize,
    budget: Duration,
    results: &mut Vec<BenchResult>,
) -> Value {
    let mut rng = Rng::seed_from_u64(42);
    // operand storage: [m,k] for NN, [k,m] for the transposed-A product
    let a: Vec<f32> = (0..m * k).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect();
    let mut out = vec![0.0f32; m * n];
    let naive = bench(&format!("gemm_naive/{label}"), budget, || {
        if tn {
            oracle::matmul_tn(std::hint::black_box(&mut out), &a, &b, m, k, n, 1.0, false);
        } else {
            oracle::matmul_nn(std::hint::black_box(&mut out), &a, &b, m, k, n, 1.0, false);
        }
    });
    let mut ws = Workspace::new();
    let blocked = bench(&format!("gemm_blocked/{label}"), budget, || {
        if tn {
            gemm_tn(&mut ws, std::hint::black_box(&mut out), &a, &b, m, k, n, 1.0, false);
        } else {
            gemm_nn(&mut ws, std::hint::black_box(&mut out), &a, &b, m, k, n, 1.0, false);
        }
    });
    let speedup = naive.mean_ns / blocked.mean_ns;
    // above this many muladds the blocked kernel fans out over threads
    // while the oracle stays serial — flag those rows so the JSON never
    // passes a thread-count win off as a kernel win
    let blocked_parallel = m * k * n >= 1 << 20;
    println!(
        "    -> blocked is {speedup:.2}x vs serial naive at ({m},{k},{n}){}",
        if blocked_parallel { "  [blocked ran multi-threaded]" } else { "" }
    );
    let row = Value::obj(vec![
        ("shape", Value::str(format!("{label} ({m}x{k}x{n})"))),
        ("naive_mean_ns", Value::num(naive.mean_ns)),
        ("blocked_mean_ns", Value::num(blocked.mean_ns)),
        ("speedup_vs_serial_naive", Value::num(speedup)),
        ("blocked_ran_parallel", Value::Bool(blocked_parallel)),
    ]);
    results.push(naive);
    results.push(blocked);
    row
}

fn result_row(r: &BenchResult) -> Value {
    Value::obj(vec![
        ("name", Value::str(&r.name)),
        ("mean_ns", Value::num(r.mean_ns)),
        ("p50_ns", Value::num(r.p50_ns)),
        ("p95_ns", Value::num(r.p95_ns)),
        ("iters", Value::num(r.iters as f64)),
    ])
}

fn main() {
    header("train_step");
    let quick = std::env::var_os("AGSEL_BENCH_QUICK").is_some();
    let budget_ms: u64 = std::env::var("AGSEL_BENCH_BUDGET_MS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick { 150 } else { 1500 });
    let budget = Duration::from_millis(budget_ms);
    let engine = ReferenceBackend::new();
    let mut results: Vec<BenchResult> = Vec::new();

    // --- kernel before/after: naive oracle vs blocked GEMM ---
    println!("\n-- GEMM kernels: naive (pre-PR baseline) vs blocked --");
    let mut kernel_rows: Vec<Value> = Vec::new();
    // (label, transposed-A product, m, k, n) in product dims; the TN rows
    // are the xᵀ·dy weight-gradient shape where the naive kernel's
    // column-strided reads hurt most
    let shapes: &[(&str, bool, usize, usize, usize)] = &[
        ("test-tiny/qkv", false, 256, 32, 32),
        ("test-tiny/mlp-up", false, 256, 32, 96),
        ("test-tiny/mlp-down", false, 256, 96, 32),
        ("test-tiny/head", false, 256, 32, 64),
        ("test-tiny/wgrad-ta", true, 32, 256, 96),
        ("qwen-sim/mlp-up", false, 1024, 64, 176),
        ("qwen-sim/wgrad-ta", true, 64, 1024, 176),
    ];
    for &(label, tn, m, k, n) in shapes {
        if quick && m.max(k) > 256 {
            continue;
        }
        kernel_rows.push(bench_gemm_pair(label, tn, m, k, n, budget, &mut results));
    }

    // --- backend execute latency per preset/entry ---
    println!();
    let presets: &[&str] = if quick {
        &["test-tiny"]
    } else {
        &["test-tiny", "qwen-sim", "llama-sim", "phi-sim", "e2e"]
    };
    for preset in presets {
        results.extend(bench_exe(&engine, preset, "train_step", budget));
    }
    let heavy = if quick { "test-tiny" } else { "qwen-sim" };
    results.extend(bench_exe(&engine, heavy, "train_step_pallas", budget));
    results.extend(bench_exe(&engine, heavy, "train_step_lora", budget));
    results.extend(bench_exe(&engine, heavy, "eval_loss", budget));
    results.extend(bench_exe(&engine, heavy, "decode_step", budget));

    // --- steady-state allocation probe over the workspace arena ---
    let steady_grows = {
        let p = engine.manifest().preset("test-tiny").unwrap().clone();
        let exe = engine.load_preset_exe("test-tiny", "train_step").unwrap();
        let state = ModelState::init(&p.blocks, 0);
        let bufs: Vec<_> =
            state.flats.iter().map(|f| engine.upload_f32(f, &[f.len()]).unwrap()).collect();
        let (b, s) = (p.model.batch, p.model.seq_len);
        let tokens: Vec<i32> = (0..b * s).map(|i| 4 + (i % 50) as i32).collect();
        let tok = engine.upload_i32(&tokens, &[b, s]).unwrap();
        let mut args: Vec<_> = bufs.iter().collect();
        args.push(&tok);
        args.push(&tok);
        // one warm-up step: the decode benches above disowned their logits
        // buffers (outputs leave the arena), so the pool must refill once
        std::hint::black_box(engine.execute(&exe, &args).unwrap());
        let warm = engine.workspace_stats();
        for _ in 0..10 {
            std::hint::black_box(engine.execute(&exe, &args).unwrap());
        }
        engine.workspace_stats().grows - warm.grows
    };
    let steady = engine.workspace_stats();
    println!(
        "\n-- workspace arena: high-water {:.2} MiB, steady-state slab allocations over 10 \
         steps: {steady_grows} --",
        steady.high_water_bytes as f64 / (1024.0 * 1024.0)
    );

    // --- masked (exploit) step vs the full backward ---
    // Fresh backend so the arena peaks are phase-attributable: warm a
    // step shape, reset the high-water mark, measure, snapshot.
    println!("\n-- masked exploit step vs full step ({heavy}) --");
    let mut invariants: Vec<Value> = Vec::new();
    {
        let engine2 = ReferenceBackend::new();
        let p = engine2.manifest().preset(heavy).unwrap().clone();
        let exe_full = engine2.load_preset_exe(heavy, "train_step").unwrap();
        let exe_masked = engine2.load_preset_exe(heavy, "train_step_masked").unwrap();
        let state = ModelState::init(&p.blocks, 0);
        let bufs: Vec<_> =
            state.flats.iter().map(|f| engine2.upload_f32(f, &[f.len()]).unwrap()).collect();
        let (b, s) = (p.model.batch, p.model.seq_len);
        let tokens: Vec<i32> = (0..b * s).map(|i| 4 + (i % 50) as i32).collect();
        let tok = engine2.upload_i32(&tokens, &[b, s]).unwrap();
        let n = p.blocks.len();
        // steady-state exploit selections concentrate at the top of the
        // stack; top block + head is the paper's ~10% shape
        let mask_vec: Vec<i32> = (0..n).map(|i| i32::from(i >= n - 2)).collect();
        let mask = engine2.upload_i32(&mask_vec, &[n]).unwrap();
        let mut args_full: Vec<&<ReferenceBackend as Backend>::Buffer> =
            bufs.iter().collect();
        args_full.push(&tok);
        args_full.push(&tok);
        let mut args_masked = args_full.clone();
        args_masked.push(&mask);

        std::hint::black_box(engine2.execute(&exe_full, &args_full).unwrap());
        engine2.reset_workspace_high_water();
        let full_r = bench(&format!("masked_pair/{heavy}/full"), budget, || {
            std::hint::black_box(engine2.execute(&exe_full, &args_full).unwrap());
        });
        let full_hw = engine2.workspace_stats().high_water_bytes;

        std::hint::black_box(engine2.execute(&exe_masked, &args_masked).unwrap());
        engine2.reset_workspace_high_water();
        let grows_before = engine2.workspace_stats().grows;
        let masked_r = bench(&format!("masked_pair/{heavy}/masked"), budget, || {
            std::hint::black_box(engine2.execute(&exe_masked, &args_masked).unwrap());
        });
        let masked_hw = engine2.workspace_stats().high_water_bytes;
        let masked_grows = engine2.workspace_stats().grows - grows_before;

        let speedup = full_r.mean_ns / masked_r.mean_ns;
        let hw_reduction = full_hw as f64 / masked_hw.max(1) as f64;
        println!(
            "    -> masked step {speedup:.2}x faster; arena high-water {:.2} MiB -> {:.2} MiB \
             ({hw_reduction:.2}x), steady-state masked grows {masked_grows}",
            full_hw as f64 / (1024.0 * 1024.0),
            masked_hw as f64 / (1024.0 * 1024.0),
        );
        // machine-independent floors enforced by scripts/bench_compare on
        // every run, calibrated baseline or not
        let inv = |name: &str, value: f64, min: f64| {
            Value::obj(vec![
                ("name", Value::str(name)),
                ("value", Value::num(value)),
                ("min", Value::num(min)),
            ])
        };
        invariants.push(inv("masked_vs_full_train_step_speedup", speedup, 1.1));
        invariants.push(inv("masked_step_arena_high_water_reduction", hw_reduction, 1.1));
        invariants.push(inv(
            "masked_steady_state_zero_grows",
            if masked_grows == 0 { 1.0 } else { 0.0 },
            1.0,
        ));
        results.push(full_r);
        results.push(masked_r);
    }

    // --- trainer-level probe: a pure-exploit run (ε₀ = 0, no clipping)
    // --- must take the masked kernel every step and never touch a
    // --- gradient norm — the paper's "avoids gradient access" property
    {
        let mut cfg = RunConfig::preset_defaults(heavy);
        cfg.method = Method::AdaGradSelect {
            pct: 30.0,
            eps0: 0.0,
            lambda: None,
            delta: 1.0,
            explore_after_epoch1: false,
            uniform_exploit: false,
        };
        cfg.train.steps = u64::MAX;
        cfg.train.log_every = 0;
        cfg.train.grad_clip = None;
        let mut t = Trainer::new(&engine, cfg).unwrap();
        let probe_steps = 6u64;
        for _ in 0..probe_steps {
            t.step_once().unwrap();
        }
        let ok = t.norm_reduced_blocks() == 0 && t.masked_steps() == probe_steps;
        println!(
            "\n-- exploit-only trainer probe: {} norm reductions, {}/{} masked steps --",
            t.norm_reduced_blocks(),
            t.masked_steps(),
            probe_steps
        );
        invariants.push(Value::obj(vec![
            ("name", Value::str("exploit_steps_zero_norm_reductions")),
            ("value", Value::num(if ok { 1.0 } else { 0.0 })),
            ("min", Value::num(1.0)),
        ]));
    }

    // --- device-resident exploit step: observed boundary traffic ---
    // A fused exploit step's only crossings must be the batch + mask
    // upload and the 4-byte loss scalar download, with zero steady-state
    // device-buffer allocations and zero arena slab growth. These are the
    // paper's device-residency claims measured at the backend boundary,
    // enforced by bench_compare as exact machine-independent invariants.
    {
        let engine3 = ReferenceBackend::new();
        let p = engine3.manifest().preset(heavy).unwrap().clone();
        let n = p.blocks.len();
        let (b, s) = (p.model.batch, p.model.seq_len);
        let mut cfg = RunConfig::preset_defaults(heavy);
        // a fixed selection keeps the mask (and therefore the masked
        // kernel's arena shape) identical across steps
        cfg.method = Method::Fixed { blocks: vec![n - 2, n - 1] };
        cfg.train.steps = u64::MAX;
        cfg.train.log_every = 0;
        cfg.train.grad_clip = None;
        let mut t = Trainer::new(&engine3, cfg).unwrap();
        assert_eq!(t.exec_mode(), ExecMode::DeviceResident);
        // warm-up: first step syncs the device step tensor (4 bytes) and
        // fills the buffer pool; second step proves the pool is warm
        for _ in 0..2 {
            t.step_once().unwrap();
        }
        let ws0 = engine3.workspace_stats();
        let probe_steps = 6u64;
        let r = bench(&format!("fused_device_step/{heavy}"), budget, || {
            t.step_once().unwrap();
        });
        // the bench ran an unknown number of iterations; re-measure a
        // fixed window for the exact byte counts
        let ts_mid = engine3.transfer_stats();
        for _ in 0..probe_steps {
            t.step_once().unwrap();
        }
        let ts = engine3.transfer_stats().delta_since(&ts_mid);
        let ws = engine3.workspace_stats();
        let want_h2d = probe_steps * (2 * (b * s) as u64 + n as u64) * 4;
        let want_d2h = probe_steps * 4;
        println!(
            "\n-- device-resident exploit steps ({heavy}): h2d {}B/step (batch+mask {}B), \
             d2h {}B/step, {} buffer allocs, {} arena grows over {probe_steps} steps --",
            ts.h2d_bytes / probe_steps,
            want_h2d / probe_steps,
            ts.d2h_bytes / probe_steps,
            ts.buffer_allocs,
            ws.grows - ws0.grows,
        );
        let inv = |name: &str, ok: bool| {
            Value::obj(vec![
                ("name", Value::str(name)),
                ("value", Value::num(if ok { 1.0 } else { 0.0 })),
                ("min", Value::num(1.0)),
            ])
        };
        invariants.push(inv("exploit_d2h_loss_scalar_only", ts.d2h_bytes == want_d2h));
        invariants.push(inv("exploit_h2d_batch_mask_only", ts.h2d_bytes == want_h2d));
        invariants.push(inv("fused_steady_state_zero_buffer_allocs", ts.buffer_allocs == 0));
        invariants.push(inv("fused_steady_state_zero_arena_grows", ws.grows == ws0.grows));
        invariants.push(inv(
            "fused_steps_all_fused",
            t.fused_steps() == t.metrics.records.len() as u64 && t.norm_reduced_blocks() == 0,
        ));
        results.push(r);
    }

    // --- telemetry: trainer-step overhead + zero-allocation probe ---
    // Fixed selection keeps every step identical, so the on/off pair
    // differ only in instrumentation; best-of-3 windows reject scheduler
    // noise.
    {
        let engine4 = ReferenceBackend::new();
        let p = engine4.manifest().preset(heavy).unwrap().clone();
        let n = p.blocks.len();
        let make_cfg = || {
            let mut cfg = RunConfig::preset_defaults(heavy);
            cfg.method = Method::Fixed { blocks: vec![n - 2, n - 1] };
            cfg.train.steps = u64::MAX;
            cfg.train.log_every = 0;
            cfg.train.grad_clip = None;
            cfg
        };
        let window = if quick { 4 } else { 8 };
        let run = |telemetry: bool| -> f64 {
            let mut t = Trainer::new(&engine4, make_cfg()).unwrap();
            if telemetry {
                t.telemetry().enable_tracing(8192);
            } else {
                t.telemetry().set_enabled(false);
            }
            for _ in 0..2 {
                t.step_once().unwrap(); // warm: device sync + buffer pool
            }
            let mut best = f64::INFINITY;
            for _ in 0..3 {
                let t0 = std::time::Instant::now();
                for _ in 0..window {
                    t.step_once().unwrap();
                }
                best = best.min(t0.elapsed().as_secs_f64() / window as f64);
            }
            best
        };
        let on_s = run(true);
        let off_s = run(false);
        let tel_ratio = off_s / on_s.max(1e-12);
        println!(
            "\n-- telemetry: {:.2} ms/step instrumented vs {:.2} ms off (off/on {tel_ratio:.3}) --",
            on_s * 1e3,
            off_s * 1e3,
        );
        invariants.push(Value::obj(vec![
            ("name", Value::str("telemetry/overhead_ratio")),
            ("value", Value::num(tel_ratio)),
            ("min", Value::num(0.95)),
        ]));
        // instrumented steady-state steps must not grow any telemetry
        // allocation (cells, preallocated buckets, preallocated ring)
        let mut t = Trainer::new(&engine4, make_cfg()).unwrap();
        t.telemetry().enable_tracing(4096);
        for _ in 0..2 {
            t.step_once().unwrap();
        }
        let fp0 = t.telemetry().fingerprint();
        for _ in 0..10 {
            t.step_once().unwrap();
        }
        let tel_no_alloc = if t.telemetry().fingerprint() == fp0 { 1.0 } else { 0.0 };
        println!(
            "-- telemetry: allocation fingerprint {} across 10 instrumented steps --",
            if tel_no_alloc == 1.0 { "stable" } else { "CHANGED" },
        );
        invariants.push(Value::obj(vec![
            ("name", Value::str("telemetry/steady_state_zero_allocs")),
            ("value", Value::num(tel_no_alloc)),
            ("min", Value::num(1.0)),
        ]));
    }

    // --- sharded data-parallel step: the selection-gated all-reduce ---
    // Byte exactness at the CommStats counters for both step shapes
    // (exploit all-reduce == selected params × 4 per leg × workers,
    // explore gather == every block, norm broadcast == one f32 squared
    // norm per block per worker), agreement with the CostModel's
    // communication terms, and zero steady-state allocations on every
    // worker backend — all enforced by bench_compare as exact invariants.
    {
        let shards = 2usize;
        let p = engine.manifest().preset(heavy).unwrap().clone();
        let numels = p.block_numels();
        let n_blocks = numels.len();
        let p_total: u64 = numels.iter().map(|&d| d as u64).sum();
        let sel = vec![n_blocks - 2, n_blocks - 1];
        let p_sel: u64 = sel.iter().map(|&b| numels[b] as u64).sum();
        let cost = CostModel::new(&p, CostModelParams::default(), p.model.lora_rank);
        let probe_steps = 4u64;
        let make_cfg = |method: Method| {
            let mut cfg = RunConfig::preset_defaults(heavy);
            cfg.method = method;
            cfg.train.steps = u64::MAX;
            cfg.train.log_every = 0;
            cfg.train.grad_clip = None;
            cfg
        };

        // exploit shape: a fixed selection keeps upload shapes and arena
        // footprints identical across steps
        let mut t =
            ShardedTrainer::new(make_cfg(Method::Fixed { blocks: sel.clone() }), shards).unwrap();
        for _ in 0..2 {
            t.step_once().unwrap();
        }
        let r = bench(&format!("sharded_step/{heavy}/x{shards}/exploit"), budget, || {
            t.step_once().unwrap();
        });
        let c0 = t.comm_stats();
        let w0 = t.worker_stats().unwrap();
        for _ in 0..probe_steps {
            t.step_once().unwrap();
        }
        let d = t.comm_stats().delta_since(&c0);
        let w1 = t.worker_stats().unwrap();
        let want_leg = probe_steps * shards as u64 * p_sel * 4;
        let exploit_exact = d.grad_gather_bytes == want_leg
            && d.grad_bcast_bytes == want_leg
            && d.norm_bcast_bytes == 0
            && d.allreduce_ops == probe_steps;
        let exploit_model = cost.exploit_comm_bytes(&sel, 2 * shards) * probe_steps as f64;
        let exploit_model_match =
            (d.grad_gather_bytes + d.grad_bcast_bytes) as f64 == exploit_model;
        let zero_allocs = w0.iter().zip(&w1).all(|(a, b)| {
            b.transfers.delta_since(&a.transfers).buffer_allocs == 0 && a.ws_grows == b.ws_grows
        });
        println!(
            "\n-- sharded x{shards} ({heavy}): exploit all-reduce {} B/step \
             (gather {} + bcast {}), {} steady-state worker allocs --",
            (d.grad_gather_bytes + d.grad_bcast_bytes) / probe_steps,
            d.grad_gather_bytes / probe_steps,
            d.grad_bcast_bytes / probe_steps,
            if zero_allocs { "zero" } else { "NONZERO" },
        );
        results.push(r);

        // explore shape: top-k ranks every step — full gather, squared
        // norms ride the broadcast
        let mut t = ShardedTrainer::new(make_cfg(Method::TopK { pct: 30.0 }), shards).unwrap();
        for _ in 0..2 {
            t.step_once().unwrap();
        }
        let r = bench(&format!("sharded_step/{heavy}/x{shards}/explore"), budget, || {
            t.step_once().unwrap();
        });
        let c0 = t.comm_stats();
        for _ in 0..probe_steps {
            t.step_once().unwrap();
        }
        let d = t.comm_stats().delta_since(&c0);
        let want_gather = probe_steps * shards as u64 * p_total * 4;
        let want_norms = probe_steps * shards as u64 * n_blocks as u64 * 4;
        let explore_exact = d.grad_gather_bytes == want_gather
            && d.norm_bcast_bytes == want_norms
            && d.allreduce_ops == 2 * probe_steps;
        let explore_model = cost.explore_comm_bytes(shards, shards) * probe_steps as f64;
        let explore_model_match =
            (d.grad_gather_bytes + d.norm_bcast_bytes) as f64 == explore_model;
        println!(
            "-- sharded x{shards} ({heavy}): explore gather {} B/step, norm bcast {} B/step \
             (exploit gather is {:.1}x smaller) --",
            d.grad_gather_bytes / probe_steps,
            d.norm_bcast_bytes / probe_steps,
            want_gather as f64 / want_leg.max(1) as f64,
        );
        results.push(r);

        let inv = |name: &str, ok: bool| {
            Value::obj(vec![
                ("name", Value::str(name)),
                ("value", Value::num(if ok { 1.0 } else { 0.0 })),
                ("min", Value::num(1.0)),
            ])
        };
        invariants.push(inv("sharded_exploit_allreduce_bytes_exact", exploit_exact));
        invariants.push(inv("sharded_explore_allreduce_bytes_exact", explore_exact));
        invariants.push(inv(
            "sharded_comm_matches_cost_model",
            exploit_model_match && explore_model_match,
        ));
        invariants.push(inv("sharded_steady_state_zero_allocs", zero_allocs));
    }

    // --- full coordinator step per method (the Fig. 1 comparison) ---
    println!("\n-- trainer step per method ({heavy}): measured CPU + modeled accel --");
    for method in [
        Method::Full,
        Method::ags(10.0),
        Method::ags(30.0),
        Method::TopK { pct: 30.0 },
        Method::Lora { double_rank: false },
        Method::Lora { double_rank: true },
    ] {
        let mut cfg = RunConfig::preset_defaults(heavy);
        cfg.method = method.clone();
        cfg.train.steps = u64::MAX;
        cfg.train.log_every = 0;
        let mut t = Trainer::new(&engine, cfg).unwrap();
        t.step_once().unwrap(); // warm
        let r = bench(&format!("trainer_step/{}", method.label()), budget, || {
            t.step_once().unwrap();
        });
        let recs = &t.metrics.records;
        let sim: f64 =
            recs.iter().map(|x| x.t_step_sim).sum::<f64>() / recs.len() as f64;
        println!(
            "    -> measured {:.2} ms/step, modeled accel {:.2} ms/step",
            r.mean_s() * 1e3,
            sim * 1e3
        );
        results.push(r);
    }

    // the audit feature must be compiled out of bench builds (see the
    // serve bench's matching invariant): 1.0 iff audit is off
    invariants.push(Value::obj(vec![
        ("name", Value::str("audit/compiled_out")),
        ("value", Value::num(if cfg!(feature = "audit") { 0.0 } else { 1.0 })),
        ("min", Value::num(1.0)),
    ]));

    // --- machine-readable summary next to BENCH_selection.json ---
    let ws_stats = engine.workspace_stats();
    let summary = Value::obj(vec![
        ("schema", Value::num(1.0)),
        ("quick", Value::Bool(quick)),
        ("budget_ms", Value::num(budget_ms as f64)),
        // a raw run is never a calibrated baseline; only
        // `scripts/bench_compare --write-baseline` stamps calibrated:true
        ("calibrated", Value::Bool(false)),
        ("results", Value::Arr(results.iter().map(result_row).collect())),
        ("kernel_speedups", Value::Arr(kernel_rows)),
        // machine-independent floors checked by scripts/bench_compare
        // unconditionally (masked-step speedup, arena reduction,
        // exploit-step zero-norm-reduction)
        ("invariants", Value::Arr(invariants)),
        (
            "workspace",
            Value::obj(vec![
                ("high_water_bytes", Value::num(ws_stats.high_water_bytes as f64)),
                ("capacity_bytes", Value::num(ws_stats.capacity_bytes as f64)),
                ("grows_total", Value::num(ws_stats.grows as f64)),
                ("takes_total", Value::num(ws_stats.takes as f64)),
                ("steady_state_grows_10_steps", Value::num(steady_grows as f64)),
            ]),
        ),
    ]);
    let path = std::env::var("AGSEL_BENCH_TRAIN_JSON")
        .unwrap_or_else(|_| "BENCH_train_step.json".to_string());
    std::fs::write(&path, format!("{summary}\n")).expect("write bench summary");
    println!("\nwrote {path}");
}
