//! Typed view of `artifacts/manifest.json`, the contract between the
//! build-time Python side (`python/compile/aot.py`) and this coordinator.
//!
//! The manifest is the *single source of truth* for model topology: block
//! tables (tensor names/shapes/offsets inside each flat block vector),
//! tokenizer vocabulary, AdamW hyperparameters baked into the kernels, and
//! the artifact filename for every entrypoint. The Rust side never
//! hardcodes any of these.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub tokenizer: TokenizerSpec,
    /// Flat chunk size used by the shared AdamW / grad-norm artifacts.
    pub chunk_size: usize,
    pub adamw: AdamWHyper,
    pub shared: HashMap<String, ArtifactInfo>,
    pub presets: HashMap<String, Preset>,
}

#[derive(Debug, Clone)]
pub struct TokenizerSpec {
    pub chars: String,
    pub vocab_size: usize,
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
    pub unk: i32,
}

#[derive(Debug, Clone, Copy)]
pub struct AdamWHyper {
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    pub wd: f32,
}

#[derive(Debug, Clone)]
pub struct Preset {
    pub model: ModelSpec,
    pub blocks: Vec<BlockSpec>,
    pub lora_blocks: Vec<BlockSpec>,
    /// LoRA block table at rank*2 (the paper's r=256 analogue).
    pub lora_blocks2: Vec<BlockSpec>,
    pub total_params: usize,
    pub artifacts: HashMap<String, ArtifactInfo>,
}

#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub lora_rank: usize,
    pub d_head: usize,
    pub norm_eps: f32,
    pub rope_theta: f32,
    pub init_std: f32,
}

#[derive(Debug, Clone)]
pub struct BlockSpec {
    pub name: String,
    pub numel: usize,
    pub tensors: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// `"normal:<std>" | "ones" | "zeros"` — mirrored by `ModelState::init`.
    pub init: String,
    pub offset: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub file: String,
    pub n_inputs: usize,
    pub bytes: usize,
    pub lower_s: f64,
}

impl Manifest {
    /// The built-in preset catalog (no artifacts directory needed) — the
    /// topology source for [`crate::runtime::ReferenceBackend`]. Identical
    /// layout rules to the AOT-exported `manifest.json`.
    pub fn builtin() -> Self {
        super::presets::builtin_manifest()
    }

    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let v = Value::parse(&text).context("parsing manifest.json")?;
        Self::from_json(&v).context("decoding manifest.json")
    }

    fn from_json(v: &Value) -> Result<Self> {
        let tok = v.get("tokenizer")?;
        let adamw = v.get("adamw")?;
        let mut shared = HashMap::new();
        for (k, a) in v.get("shared")?.as_obj()? {
            shared.insert(k.clone(), artifact_from_json(a)?);
        }
        let mut presets = HashMap::new();
        for (k, pv) in v.get("presets")?.as_obj()? {
            presets.insert(k.clone(), preset_from_json(pv)?);
        }
        Ok(Manifest {
            version: v.get("version")?.as_usize()? as u32,
            tokenizer: TokenizerSpec {
                chars: tok.get("chars")?.as_str()?.to_string(),
                vocab_size: tok.get("vocab_size")?.as_usize()?,
                pad: tok.get("pad")?.as_i64()? as i32,
                bos: tok.get("bos")?.as_i64()? as i32,
                eos: tok.get("eos")?.as_i64()? as i32,
                unk: tok.get("unk")?.as_i64()? as i32,
            },
            chunk_size: v.get("chunk_size")?.as_usize()?,
            adamw: AdamWHyper {
                b1: adamw.get("b1")?.as_f32()?,
                b2: adamw.get("b2")?.as_f32()?,
                eps: adamw.get("eps")?.as_f32()?,
                wd: adamw.get("wd")?.as_f32()?,
            },
            shared,
            presets,
        })
    }

    pub fn preset(&self, name: &str) -> Result<&Preset> {
        self.presets.get(name).ok_or_else(|| {
            let known: Vec<_> = self.presets.keys().cloned().collect();
            anyhow!("unknown preset {name:?}; manifest has {known:?}")
        })
    }
}

fn artifact_from_json(v: &Value) -> Result<ArtifactInfo> {
    Ok(ArtifactInfo {
        file: v.get("file")?.as_str()?.to_string(),
        n_inputs: v.get("n_inputs")?.as_usize()?,
        bytes: v.get("bytes")?.as_usize()?,
        lower_s: v.opt("lower_s").map(|x| x.as_f64()).transpose()?.unwrap_or(0.0),
    })
}

fn tensor_from_json(v: &Value) -> Result<TensorSpec> {
    Ok(TensorSpec {
        name: v.get("name")?.as_str()?.to_string(),
        shape: v.get("shape")?.as_arr()?.iter().map(|x| x.as_usize()).collect::<Result<_>>()?,
        init: v.get("init")?.as_str()?.to_string(),
        offset: v.get("offset")?.as_usize()?,
    })
}

fn block_from_json(v: &Value) -> Result<BlockSpec> {
    Ok(BlockSpec {
        name: v.get("name")?.as_str()?.to_string(),
        numel: v.get("numel")?.as_usize()?,
        tensors: v.get("tensors")?.as_arr()?.iter().map(tensor_from_json).collect::<Result<_>>()?,
    })
}

fn blocks_from_json(v: &Value) -> Result<Vec<BlockSpec>> {
    v.as_arr()?.iter().map(block_from_json).collect()
}

fn preset_from_json(v: &Value) -> Result<Preset> {
    let m = v.get("model")?;
    let mut artifacts = HashMap::new();
    for (k, a) in v.get("artifacts")?.as_obj()? {
        artifacts.insert(k.clone(), artifact_from_json(a)?);
    }
    Ok(Preset {
        model: ModelSpec {
            name: m.get("name")?.as_str()?.to_string(),
            d_model: m.get("d_model")?.as_usize()?,
            n_layers: m.get("n_layers")?.as_usize()?,
            n_heads: m.get("n_heads")?.as_usize()?,
            d_ff: m.get("d_ff")?.as_usize()?,
            vocab: m.get("vocab")?.as_usize()?,
            seq_len: m.get("seq_len")?.as_usize()?,
            batch: m.get("batch")?.as_usize()?,
            lora_rank: m.get("lora_rank")?.as_usize()?,
            d_head: m.get("d_head")?.as_usize()?,
            norm_eps: m.get("norm_eps")?.as_f32()?,
            rope_theta: m.get("rope_theta")?.as_f32()?,
            init_std: m.get("init_std")?.as_f32()?,
        },
        blocks: blocks_from_json(v.get("blocks")?)?,
        lora_blocks: blocks_from_json(v.get("lora_blocks")?)?,
        lora_blocks2: blocks_from_json(v.get("lora_blocks2")?)?,
        total_params: v.get("total_params")?.as_usize()?,
        artifacts,
    })
}

impl Preset {
    /// Number of paper-"blocks" (embed + layers + head).
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn artifact(&self, entry: &str) -> Result<&ArtifactInfo> {
        self.artifacts.get(entry).ok_or_else(|| {
            anyhow!(
                "preset {} has no artifact {entry:?} (have: {:?})",
                self.model.name,
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn artifact_path(&self, dir: &Path, entry: &str) -> Result<PathBuf> {
        Ok(dir.join(&self.artifact(entry)?.file))
    }

    /// Block sizes in elements, in block order.
    pub fn block_numels(&self) -> Vec<usize> {
        self.blocks.iter().map(|b| b.numel).collect()
    }

    /// The paper's practitioner guideline: min selection percentage that
    /// still updates at least one block every iteration (`min% >= 100/B`).
    pub fn min_selection_pct(&self) -> f64 {
        100.0 / self.n_blocks() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_has_presets() {
        let m = Manifest::builtin();
        for name in ["test-tiny", "qwen-sim", "llama-sim", "phi-sim", "e2e"] {
            let p = m.preset(name).unwrap();
            assert_eq!(p.n_blocks(), p.model.n_layers + 2, "{name}");
            assert_eq!(
                p.total_params,
                p.blocks.iter().map(|b| b.numel).sum::<usize>()
            );
        }
    }

    #[test]
    fn qwen_sim_matches_paper_block_count() {
        // Qwen2.5-0.5B has 25 transformer blocks in the paper.
        let m = Manifest::builtin();
        assert_eq!(m.preset("qwen-sim").unwrap().model.n_layers, 25);
        assert_eq!(m.preset("llama-sim").unwrap().model.n_layers, 18);
        assert_eq!(m.preset("phi-sim").unwrap().model.n_layers, 32);
    }

    #[test]
    fn tensor_offsets_contiguous() {
        let m = Manifest::builtin();
        for b in &m.preset("qwen-sim").unwrap().blocks {
            let mut off = 0;
            for t in &b.tensors {
                assert_eq!(t.offset, off, "{}/{}", b.name, t.name);
                off += t.shape.iter().product::<usize>();
            }
            assert_eq!(off, b.numel);
        }
    }

    #[test]
    fn min_selection_pct_guideline() {
        let m = Manifest::builtin();
        let p = m.preset("qwen-sim").unwrap();
        // 27 blocks (embed + 25 + head) => ~3.7%
        assert!((p.min_selection_pct() - 100.0 / 27.0).abs() < 1e-9);
    }

    #[test]
    fn missing_manifest_reports_helpful_error() {
        let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
