//! Built-in model presets — the native mirror of `python/compile/presets.py`.
//!
//! The reference backend needs the full model topology (block tables,
//! tokenizer, AdamW hyperparameters) without any `artifacts/manifest.json`
//! on disk, so the preset catalog is constructed here in Rust. The layout
//! rules are identical to the Python side (same tensor order, shapes and
//! init specs), which is what keeps the two backends' parameter vectors
//! bit-compatible: a checkpoint trained on one backend loads on the other.

use std::collections::HashMap;

use super::manifest::{
    AdamWHyper, ArtifactInfo, BlockSpec, Manifest, ModelSpec, Preset, TensorSpec, TokenizerSpec,
};

/// Char-level vocabulary shared with `python/compile/tokenizer.py`.
pub const TOKENIZER_CHARS: &str = " 0123456789abcdefghijklmnopqrstuvwxyz+-*/=().,?#:'%$\n";
pub const VOCAB_SIZE: usize = 64;

/// Flat chunk size of the shared AdamW / grad-norm kernels
/// (`python/compile/kernels/adamw.py`).
pub const CHUNK_SIZE: usize = 65536;

/// Projections adapted by LoRA: every weight matrix in a layer.
const LORA_PROJS: [&str; 7] = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"];

struct BlockBuilder {
    spec: BlockSpec,
}

impl BlockBuilder {
    fn new(name: &str) -> Self {
        Self { spec: BlockSpec { name: name.to_string(), numel: 0, tensors: Vec::new() } }
    }

    fn add(mut self, name: &str, shape: &[usize], init: &str) -> Self {
        let numel: usize = shape.iter().product();
        self.spec.tensors.push(TensorSpec {
            name: name.to_string(),
            shape: shape.to_vec(),
            init: init.to_string(),
            offset: self.spec.numel,
        });
        self.spec.numel += numel;
        self
    }

    fn build(self) -> BlockSpec {
        self.spec
    }
}

/// The paper's block decomposition: embed | layer 0..L-1 | final norm+head.
pub fn block_table(m: &ModelSpec) -> Vec<BlockSpec> {
    let std = format!("normal:{}", m.init_std);
    // residual-branch output projections get the depth-scaled init
    let out_std = format!(
        "normal:{}",
        m.init_std as f64 / (2.0 * m.n_layers as f64).sqrt()
    );
    let mut blocks = Vec::with_capacity(m.n_layers + 2);

    blocks.push(BlockBuilder::new("embed").add("tok_emb", &[m.vocab, m.d_model], &std).build());

    for i in 0..m.n_layers {
        blocks.push(
            BlockBuilder::new(&format!("layer{i}"))
                .add("ln1", &[m.d_model], "ones")
                .add("wq", &[m.d_model, m.d_model], &std)
                .add("wk", &[m.d_model, m.d_model], &std)
                .add("wv", &[m.d_model, m.d_model], &std)
                .add("wo", &[m.d_model, m.d_model], &out_std)
                .add("ln2", &[m.d_model], "ones")
                .add("wg", &[m.d_model, m.d_ff], &std)
                .add("wu", &[m.d_model, m.d_ff], &std)
                .add("wd", &[m.d_ff, m.d_model], &out_std)
                .build(),
        );
    }

    blocks.push(
        BlockBuilder::new("head")
            .add("ln_f", &[m.d_model], "ones")
            .add("w_out", &[m.d_model, m.vocab], &std)
            .build(),
    );
    blocks
}

/// One LoRA block per transformer layer: `W' = W + 2·A·B` with
/// `A: (in, r) ~ N(0, 1/√r)`, `B: (r, out) = 0`.
pub fn lora_block_table(m: &ModelSpec, rank: usize) -> Vec<BlockSpec> {
    let a_std = format!("normal:{}", 1.0 / (rank as f64).sqrt());
    let dims = |proj: &str| -> (usize, usize) {
        match proj {
            "wg" | "wu" => (m.d_model, m.d_ff),
            "wd" => (m.d_ff, m.d_model),
            _ => (m.d_model, m.d_model),
        }
    };
    (0..m.n_layers)
        .map(|i| {
            let mut b = BlockBuilder::new(&format!("lora{i}"));
            for proj in LORA_PROJS {
                let (d_in, d_out) = dims(proj);
                b = b
                    .add(&format!("{proj}_a"), &[d_in, rank], &a_std)
                    .add(&format!("{proj}_b"), &[rank, d_out], "zeros");
            }
            b.build()
        })
        .collect()
}

#[allow(clippy::too_many_arguments)]
fn model_spec(
    name: &str,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    d_ff: usize,
    seq_len: usize,
    batch: usize,
    lora_rank: usize,
) -> ModelSpec {
    assert!(d_model % n_heads == 0, "{name}: d_model must divide by heads");
    ModelSpec {
        name: name.to_string(),
        d_model,
        n_layers,
        n_heads,
        d_ff,
        vocab: VOCAB_SIZE,
        seq_len,
        batch,
        lora_rank,
        d_head: d_model / n_heads,
        norm_eps: 1e-5,
        rope_theta: 10000.0,
        init_std: 0.02,
    }
}

fn artifact(file: String, n_inputs: usize) -> ArtifactInfo {
    ArtifactInfo { file, n_inputs, bytes: 0, lower_s: 0.0 }
}

fn preset(model: ModelSpec, pallas: bool) -> Preset {
    let blocks = block_table(&model);
    let lora_blocks = lora_block_table(&model, model.lora_rank);
    let lora_blocks2 = lora_block_table(&model, model.lora_rank * 2);
    let total_params = blocks.iter().map(|b| b.numel).sum();
    let n = blocks.len();
    let nl = model.n_layers;
    let name = &model.name;

    let mut artifacts = HashMap::new();
    let mut add = |entry: &str, n_inputs: usize| {
        artifacts.insert(
            entry.to_string(),
            artifact(format!("{name}_{entry}.hlo.txt"), n_inputs),
        );
    };
    add("train_step", n + 2);
    // selection-gated backward: blocks + tokens + targets + block mask.
    // Output arity is mask-dependent (loss + one grad flat per *selected*
    // block), which the reference backend handles natively; an XLA
    // lowering would pad to fixed arity, so the AOT export keeps this
    // entry reference-backend-first.
    add("train_step_masked", n + 3);
    // shard-local data-parallel steps: blocks + tokens + targets + denom
    // (i32[1] global non-pad target count), masked form appends the block
    // mask. Batch is derived from the token tensor so one executable
    // serves any shard width; outputs are *undivided* loss partials +
    // gradient subtree partials that tree-fold bit-exactly across ranks
    // (see train/sharded.rs).
    add("train_step_shard", n + 3);
    add("train_step_masked_shard", n + 4);
    // fully device-resident exploit step: blocks + m + v + t (per-block
    // f32[1] step counts) + sched f32[4] + global step f32[1] + tokens +
    // targets + mask. Updates the selected blocks' p/m/v/t in place
    // (donated buffers) and returns only the loss scalar — like the
    // masked entry, reference-backend-first (XLA would express the
    // donation as input→output aliasing at fixed arity).
    add("train_step_fused", 4 * n + 5);
    if pallas {
        add("train_step_pallas", n + 2);
    }
    add("train_step_lora", n + nl + 2);
    add("train_step_lora2", n + nl + 2);
    add("lora_merge", 2);
    add("lora_merge2", 2);
    add("eval_loss", n + 2);
    add("decode_step", n + 1);
    // serving entries: prompt prefill (blocks + tokens) and one KV-cached
    // decode step (blocks + k + v + token + position)
    add("prefill", n + 1);
    add("decode_step_kv", n + 4);

    Preset { model, blocks, lora_blocks, lora_blocks2, total_params, artifacts }
}

/// The full built-in catalog (same five presets the AOT path exports).
pub(crate) fn builtin_manifest() -> Manifest {
    let mut presets = HashMap::new();
    // unit/integration-test preset: runs in well under a second
    let tiny = model_spec("test-tiny", 32, 2, 2, 96, 64, 4, 4);
    // Qwen2.5-0.5B stand-in: 25 transformer blocks (paper: 10% => 2 blocks)
    let qwen = model_spec("qwen-sim", 64, 25, 4, 176, 128, 8, 8);
    // LLaMA3.2-1B stand-in: 18 blocks (paper: 10% => a single block)
    let llama = model_spec("llama-sim", 80, 18, 4, 216, 128, 8, 10);
    // Phi4-mini-3.8B stand-in: 32 blocks
    let phi = model_spec("phi-sim", 96, 32, 4, 256, 128, 8, 12);
    // end-to-end example model (examples/e2e_train.rs)
    let e2e = model_spec("e2e", 160, 8, 5, 432, 128, 8, 20);

    for (m, pallas) in [(tiny, true), (qwen, true), (llama, false), (phi, false), (e2e, false)] {
        presets.insert(m.name.clone(), preset(m, pallas));
    }

    let mut shared = HashMap::new();
    shared.insert("adamw_update".to_string(), artifact("adamw_update.hlo.txt".into(), 6));
    // donating form over whole-block device tensors: (p, g, m, v, t, lr,
    // scale), updates p/m/v/t in place, no outputs — the composed
    // device-resident optimizer path (see `train_step_fused` for the
    // fully fused one)
    shared.insert(
        "adamw_update_inplace".to_string(),
        artifact("adamw_update_inplace.hlo.txt".into(), 7),
    );
    shared.insert("grad_norm_sq".to_string(), artifact("grad_norm_sq.hlo.txt".into(), 1));

    Manifest {
        version: 1,
        tokenizer: TokenizerSpec {
            chars: TOKENIZER_CHARS.to_string(),
            vocab_size: VOCAB_SIZE,
            pad: 0,
            bos: 1,
            eos: 2,
            unk: 3,
        },
        chunk_size: CHUNK_SIZE,
        adamw: AdamWHyper { b1: 0.9, b2: 0.999, eps: 1e-8, wd: 0.01 },
        shared,
        presets,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_covers_chars() {
        assert!(4 + TOKENIZER_CHARS.chars().count() <= VOCAB_SIZE);
    }

    #[test]
    fn layer_tensor_order_is_stable() {
        let m = model_spec("t", 8, 1, 2, 16, 4, 1, 2);
        let blocks = block_table(&m);
        let names: Vec<&str> = blocks[1].tensors.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, ["ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd"]);
        assert_eq!(blocks[0].tensors[0].name, "tok_emb");
        assert_eq!(blocks[2].tensors[0].name, "ln_f");
        assert_eq!(blocks[2].tensors[1].name, "w_out");
    }

    #[test]
    fn lora_block_has_all_projections() {
        let m = model_spec("t", 8, 2, 2, 16, 4, 1, 2);
        let lb = lora_block_table(&m, 2);
        assert_eq!(lb.len(), 2);
        assert_eq!(lb[0].tensors.len(), 14);
        assert_eq!(lb[0].tensors[0].name, "wq_a");
        assert_eq!(lb[0].tensors[1].name, "wq_b");
        // A rows carry N(0, 1/sqrt(r)), B rows are zeros
        assert!(lb[0].tensors[0].init.starts_with("normal:"));
        assert_eq!(lb[0].tensors[1].init, "zeros");
    }
}
