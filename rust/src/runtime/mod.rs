//! Compute runtimes: the [`Backend`] abstraction and its implementations.
//!
//! # The `Backend` trait
//!
//! The coordinator never talks to an executor directly — everything goes
//! through [`Backend`], a **device-resident tensor-handle API**: load an
//! entrypoint ([`Backend::load_preset_exe`] / [`Backend::load_shared_exe`];
//! loading asserts the manifest-declared input arity), move tensors
//! across the boundary explicitly ([`Backend::upload_f32`] /
//! [`Backend::upload_i32`] / in-place [`Backend::write_f32`]), run
//! ([`Backend::execute`], which returns output *handles*), and read back
//! only what the host actually needs ([`Backend::read_f32`] /
//! [`Backend::read_scalar_f32`]). Every byte that crosses is counted in
//! [`Backend::transfer_stats`] — a device-resident exploit step is
//! *observed* to download exactly its 4-byte loss scalar, not assumed to.
//! `Trainer`, `Evaluator`, the serving engine and the experiment harness
//! are all generic over `B: Backend`; see [`crate::runtime::backend`] for
//! the handle model, donation rules, read-back costs and the
//! `HostOutputs` migration note.
//!
//! # Implementations
//!
//! * [`ReferenceBackend`] — **default**: pure-Rust CPU executor. The
//!   transformer fwd/bwd lives in [`crate::model::forward`]; model
//!   topology comes from the built-in preset catalog
//!   ([`Manifest::builtin`], mirroring `python/compile/presets.py`), so no
//!   artifacts, Python or HLO files are needed. This is what CI builds,
//!   tests and trains end-to-end.
//! * `Engine` — the PJRT path, behind the **`pjrt` cargo feature**: it
//!   loads AOT-lowered HLO-text artifacts (`make artifacts`) through the
//!   `xla` crate and keeps parameters device-resident between steps.
//!   Default builds never compile or link `xla`; the feature is
//!   type-checked in CI against the in-tree `rust/vendor/xla` API stub and
//!   runs for real when the path dependency points at actual bindings.
//!
//! # Entry catalog
//!
//! Both backends expose the same entry names with identical
//! argument/output layouts, so checkpoints, configs and metrics are
//! portable across them and the parity suite can hold one against the
//! other. With `n` = number of blocks, `nl` = LoRA blocks:
//!
//! | entry | inputs | outputs | in-place |
//! |---|---|---|---|
//! | `train_step` (+`_pallas`) | blocks·n, tokens, targets | loss, grad·n | — |
//! | `train_step_masked` | blocks·n, tokens, targets, mask | loss, grad per *selected* block | — |
//! | `train_step_shard` | blocks·n, tokens, targets, denom | loss *partial*, grad partial·n | — |
//! | `train_step_masked_shard` | blocks·n, tokens, targets, denom, mask | loss *partial*, grad partial per *selected* block | — |
//! | `train_step_fused` | blocks·n, m·n, v·n, t·n, sched, step, tokens, targets, mask | loss | p/m/v/t of selected blocks, step |
//! | `train_step_lora[2]` | blocks·n, adapters·nl, tokens, targets | loss, adapter grad·nl | — |
//! | `eval_loss` | blocks·n, tokens, targets | loss | — |
//! | `decode_step` | blocks·n, tokens | logits | — |
//! | `prefill` | blocks·n, tokens | logits, k, v | — |
//! | `decode_step_kv` | blocks·n, k, v, token, pos | logits, k, v | — |
//! | `lora_merge[2]` | base block, adapter block | merged block | — |
//! | `adamw_update` (shared) | p, g, m, v, lr, step | p, m, v | — |
//! | `adamw_update_inplace` (shared) | p, g, m, v, t, lr, scale | *(none)* | p, m, v, t |
//! | `grad_norm_sq` (shared) | g | sum(g²) | — |
//!
//! The in-place entries carry the donation semantics of the redesigned
//! API: the tensors their argument handles name are overwritten, nothing
//! is reallocated, and nothing crosses the boundary. `train_step_fused`
//! evaluates the cosine learning-rate schedule *on device* from its
//! `sched`/`step` tensors (`optimizer::lr_cosine` — the same f32 formula
//! `RunConfig::lr_at` uses), so a steady-state exploit step's entire
//! boundary traffic is the batch + mask upload and the loss-scalar
//! read-back. The `train_step_masked` and `train_step_fused` entries are
//! reference-backend-first (mask-dependent output arity / buffer
//! donation; an XLA lowering would pad arity and declare input→output
//! aliasing); backends whose manifests lack them degrade gracefully — the
//! trainer falls back to the full backward and the host-loop optimizer.
//!
//! The `*_shard` entries are the data-parallel forms consumed by
//! `train::sharded::ShardedTrainer`: the local batch is derived from the
//! token tensor (one executable serves any shard width dividing the
//! preset batch), `denom` is the globally summed non-pad target count
//! (i32[1]), and the outputs are **undivided** loss partials plus
//! gradient *subtree partials* that a coordinator tree-fold combines
//! bit-exactly into the single-worker `train_step` result — see
//! `model::forward::train_step_shard_in` for the decomposition contract
//! and [`backend::CommStats`] for the wire-byte accounting.
//!
//! The serving subsystem built on top of these entries — KV-cache slot
//! pool, continuous-batching scheduler, engine — lives in [`crate::serve`];
//! backends additionally implementing `serve::KvBackend` run the serving
//! pair as in-place kernels over slot-pooled caches, while plain
//! [`Backend::execute`] runs the stateless cache-in/cache-out form.

pub mod backend;
#[cfg(feature = "pjrt")]
mod engine;
mod manifest;
pub mod presets;
mod reference;

pub use backend::{
    Backend, CommStats, DType, DeviceOutputs, HostOutputs, TensorMeta, TransferStats,
};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, EngineTensor, Exe};
pub use manifest::{
    AdamWHyper, ArtifactInfo, BlockSpec, Manifest, ModelSpec, Preset, TensorSpec, TokenizerSpec,
};
pub use reference::{RefExe, RefTensor, ReferenceBackend, TensorData};
