//! Compute runtimes: the [`Backend`] abstraction and its implementations.
//!
//! # The `Backend` trait
//!
//! The coordinator never talks to an executor directly — everything goes
//! through [`Backend`]: load an entrypoint ([`Backend::load_preset_exe`] /
//! [`Backend::load_shared_exe`]), move tensors ([`Backend::upload_f32`] /
//! [`Backend::upload_i32`]), run ([`Backend::execute`]) and read the
//! outputs back as flat `f32` vectors ([`HostOutputs`]). `Trainer`,
//! `Evaluator`, the selective-AdamW kernel driver and the experiment
//! harness are all generic over `B: Backend`.
//!
//! # Implementations
//!
//! * [`ReferenceBackend`] — **default**: pure-Rust CPU executor. The
//!   transformer fwd/bwd lives in [`crate::model::forward`]; model
//!   topology comes from the built-in preset catalog
//!   ([`Manifest::builtin`], mirroring `python/compile/presets.py`), so no
//!   artifacts, Python or HLO files are needed. This is what CI builds,
//!   tests and trains end-to-end.
//! * [`Engine`] — the PJRT path, behind the **`pjrt` cargo feature**: it
//!   loads AOT-lowered HLO-text artifacts (`make artifacts`) through the
//!   `xla` crate and keeps parameters device-resident between steps.
//!   Default builds never compile or link `xla`; the feature is
//!   type-checked in CI against the in-tree `rust/vendor/xla` API stub and
//!   runs for real when the path dependency points at actual bindings.
//!
//! Both backends expose the same entry names (`train_step`, the
//! selection-gated `train_step_masked` (blocks + tokens + targets + block
//! mask, returning loss + the *selected* blocks' gradient flats only),
//! `train_step_lora[2]`, `eval_loss`, `decode_step`, the serving pair
//! `prefill` / `decode_step_kv`, `lora_merge[2]`, and the shared
//! `adamw_update` / `grad_norm_sq` kernels) with identical
//! argument/output layouts, so checkpoints, configs and metrics are
//! portable across them and the parity suite can hold one against the
//! other. The serving subsystem built on top of these entries —
//! KV-cache slot pool, continuous-batching scheduler, engine — lives in
//! [`crate::serve`].

mod backend;
#[cfg(feature = "pjrt")]
mod engine;
mod manifest;
pub mod presets;
mod reference;

pub use backend::{Backend, HostOutputs};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, Exe};
pub use manifest::{
    AdamWHyper, ArtifactInfo, BlockSpec, Manifest, ModelSpec, Preset, TensorSpec, TokenizerSpec,
};
pub use reference::{RefBuffer, RefExe, ReferenceBackend};
