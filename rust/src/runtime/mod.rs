//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only module that touches the `xla` crate. Everything above
//! it works in terms of flat `Vec<f32>` block vectors and `Vec<i32>` token
//! matrices. HLO *text* is the interchange format (see
//! `python/compile/aot.py` for why not serialized protos).

mod engine;
mod manifest;

pub use engine::{Engine, Exe, HostOutputs};
pub use manifest::{
    AdamWHyper, ArtifactInfo, BlockSpec, Manifest, ModelSpec, Preset, TensorSpec, TokenizerSpec,
};
