//! The pluggable compute-backend abstraction.
//!
//! Everything above this layer (trainer, optimizer, evaluator, experiment
//! harness) is generic over [`Backend`]: an executor that can load an
//! entrypoint (a "compiled executable"), hold uploaded tensors as opaque
//! device buffers, and execute an entrypoint over buffers, returning the
//! outputs as flat host `f32` vectors.
//!
//! Two implementations exist:
//!
//! * [`crate::runtime::ReferenceBackend`] — the default: a pure-Rust CPU
//!   executor whose "executables" dispatch to the native transformer
//!   fwd/bwd in [`crate::model::forward`]. No artifacts, no Python, no
//!   external crates; this is what CI builds and tests.
//! * [`crate::runtime::Engine`] (cargo feature `pjrt`) — the PJRT path
//!   that loads AOT-lowered HLO-text artifacts through the `xla` crate.
//!
//! Entry names are shared between backends (`train_step`, `eval_loss`,
//! `decode_step`, the serving pair `prefill` / `decode_step_kv`,
//! `train_step_lora[2]`, `lora_merge[2]`, and the shared `adamw_update` /
//! `grad_norm_sq` kernels), so a `Trainer<B>` behaves identically up to
//! floating-point on either executor — the property the backend-parity
//! test suite pins down. Backends that additionally implement
//! [`crate::serve::KvBackend`] expose the serving pair as in-place
//! kernels over slot-pooled caches; through plain [`Backend::execute`]
//! the pair runs in its stateless cache-in/cache-out form.

use std::rc::Rc;

use anyhow::{anyhow, Result};

use super::manifest::Manifest;

/// Host-side copy of an executable's output tuple, backend-neutral: one
/// flat `f32` vector per output (scalars are length-1 vectors).
pub struct HostOutputs {
    pub outputs: Vec<Vec<f32>>,
    /// Wallclock of the execute call (device compute + sync).
    pub execute_s: f64,
    /// Wallclock of the device→host copy of the outputs (0 for host
    /// backends, where outputs are produced in place).
    pub download_s: f64,
}

impl HostOutputs {
    pub fn new(outputs: Vec<Vec<f32>>, execute_s: f64, download_s: f64) -> Self {
        Self { outputs, execute_s, download_s }
    }

    fn check(&self, idx: usize) -> Result<()> {
        if idx >= self.outputs.len() {
            return Err(anyhow!(
                "output index {idx} out of range (executable produced {})",
                self.outputs.len()
            ));
        }
        Ok(())
    }

    pub fn scalar_f32(&self, idx: usize) -> Result<f32> {
        self.check(idx)?;
        self.outputs[idx]
            .first()
            .copied()
            .ok_or_else(|| anyhow!("output {idx} is empty, expected a scalar"))
    }

    /// Borrow output `idx` as a flat slice.
    pub fn vec_f32(&self, idx: usize) -> Result<&[f32]> {
        self.check(idx)?;
        Ok(&self.outputs[idx])
    }

    /// Move output `idx` out (leaves an empty vector behind) — avoids a
    /// copy when the caller owns the downstream buffer anyway.
    pub fn take_vec(&mut self, idx: usize) -> Result<Vec<f32>> {
        self.check(idx)?;
        Ok(std::mem::take(&mut self.outputs[idx]))
    }
}

/// A compute executor the training stack can run on.
///
/// `Buffer` is an opaque device-resident tensor (host vectors for the
/// reference backend, `PjRtBuffer` for PJRT); `Exe` is a loaded
/// entrypoint. Executables are cached by the backend, so `load_*_exe` is
/// cheap after the first call for a given entry.
pub trait Backend {
    type Buffer;
    type Exe;

    /// Human-readable platform tag (e.g. `"reference-cpu"`, `"cpu"`).
    fn platform(&self) -> String;

    /// Model topology / tokenizer / hyperparameter source of truth.
    fn manifest(&self) -> &Manifest;

    /// Load the executable for a preset entrypoint (e.g. `"train_step"`).
    fn load_preset_exe(&self, preset: &str, entry: &str) -> Result<Rc<Self::Exe>>;

    /// Load a shared (preset-independent) executable, e.g. `"adamw_update"`.
    fn load_shared_exe(&self, entry: &str) -> Result<Rc<Self::Exe>>;

    /// Upload a flat f32 vector.
    fn upload_f32(&self, data: &[f32]) -> Result<Self::Buffer>;

    /// Upload an i32 matrix (row-major) of shape `dims`.
    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Self::Buffer>;

    /// Execute an entrypoint and return all outputs on the host.
    fn execute(&self, exe: &Self::Exe, args: &[&Self::Buffer]) -> Result<HostOutputs>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_outputs_accessors() {
        let mut out = HostOutputs::new(vec![vec![2.5], vec![1.0, 2.0]], 0.0, 0.0);
        assert_eq!(out.scalar_f32(0).unwrap(), 2.5);
        assert_eq!(out.vec_f32(1).unwrap(), &[1.0, 2.0]);
        let taken = out.take_vec(1).unwrap();
        assert_eq!(taken, vec![1.0, 2.0]);
        assert!(out.vec_f32(1).unwrap().is_empty());
        assert!(out.scalar_f32(9).is_err());
    }
}
