//! The pluggable compute-backend abstraction: a device-resident,
//! typed-tensor-handle API.
//!
//! Everything above this layer (trainer, optimizer, evaluator, serving
//! engine, experiment harness) is generic over [`Backend`]: an executor
//! that can load an entrypoint (a "compiled executable"), hold tensors as
//! typed device-resident handles, execute an entrypoint over handles —
//! returning *output handles*, not host data — and move bytes across the
//! host↔device boundary only through explicit, byte-counted calls.
//!
//! # The handle model
//!
//! * A `Backend::Buffer` is a **typed device tensor handle** with an
//!   explicit dtype and shape ([`Backend::meta`]). Handles are cheap to
//!   hold; the tensor they name lives on the executor's side of the
//!   boundary (host vectors for the reference backend, `PjRtBuffer`s for
//!   PJRT). A handle's tensor stays alive as long as any handle to it
//!   does; dropping the last handle releases the buffer back to the
//!   backend's pool.
//! * **Uploads** ([`Backend::upload_f32`] / [`Backend::upload_i32`])
//!   allocate a device tensor and copy host data in; **in-place writes**
//!   ([`Backend::write_f32`] / [`Backend::write_i32`]) overwrite an
//!   existing tensor without reallocation. Both count toward
//!   [`TransferStats::h2d_bytes`].
//! * **Execution** ([`Backend::execute`]) consumes argument handles and
//!   returns [`DeviceOutputs`]: one *handle per output*. Nothing crosses
//!   back to the host implicitly.
//! * **Read-back** ([`Backend::read_f32`] / [`Backend::read_scalar_f32`])
//!   is the only way host code sees device data, and every call counts
//!   toward [`TransferStats::d2h_bytes`]. A training step that only reads
//!   its loss scalar is *observably* a 4-byte download — the paper's
//!   device-residency claim, measured instead of assumed.
//!
//! # Donation / in-place update semantics
//!
//! Some entrypoints update their inputs **in place** instead of returning
//! fresh outputs (the XLA analogue is input→output buffer aliasing /
//! donation). The contract is per-entry and documented in the entry
//! catalog in [`crate::runtime`]: e.g. `adamw_update_inplace` overwrites
//! its `p`/`m`/`v`/`t` arguments and returns nothing, and
//! `train_step_fused` overwrites the selected blocks' parameters and
//! optimizer moments while returning only the loss. Callers must not
//! pass the same handle for two arguments of an in-place entry (the
//! executor rejects the aliasing it can detect). Handles passed to
//! non-donating entries are never mutated.
//!
//! # Transfer accounting
//!
//! [`Backend::transfer_stats`] exposes monotone counters for every byte
//! that crossed the boundary plus every device-buffer allocation the
//! backend performed. Snapshot before/after a region and diff with
//! [`TransferStats::delta_since`]; the trainer does this per step and the
//! bench suite enforces the exploit-step invariants (`d2h_bytes` == one
//! f32 loss scalar, `h2d_bytes` == batch + mask upload, zero steady-state
//! buffer allocations) on every CI run.
//!
//! # Migrating from the flat `HostOutputs` API
//!
//! Before this redesign, `execute` copied every output to the host
//! eagerly and returned [`HostOutputs`]. That shape still exists as the
//! provided convenience [`Backend::execute_to_host`] — identical
//! semantics, one call — so host-consuming call sites migrate by renaming
//! `execute` → `execute_to_host`. The differences to be aware of:
//!
//! * `upload_f32` now takes explicit dims (`&[data.len()]` for a flat
//!   vector).
//! * the download is now visible in `transfer_stats()` — code that
//!   previously "got outputs for free" now observably pays for them;
//! * hot loops should keep outputs as handles and read back only what
//!   they need.
//!
//! Two implementations exist: [`crate::runtime::ReferenceBackend`]
//! (default; pure-Rust CPU executor, what CI builds and tests) and the
//! PJRT `Engine` behind the `pjrt` cargo feature. Entry names and
//! layouts are shared between them — see the catalog in
//! [`crate::runtime`].

use std::rc::Rc;

use anyhow::{anyhow, Result};

use super::manifest::Manifest;
use crate::telemetry::Stopwatch;

/// Element type of a device tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
        }
    }
}

/// Shape + dtype of a device tensor handle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorMeta {
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl TensorMeta {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.numel() * self.dtype.size()
    }
}

/// Monotone counters for host↔device traffic and device-buffer churn.
///
/// `h2d_bytes`/`d2h_bytes` count every byte moved by uploads, in-place
/// writes and read-backs; `buffer_allocs`/`buffer_alloc_bytes` count
/// device tensors the backend had to *allocate* (pool hits and in-place
/// writes are free). Snapshot + [`TransferStats::delta_since`] gives the
/// traffic of a region.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransferStats {
    /// Host→device bytes (uploads + in-place writes).
    pub h2d_bytes: u64,
    /// Device→host bytes (explicit read-backs).
    pub d2h_bytes: u64,
    /// Number of host→device transfer calls.
    pub h2d_transfers: u64,
    /// Number of device→host transfer calls.
    pub d2h_transfers: u64,
    /// Fresh device-buffer allocations (buffer-pool misses).
    pub buffer_allocs: u64,
    /// Bytes of those fresh allocations.
    pub buffer_alloc_bytes: u64,
}

impl TransferStats {
    /// Field names in [`TransferStats::gauge_values`] order, for
    /// registering one telemetry gauge per counter.
    pub const GAUGE_NAMES: [&'static str; 6] = [
        "h2d_bytes",
        "d2h_bytes",
        "h2d_transfers",
        "d2h_transfers",
        "buffer_allocs",
        "buffer_alloc_bytes",
    ];

    /// The counters as `f64` gauge values, in [`TransferStats::GAUGE_NAMES`] order.
    pub fn gauge_values(&self) -> [f64; 6] {
        [
            self.h2d_bytes as f64,
            self.d2h_bytes as f64,
            self.h2d_transfers as f64,
            self.d2h_transfers as f64,
            self.buffer_allocs as f64,
            self.buffer_alloc_bytes as f64,
        ]
    }

    /// Counter-wise difference `self - earlier` (both from the same
    /// backend, `earlier` snapshotted first).
    pub fn delta_since(&self, earlier: &TransferStats) -> TransferStats {
        TransferStats {
            h2d_bytes: self.h2d_bytes - earlier.h2d_bytes,
            d2h_bytes: self.d2h_bytes - earlier.d2h_bytes,
            h2d_transfers: self.h2d_transfers - earlier.h2d_transfers,
            d2h_transfers: self.d2h_transfers - earlier.d2h_transfers,
            buffer_allocs: self.buffer_allocs - earlier.buffer_allocs,
            buffer_alloc_bytes: self.buffer_alloc_bytes - earlier.buffer_alloc_bytes,
        }
    }
}

/// Monotone counters for inter-worker (shard↔coordinator) communication
/// in sharded data-parallel training — the wire-traffic sibling of
/// [`TransferStats`], which counts the host↔device boundary.
///
/// The byte model is a parameter-server star: the coordinator gathers
/// per-shard partials, reduces them in a fixed tree order, and
/// broadcasts the result back, so every logical all-reduce costs one
/// gather leg plus one broadcast leg, each multiplied by the worker
/// count. The selection gate shows up directly in these counters:
/// exploit steps gather/broadcast only the *selected* blocks' gradient
/// flats (`grad_gather_bytes`/`grad_bcast_bytes` scale with selected
/// params, not total params), while explore steps additionally
/// broadcast the reduced per-block squared norms the strategies consume
/// (`norm_bcast_bytes`, `n_blocks` f32s per worker). Everything else —
/// step commands, loss partials, valid-target counts, the global loss
/// denominator, the clip scale — is `ctrl_bytes`. Exported as
/// `train_comm_*` registry gauges by `train::sharded::ShardedTrainer`
/// and enforced per step by the bench invariants.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CommStats {
    /// Gradient-partial bytes gathered from workers (gather leg).
    pub grad_gather_bytes: u64,
    /// Reduced-gradient bytes broadcast back to workers (bcast leg).
    pub grad_bcast_bytes: u64,
    /// Reduced per-block squared-norm bytes broadcast on explore steps.
    pub norm_bcast_bytes: u64,
    /// Control-plane bytes (commands, loss partials, counts, scales).
    pub ctrl_bytes: u64,
    /// Number of logical all-reduce operations performed.
    pub allreduce_ops: u64,
}

impl CommStats {
    /// Field names in [`CommStats::gauge_values`] order, for registering
    /// one telemetry gauge per counter.
    pub const GAUGE_NAMES: [&'static str; 5] = [
        "grad_gather_bytes",
        "grad_bcast_bytes",
        "norm_bcast_bytes",
        "ctrl_bytes",
        "allreduce_ops",
    ];

    /// The counters as `f64` gauge values, in [`CommStats::GAUGE_NAMES`] order.
    pub fn gauge_values(&self) -> [f64; 5] {
        [
            self.grad_gather_bytes as f64,
            self.grad_bcast_bytes as f64,
            self.norm_bcast_bytes as f64,
            self.ctrl_bytes as f64,
            self.allreduce_ops as f64,
        ]
    }

    /// Counter-wise difference `self - earlier` (both from the same
    /// trainer, `earlier` snapshotted first).
    pub fn delta_since(&self, earlier: &CommStats) -> CommStats {
        CommStats {
            grad_gather_bytes: self.grad_gather_bytes - earlier.grad_gather_bytes,
            grad_bcast_bytes: self.grad_bcast_bytes - earlier.grad_bcast_bytes,
            norm_bcast_bytes: self.norm_bcast_bytes - earlier.norm_bcast_bytes,
            ctrl_bytes: self.ctrl_bytes - earlier.ctrl_bytes,
            allreduce_ops: self.allreduce_ops - earlier.allreduce_ops,
        }
    }
}

/// Output handles of one [`Backend::execute`] call: one device tensor
/// handle per output (entries with pure in-place semantics return an
/// empty vector). Nothing here has touched the host yet — read back what
/// you need with [`Backend::read_f32`] / [`Backend::read_scalar_f32`].
pub struct DeviceOutputs<T> {
    pub outputs: Vec<T>,
    /// Wallclock of the execute call (device compute + sync).
    pub execute_s: f64,
}

/// Host-side copy of an executable's output tuple: one flat `f32` vector
/// per output (scalars are length-1 vectors). Produced by the
/// [`Backend::execute_to_host`] convenience — the migration shim for the
/// pre-handle API, and still the right shape for cold paths that consume
/// every output on the host anyway.
pub struct HostOutputs {
    pub outputs: Vec<Vec<f32>>,
    /// Wallclock of the execute call (device compute + sync).
    pub execute_s: f64,
    /// Wallclock of the device→host copy of the outputs.
    pub download_s: f64,
}

impl HostOutputs {
    pub fn new(outputs: Vec<Vec<f32>>, execute_s: f64, download_s: f64) -> Self {
        Self { outputs, execute_s, download_s }
    }

    fn check(&self, idx: usize) -> Result<()> {
        if idx >= self.outputs.len() {
            return Err(anyhow!(
                "output index {idx} out of range (executable produced {})",
                self.outputs.len()
            ));
        }
        Ok(())
    }

    pub fn scalar_f32(&self, idx: usize) -> Result<f32> {
        self.check(idx)?;
        self.outputs[idx]
            .first()
            .copied()
            .ok_or_else(|| anyhow!("output {idx} is empty, expected a scalar"))
    }

    /// Borrow output `idx` as a flat slice.
    pub fn vec_f32(&self, idx: usize) -> Result<&[f32]> {
        self.check(idx)?;
        Ok(&self.outputs[idx])
    }

    /// Move output `idx` out (leaves an empty vector behind) — avoids a
    /// copy when the caller owns the downstream buffer anyway.
    pub fn take_vec(&mut self, idx: usize) -> Result<Vec<f32>> {
        self.check(idx)?;
        Ok(std::mem::take(&mut self.outputs[idx]))
    }
}

/// A compute executor the training stack can run on.
///
/// `Buffer` is a typed device tensor handle (see the module docs for the
/// handle model, donation rules and read-back costs); `Exe` is a loaded
/// entrypoint. Executables are cached by the backend, so `load_*_exe` is
/// cheap after the first call for a given entry, and loading asserts the
/// manifest-declared input arity against the executable.
pub trait Backend {
    type Buffer;
    type Exe;

    /// Human-readable platform tag (e.g. `"reference-cpu"`, `"cpu"`).
    fn platform(&self) -> String;

    /// Model topology / tokenizer / hyperparameter source of truth.
    fn manifest(&self) -> &Manifest;

    /// Load the executable for a preset entrypoint (e.g. `"train_step"`).
    fn load_preset_exe(&self, preset: &str, entry: &str) -> Result<Rc<Self::Exe>>;

    /// Load a shared (preset-independent) executable, e.g. `"adamw_update"`.
    fn load_shared_exe(&self, entry: &str) -> Result<Rc<Self::Exe>>;

    /// Upload an f32 tensor of shape `dims` (use `&[data.len()]` for a
    /// flat vector). Counts `data.len() * 4` bytes of H2D traffic.
    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Self::Buffer>;

    /// Upload an i32 tensor (row-major) of shape `dims`.
    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Self::Buffer>;

    /// Overwrite an existing f32 device tensor in place with host data of
    /// the same element count. H2D traffic, but **no allocation**: the
    /// tensor every existing handle names is updated.
    fn write_f32(&self, dst: &Self::Buffer, data: &[f32]) -> Result<()>;

    /// [`Backend::write_f32`] for i32 tensors.
    fn write_i32(&self, dst: &Self::Buffer, data: &[i32]) -> Result<()>;

    /// Dtype + shape of a handle.
    fn meta(&self, buf: &Self::Buffer) -> TensorMeta;

    /// Execute an entrypoint over argument handles and return the output
    /// *handles*. No output data crosses to the host here; in-place
    /// entries mutate their donated arguments instead (see the entry
    /// catalog in [`crate::runtime`]).
    fn execute(
        &self,
        exe: &Self::Exe,
        args: &[&Self::Buffer],
    ) -> Result<DeviceOutputs<Self::Buffer>>;

    /// Copy a device tensor back to the host (f32 tensors only). The
    /// explicit — and only — D2H path; counts `numel * 4` bytes.
    fn read_f32(&self, buf: &Self::Buffer) -> Result<Vec<f32>>;

    /// Read back a single f32 scalar (first element of a length-≥1
    /// tensor). Counts 4 bytes of D2H traffic.
    fn read_scalar_f32(&self, buf: &Self::Buffer) -> Result<f32>;

    /// Whether this executor honors the in-place (donation) entry
    /// contract — entries like `adamw_update_inplace` actually mutating
    /// the tensors their argument handles name. The trainer only selects
    /// its device-resident mode on backends that return `true`; a
    /// manifest exporting the entry names is not enough, because a purely
    /// functional executor would silently discard every update.
    fn supports_donation(&self) -> bool;

    /// Monotone transfer/allocation counters (see [`TransferStats`]).
    fn transfer_stats(&self) -> TransferStats;

    /// Execute and copy **every** output back to the host — the
    /// pre-handle `execute` semantics, kept for cold paths and migration.
    /// The downloads are real: they show up in [`Backend::transfer_stats`].
    fn execute_to_host(&self, exe: &Self::Exe, args: &[&Self::Buffer]) -> Result<HostOutputs> {
        let out = self.execute(exe, args)?;
        let t0 = Stopwatch::start();
        let host: Vec<Vec<f32>> =
            out.outputs.iter().map(|b| self.read_f32(b)).collect::<Result<_>>()?;
        Ok(HostOutputs::new(host, out.execute_s, t0.elapsed_s()))
    }

    /// Shadow-state audit of backend-internal bookkeeping (e.g. the
    /// reference executor's workspace-arena accounting); empty = sound.
    /// The trainer's `audit`-gated per-step hook calls this; the default
    /// is a no-op for backends with nothing to re-derive.
    fn audit_report(&self) -> Vec<String> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_outputs_accessors() {
        let mut out = HostOutputs::new(vec![vec![2.5], vec![1.0, 2.0]], 0.0, 0.0);
        assert_eq!(out.scalar_f32(0).unwrap(), 2.5);
        assert_eq!(out.vec_f32(1).unwrap(), &[1.0, 2.0]);
        let taken = out.take_vec(1).unwrap();
        assert_eq!(taken, vec![1.0, 2.0]);
        assert!(out.vec_f32(1).unwrap().is_empty());
        assert!(out.scalar_f32(9).is_err());
    }

    #[test]
    fn transfer_stats_delta() {
        let a = TransferStats {
            h2d_bytes: 100,
            d2h_bytes: 4,
            h2d_transfers: 2,
            d2h_transfers: 1,
            buffer_allocs: 3,
            buffer_alloc_bytes: 100,
        };
        let mut b = a;
        b.h2d_bytes += 40;
        b.d2h_bytes += 4;
        b.h2d_transfers += 1;
        b.d2h_transfers += 1;
        let d = b.delta_since(&a);
        assert_eq!(d.h2d_bytes, 40);
        assert_eq!(d.d2h_bytes, 4);
        assert_eq!(d.buffer_allocs, 0);
    }

    #[test]
    fn comm_stats_delta_and_gauges() {
        let a = CommStats {
            grad_gather_bytes: 800,
            grad_bcast_bytes: 800,
            norm_bcast_bytes: 32,
            ctrl_bytes: 20,
            allreduce_ops: 2,
        };
        let mut b = a;
        b.grad_gather_bytes += 400;
        b.grad_bcast_bytes += 400;
        b.ctrl_bytes += 8;
        b.allreduce_ops += 1;
        let d = b.delta_since(&a);
        assert_eq!(d.grad_gather_bytes, 400);
        assert_eq!(d.grad_bcast_bytes, 400);
        assert_eq!(d.norm_bcast_bytes, 0);
        assert_eq!(d.ctrl_bytes, 8);
        assert_eq!(d.allreduce_ops, 1);
        let g = a.gauge_values();
        assert_eq!(g.len(), CommStats::GAUGE_NAMES.len());
        assert_eq!(g[0], 800.0);
        assert_eq!(g[4], 2.0);
    }

    #[test]
    fn tensor_meta_accounting() {
        let m = TensorMeta { dtype: DType::F32, dims: vec![4, 8] };
        assert_eq!(m.numel(), 32);
        assert_eq!(m.bytes(), 128);
        assert_eq!(DType::I32.size(), 4);
    }
}
