//! Pure-Rust CPU reference backend.
//!
//! Implements [`Backend`] with no artifacts, no Python and no external
//! crates: "executables" are dispatch tags into the native transformer
//! fwd/bwd (`model::forward`) and the fused AdamW / grad-norm kernels, and
//! "device buffers" are plain host vectors. Entry names and argument
//! layouts are byte-for-byte the PJRT engine's, so the trainer, evaluator
//! and benches run unchanged on either backend.
//!
//! This is the trusted dense reference the selection methods are
//! validated against (GRASS / BlockLLM-style parity methodology): CI
//! trains real models through this backend on every push.
//!
//! The backend owns a [`Workspace`] arena shared by every entrypoint it
//! executes: the first step warms the slab pool, after which the compute
//! path (GEMMs, activations, attention scratch, per-projection gradient
//! staging) performs zero heap allocations per step. The arena's
//! high-water mark — the real per-step buffer footprint — is exposed via
//! [`ReferenceBackend::workspace_stats`] and surfaced through the
//! `memory` accounting and the `train_step` bench JSON.
//!
//! The serving entries (`prefill`, `decode_step_kv`) are exposed here in
//! their stateless functional form (caches as explicit inputs/outputs,
//! the shape an XLA lowering has). The serving engine itself
//! (`crate::serve`) bypasses `execute` and runs the same kernels in-place
//! against slot-pooled caches through the backend's arena — that is the
//! zero-copy, zero-steady-state-allocation path.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::model::forward;
use crate::optimizer::{fused_adamw, AdamWParams};
use crate::selection::grad_norm::block_norm_sq;
use crate::util::workspace::{Workspace, WorkspaceStats};

use super::backend::{Backend, HostOutputs};
use super::manifest::{Manifest, Preset};

/// Host-side "device buffer" for the reference backend.
pub enum RefBuffer {
    F32(Vec<f32>),
    I32(Vec<i32>, Vec<usize>),
}

impl RefBuffer {
    fn as_f32(&self) -> Result<&[f32]> {
        match self {
            RefBuffer::F32(v) => Ok(v),
            RefBuffer::I32(..) => Err(anyhow!("expected an f32 buffer, got i32")),
        }
    }

    fn as_i32(&self) -> Result<&[i32]> {
        match self {
            RefBuffer::I32(v, _) => Ok(v),
            RefBuffer::F32(_) => Err(anyhow!("expected an i32 buffer, got f32")),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Entry {
    TrainStep,
    TrainStepMasked,
    TrainStepLora { double: bool },
    EvalLoss,
    DecodeStep,
    Prefill,
    DecodeStepKv,
    LoraMerge { double: bool },
    AdamWUpdate,
    GradNormSq,
}

/// A "loaded executable": an entry tag bound to a preset (or shared).
pub struct RefExe {
    pub name: String,
    entry: Entry,
    preset: Option<String>,
}

/// The pure-Rust CPU executor (the crate's default backend).
pub struct ReferenceBackend {
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<RefExe>>>,
    /// Step-scoped buffer arena shared by all entrypoints (warm after the
    /// first execute; steady-state steps allocate nothing).
    ws: RefCell<Workspace>,
}

impl Default for ReferenceBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ReferenceBackend {
    /// Backend over the built-in preset catalog (no artifacts needed).
    pub fn new() -> Self {
        Self::with_manifest(Manifest::builtin())
    }

    /// Backend over an explicit manifest (e.g. one loaded from an
    /// artifacts directory, for strict topology parity with a PJRT run).
    pub fn with_manifest(manifest: Manifest) -> Self {
        Self {
            manifest,
            cache: RefCell::new(HashMap::new()),
            ws: RefCell::new(Workspace::new()),
        }
    }

    /// Snapshot of the compute arena's accounting: high-water bytes (the
    /// measured per-step activation/scratch footprint) and the slab-grow
    /// counter (unchanged between two snapshots ⇒ the interval ran
    /// allocation-free).
    pub fn workspace_stats(&self) -> WorkspaceStats {
        self.ws.borrow().stats()
    }

    /// Restart the arena's peak tracking (see
    /// [`Workspace::reset_high_water`]) so the next
    /// [`ReferenceBackend::workspace_stats`] reports the footprint of
    /// just the steps executed since — how the bench measures the masked
    /// (exploit) step's reduced activation footprint separately from the
    /// full step's.
    pub fn reset_workspace_high_water(&self) {
        self.ws.borrow_mut().reset_high_water();
    }

    /// Run `f` against the backend's shared workspace arena — the hook
    /// the serving fast path (`serve::KvBackend`) uses to execute the
    /// in-place prefill/decode kernels without going through the
    /// stateless `execute` interface, while still sharing the warm slab
    /// pool with every other entrypoint.
    pub(crate) fn with_workspace<R>(&self, f: impl FnOnce(&mut Workspace) -> R) -> R {
        f(&mut self.ws.borrow_mut())
    }

    fn parse_entry(entry: &str) -> Result<Entry> {
        Ok(match entry {
            // the Pallas-attention artifact computes the same function;
            // the reference backend has exactly one attention path
            "train_step" | "train_step_pallas" => Entry::TrainStep,
            "train_step_masked" => Entry::TrainStepMasked,
            "train_step_lora" => Entry::TrainStepLora { double: false },
            "train_step_lora2" => Entry::TrainStepLora { double: true },
            "eval_loss" => Entry::EvalLoss,
            "decode_step" => Entry::DecodeStep,
            "prefill" => Entry::Prefill,
            "decode_step_kv" => Entry::DecodeStepKv,
            "lora_merge" => Entry::LoraMerge { double: false },
            "lora_merge2" => Entry::LoraMerge { double: true },
            "adamw_update" => Entry::AdamWUpdate,
            "grad_norm_sq" => Entry::GradNormSq,
            other => return Err(anyhow!("reference backend has no entrypoint {other:?}")),
        })
    }

    fn preset(&self, exe: &RefExe) -> Result<&Preset> {
        let name = exe
            .preset
            .as_deref()
            .ok_or_else(|| anyhow!("{}: entry needs a preset", exe.name))?;
        self.manifest.preset(name)
    }

    fn run(&self, exe: &RefExe, args: &[&RefBuffer]) -> Result<Vec<Vec<f32>>> {
        let want = |n: usize| -> Result<()> {
            if args.len() != n {
                return Err(anyhow!("{}: expected {n} inputs, got {}", exe.name, args.len()));
            }
            Ok(())
        };
        let pad = self.manifest.tokenizer.pad;
        match exe.entry {
            Entry::TrainStep => {
                let p = self.preset(exe)?;
                let n = p.blocks.len();
                want(n + 2)?;
                let flats: Vec<&[f32]> =
                    args[..n].iter().map(|b| b.as_f32()).collect::<Result<_>>()?;
                let tokens = args[n].as_i32()?;
                let targets = args[n + 1].as_i32()?;
                let mut ws = self.ws.borrow_mut();
                let (loss, grads) = forward::train_step_in(
                    &mut ws, &p.model, &p.blocks, &flats, tokens, targets, pad,
                )?;
                let mut out = vec![vec![loss]];
                out.extend(grads);
                Ok(out)
            }
            Entry::TrainStepMasked => {
                // blocks..., tokens, targets, mask (i32[n_blocks], nonzero
                // = selected). Outputs: loss + one gradient flat per
                // *selected* block in ascending block order — unselected
                // gradients never exist, so they cannot cross this
                // boundary.
                let p = self.preset(exe)?;
                let n = p.blocks.len();
                want(n + 3)?;
                let flats: Vec<&[f32]> =
                    args[..n].iter().map(|b| b.as_f32()).collect::<Result<_>>()?;
                let tokens = args[n].as_i32()?;
                let targets = args[n + 1].as_i32()?;
                let mask_raw = args[n + 2].as_i32()?;
                let mask: Vec<bool> = mask_raw.iter().map(|&x| x != 0).collect();
                let mut ws = self.ws.borrow_mut();
                let (loss, grads) = forward::train_step_masked_in(
                    &mut ws, &p.model, &p.blocks, &flats, tokens, targets, pad, &mask,
                )?;
                let mut out = vec![vec![loss]];
                out.extend(grads);
                Ok(out)
            }
            Entry::TrainStepLora { double } => {
                let p = self.preset(exe)?;
                let lblocks = if double { &p.lora_blocks2 } else { &p.lora_blocks };
                let (n, nl) = (p.blocks.len(), lblocks.len());
                want(n + nl + 2)?;
                let base: Vec<&[f32]> =
                    args[..n].iter().map(|b| b.as_f32()).collect::<Result<_>>()?;
                let lora: Vec<&[f32]> =
                    args[n..n + nl].iter().map(|b| b.as_f32()).collect::<Result<_>>()?;
                let tokens = args[n + nl].as_i32()?;
                let targets = args[n + nl + 1].as_i32()?;
                let mut ws = self.ws.borrow_mut();
                let (loss, grads) = forward::train_step_lora_in(
                    &mut ws, &p.model, &p.blocks, lblocks, &base, &lora, tokens, targets, pad,
                )?;
                let mut out = vec![vec![loss]];
                out.extend(grads);
                Ok(out)
            }
            Entry::EvalLoss => {
                let p = self.preset(exe)?;
                let n = p.blocks.len();
                want(n + 2)?;
                let flats: Vec<&[f32]> =
                    args[..n].iter().map(|b| b.as_f32()).collect::<Result<_>>()?;
                let mut ws = self.ws.borrow_mut();
                let loss = forward::eval_loss_in(
                    &mut ws,
                    &p.model,
                    &p.blocks,
                    &flats,
                    args[n].as_i32()?,
                    args[n + 1].as_i32()?,
                    pad,
                )?;
                Ok(vec![vec![loss]])
            }
            Entry::DecodeStep => {
                let p = self.preset(exe)?;
                let n = p.blocks.len();
                want(n + 1)?;
                let flats: Vec<&[f32]> =
                    args[..n].iter().map(|b| b.as_f32()).collect::<Result<_>>()?;
                let mut ws = self.ws.borrow_mut();
                let logits = forward::decode_logits_in(
                    &mut ws, &p.model, &p.blocks, &flats, args[n].as_i32()?,
                )?;
                Ok(vec![logits])
            }
            // The two serving entries in their stateless functional form
            // (cache-in/cache-out, mirroring what an XLA lowering returns):
            // the high-throughput path bypasses `execute` and runs the
            // in-place kernels against slot-pooled caches (`serve`).
            Entry::Prefill => {
                let p = self.preset(exe)?;
                let n = p.blocks.len();
                want(n + 1)?;
                let flats: Vec<&[f32]> =
                    args[..n].iter().map(|b| b.as_f32()).collect::<Result<_>>()?;
                let tokens = args[n].as_i32()?;
                let m = &p.model;
                let d = m.n_heads * m.d_head;
                let t = tokens.len();
                if t == 0 {
                    return Err(anyhow!("{}: empty prompt", exe.name));
                }
                // functional form: cache capacity == prompt length
                let mut k_store = vec![0.0f32; m.n_layers * t * d];
                let mut v_store = vec![0.0f32; m.n_layers * t * d];
                let logits = {
                    let layers = k_store
                        .chunks_mut(t * d)
                        .zip(v_store.chunks_mut(t * d))
                        .map(|(k, v)| forward::KvLayer { k, v })
                        .collect();
                    let mut seq = forward::SeqKv { layers, pos: 0 };
                    let mut ws = self.ws.borrow_mut();
                    forward::prefill_in(&mut ws, m, &p.blocks, &flats, tokens, &mut seq)?
                };
                Ok(vec![logits, k_store, v_store])
            }
            Entry::DecodeStepKv => {
                let p = self.preset(exe)?;
                let n = p.blocks.len();
                want(n + 4)?;
                let flats: Vec<&[f32]> =
                    args[..n].iter().map(|b| b.as_f32()).collect::<Result<_>>()?;
                let m = &p.model;
                let d = m.n_heads * m.d_head;
                let mut k_store = args[n].as_f32()?.to_vec();
                let mut v_store = args[n + 1].as_f32()?.to_vec();
                let token = *args[n + 2]
                    .as_i32()?
                    .first()
                    .ok_or_else(|| anyhow!("{}: empty token input", exe.name))?;
                let pos = *args[n + 3]
                    .as_i32()?
                    .first()
                    .ok_or_else(|| anyhow!("{}: empty position input", exe.name))?;
                if pos < 0 {
                    return Err(anyhow!("{}: negative position {pos}", exe.name));
                }
                if k_store.is_empty()
                    || k_store.len() != v_store.len()
                    || m.n_layers == 0
                    || k_store.len() % (m.n_layers * d) != 0
                {
                    return Err(anyhow!(
                        "{}: cache size {} does not tile into {} layer planes of width {d}",
                        exe.name,
                        k_store.len(),
                        m.n_layers
                    ));
                }
                let plane = k_store.len() / m.n_layers;
                let logits = {
                    let layers = k_store
                        .chunks_mut(plane)
                        .zip(v_store.chunks_mut(plane))
                        .map(|(k, v)| forward::KvLayer { k, v })
                        .collect();
                    let seq = forward::SeqKv { layers, pos: pos as usize };
                    let mut seqs = [seq];
                    let mut ws = self.ws.borrow_mut();
                    forward::decode_step_kv_in(&mut ws, m, &p.blocks, &flats, &[token], &mut seqs)?
                };
                Ok(vec![logits, k_store, v_store])
            }
            Entry::LoraMerge { double } => {
                let p = self.preset(exe)?;
                want(2)?;
                let lblocks = if double { &p.lora_blocks2 } else { &p.lora_blocks };
                if p.model.n_layers == 0 {
                    return Err(anyhow!("{}: preset has no layers", exe.name));
                }
                let merged = forward::lora_merge(
                    &p.blocks[1],
                    &lblocks[0],
                    args[0].as_f32()?,
                    args[1].as_f32()?,
                )?;
                Ok(vec![merged])
            }
            Entry::AdamWUpdate => {
                want(6)?;
                let mut p = args[0].as_f32()?.to_vec();
                let g = args[1].as_f32()?;
                let mut m = args[2].as_f32()?.to_vec();
                let mut v = args[3].as_f32()?.to_vec();
                let lr = *args[4]
                    .as_f32()?
                    .first()
                    .ok_or_else(|| anyhow!("adamw_update: empty lr input"))?;
                let step_f = *args[5]
                    .as_f32()?
                    .first()
                    .ok_or_else(|| anyhow!("adamw_update: empty step input"))?;
                if g.len() != p.len() || m.len() != p.len() || v.len() != p.len() {
                    return Err(anyhow!("adamw_update: p/g/m/v length mismatch"));
                }
                let hp = AdamWParams::from(self.manifest.adamw);
                fused_adamw(&mut p, g, &mut m, &mut v, lr, step_f.round() as u64, hp);
                Ok(vec![p, m, v])
            }
            Entry::GradNormSq => {
                want(1)?;
                let g = args[0].as_f32()?;
                Ok(vec![vec![block_norm_sq(g) as f32]])
            }
        }
    }
}

impl Backend for ReferenceBackend {
    type Buffer = RefBuffer;
    type Exe = RefExe;

    fn platform(&self) -> String {
        "reference-cpu".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load_preset_exe(&self, preset: &str, entry: &str) -> Result<Rc<RefExe>> {
        // mirror the PJRT engine: loading fails for entries the preset
        // does not export (e.g. train_step_pallas on non-Pallas presets)
        self.manifest.preset(preset)?.artifact(entry)?;
        let key = format!("{preset}:{entry}");
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let exe = Rc::new(RefExe {
            name: key.clone(),
            entry: Self::parse_entry(entry)?,
            preset: Some(preset.to_string()),
        });
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    fn load_shared_exe(&self, entry: &str) -> Result<Rc<RefExe>> {
        self.manifest
            .shared
            .get(entry)
            .ok_or_else(|| anyhow!("no shared artifact {entry:?}"))?;
        let key = format!("shared:{entry}");
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let exe = Rc::new(RefExe {
            name: key.clone(),
            entry: Self::parse_entry(entry)?,
            preset: None,
        });
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    fn upload_f32(&self, data: &[f32]) -> Result<RefBuffer> {
        Ok(RefBuffer::F32(data.to_vec()))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<RefBuffer> {
        let numel: usize = dims.iter().product();
        if numel != data.len() {
            return Err(anyhow!("upload i32: {} elements vs dims {dims:?}", data.len()));
        }
        Ok(RefBuffer::I32(data.to_vec(), dims.to_vec()))
    }

    fn execute(&self, exe: &RefExe, args: &[&RefBuffer]) -> Result<HostOutputs> {
        let t0 = Instant::now();
        let outputs = self.run(exe, args)?;
        Ok(HostOutputs::new(outputs, t0.elapsed().as_secs_f64(), 0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exe_cache_dedups() {
        let b = ReferenceBackend::new();
        let a = b.load_shared_exe("adamw_update").unwrap();
        let c = b.load_shared_exe("adamw_update").unwrap();
        assert!(Rc::ptr_eq(&a, &c));
        let t1 = b.load_preset_exe("test-tiny", "train_step").unwrap();
        let t2 = b.load_preset_exe("test-tiny", "train_step").unwrap();
        assert!(Rc::ptr_eq(&t1, &t2));
    }

    #[test]
    fn unknown_entries_rejected() {
        let b = ReferenceBackend::new();
        assert!(b.load_preset_exe("test-tiny", "nope").is_err());
        assert!(b.load_preset_exe("no-such-preset", "train_step").is_err());
        assert!(b.load_shared_exe("nope").is_err());
        // pallas artifact exists only for the pallas presets
        assert!(b.load_preset_exe("test-tiny", "train_step_pallas").is_ok());
        assert!(b.load_preset_exe("e2e", "train_step_pallas").is_err());
    }

    #[test]
    fn grad_norm_sq_entry_matches_native() {
        let b = ReferenceBackend::new();
        let exe = b.load_shared_exe("grad_norm_sq").unwrap();
        let g = vec![2.0f32; 1000];
        let buf = b.upload_f32(&g).unwrap();
        let out = b.execute(&exe, &[&buf]).unwrap();
        let norm = out.scalar_f32(0).unwrap();
        assert!((norm - 4000.0).abs() < 1e-3, "{norm}");
    }

    #[test]
    fn workspace_reaches_steady_state_after_warmup() {
        let b = ReferenceBackend::new();
        let p = b.manifest().preset("test-tiny").unwrap().clone();
        let exe = b.load_preset_exe("test-tiny", "train_step").unwrap();
        let state = crate::model::ModelState::init(&p.blocks, 2);
        let blocks: Vec<_> = state.flats.iter().map(|f| b.upload_f32(f).unwrap()).collect();
        let (bb, ss) = (p.model.batch, p.model.seq_len);
        let tokens: Vec<i32> = (0..bb * ss).map(|i| 4 + (i % 40) as i32).collect();
        let tok = b.upload_i32(&tokens, &[bb, ss]).unwrap();
        let mut args: Vec<_> = blocks.iter().collect();
        args.push(&tok);
        args.push(&tok);
        let out0 = b.execute(&exe, &args).unwrap();
        let warm = b.workspace_stats();
        assert!(warm.high_water_bytes > 0);
        for _ in 0..3 {
            let out = b.execute(&exe, &args).unwrap();
            assert_eq!(out.outputs, out0.outputs, "arena reuse must stay bit-deterministic");
        }
        let steady = b.workspace_stats();
        assert_eq!(steady.grows, warm.grows, "steady-state steps must not allocate slabs");
        assert_eq!(steady.high_water_bytes, warm.high_water_bytes);
    }

    #[test]
    fn upload_i32_validates_dims() {
        let b = ReferenceBackend::new();
        assert!(b.upload_i32(&[1, 2, 3], &[2, 2]).is_err());
        assert!(b.upload_i32(&[1, 2, 3, 4], &[2, 2]).is_ok());
    }
}
