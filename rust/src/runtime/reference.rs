//! Pure-Rust CPU reference backend.
//!
//! Implements [`Backend`] with no artifacts, no Python and no external
//! crates: "executables" are dispatch tags into the native transformer
//! fwd/bwd (`model::forward`) and the fused AdamW / grad-norm kernels, and
//! "device tensors" are host vectors behind [`RefTensor`] handles. Entry
//! names and argument layouts are byte-for-byte the PJRT engine's, so the
//! trainer, evaluator and benches run unchanged on either backend.
//!
//! This is the trusted dense reference the selection methods are
//! validated against (GRASS / BlockLLM-style parity methodology): CI
//! trains real models through this backend on every push.
//!
//! # Device-tensor handles and the buffer pool
//!
//! A [`RefTensor`] is a shared handle (`Rc<RefCell<..>>`) to one typed
//! tensor. Handles make three things possible that the old flat
//! upload/execute/download API could not express:
//!
//! * **In-place entries** (`train_step_fused`, `adamw_update_inplace`)
//!   mutate the tensors their argument handles name — parameter and
//!   moment buffers are updated without reallocation or host traffic,
//!   the donation semantics of the [`Backend`] contract.
//! * **Explicit read-back**: outputs come back as handles; only
//!   [`Backend::read_f32`] moves bytes, and every byte is counted in
//!   [`Backend::transfer_stats`].
//! * **Buffer pooling**: when the last handle to a tensor drops, the
//!   backend's registry reuses its storage for the next same-shaped
//!   allocation. Steady-state training loops therefore perform zero
//!   device-buffer allocations — `transfer_stats().buffer_allocs` is the
//!   observable, and the bench suite pins it.
//!
//! The backend also owns a [`Workspace`] arena shared by every entrypoint
//! it executes: the first step warms the slab pool, after which the
//! compute path (GEMMs, activations, attention scratch, per-projection
//! gradient staging) performs zero heap allocations per step. The arena's
//! high-water mark — the real per-step activation/scratch footprint — is
//! exposed via [`ReferenceBackend::workspace_stats`].
//!
//! The serving entries (`prefill`, `decode_step_kv`) are exposed here in
//! their stateless functional form (caches as explicit inputs/outputs,
//! the shape an XLA lowering has). The serving engine itself
//! (`crate::serve`) bypasses `execute` and runs the same kernels in-place
//! against slot-pooled caches through the backend's arena — that is the
//! zero-copy, zero-steady-state-allocation path.

use std::cell::{Cell, Ref, RefCell, RefMut};
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, Result};

use crate::model::forward;
use crate::optimizer::{fused_adamw, fused_adamw_scaled, lr_cosine, AdamWParams};
use crate::selection::grad_norm::block_norm_sq;
use crate::telemetry::Stopwatch;
use crate::util::workspace::{Workspace, WorkspaceStats};

use super::backend::{Backend, DType, DeviceOutputs, TensorMeta, TransferStats};
use super::manifest::{Manifest, Preset};

/// Storage of one reference-backend "device" tensor.
pub enum TensorData {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl TensorData {
    fn meta(&self) -> TensorMeta {
        match self {
            TensorData::F32 { dims, .. } => TensorMeta { dtype: DType::F32, dims: dims.clone() },
            TensorData::I32 { dims, .. } => TensorMeta { dtype: DType::I32, dims: dims.clone() },
        }
    }
}

/// Typed device-tensor handle of the reference backend. Cloning a handle
/// shares the underlying tensor; the storage is recycled by the backend's
/// buffer pool once the last handle drops.
pub struct RefTensor {
    cell: Rc<RefCell<TensorData>>,
}

impl Clone for RefTensor {
    fn clone(&self) -> Self {
        Self { cell: self.cell.clone() }
    }
}

impl RefTensor {
    fn new(data: TensorData) -> Self {
        Self { cell: Rc::new(RefCell::new(data)) }
    }

    /// Borrow the tensor as an f32 slice (errors on i32 tensors).
    pub fn as_f32(&self) -> Result<Ref<'_, [f32]>> {
        Ref::filter_map(self.cell.borrow(), |d| match d {
            TensorData::F32 { data, .. } => Some(data.as_slice()),
            TensorData::I32 { .. } => None,
        })
        .map_err(|_| anyhow!("expected an f32 tensor, got i32"))
    }

    /// Borrow the tensor as an i32 slice (errors on f32 tensors).
    pub fn as_i32(&self) -> Result<Ref<'_, [i32]>> {
        Ref::filter_map(self.cell.borrow(), |d| match d {
            TensorData::I32 { data, .. } => Some(data.as_slice()),
            TensorData::F32 { .. } => None,
        })
        .map_err(|_| anyhow!("expected an i32 tensor, got f32"))
    }

    /// Mutably borrow as f32 — the in-place (donation) path. Errors if
    /// the tensor is i32 or already borrowed (the same handle passed for
    /// two arguments of an in-place entry).
    fn as_f32_mut(&self) -> Result<RefMut<'_, [f32]>> {
        let cell = self
            .cell
            .try_borrow_mut()
            .map_err(|_| anyhow!("tensor is aliased by another argument of an in-place entry"))?;
        RefMut::filter_map(cell, |d| match d {
            TensorData::F32 { data, .. } => Some(data.as_mut_slice()),
            TensorData::I32 { .. } => None,
        })
        .map_err(|_| anyhow!("expected an f32 tensor, got i32"))
    }

    fn meta(&self) -> TensorMeta {
        self.cell.borrow().meta()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Entry {
    TrainStep,
    TrainStepMasked,
    TrainStepShard,
    TrainStepMaskedShard,
    TrainStepFused,
    TrainStepLora { double: bool },
    EvalLoss,
    DecodeStep,
    Prefill,
    DecodeStepKv,
    LoraMerge { double: bool },
    AdamWUpdate,
    AdamWUpdateInplace,
    GradNormSq,
}

impl Entry {
    /// Input arity of this entry for a preset with `n` base blocks and
    /// `nl` LoRA blocks — the number the manifest's [`ArtifactInfo`]
    /// (`n_inputs`) must agree with at load time.
    ///
    /// [`ArtifactInfo`]: super::manifest::ArtifactInfo
    fn arity(self, n: usize, nl: usize) -> usize {
        match self {
            Entry::TrainStep => n + 2,
            Entry::TrainStepMasked => n + 3,
            // blocks + tokens + targets + denom (global non-pad count)
            Entry::TrainStepShard => n + 3,
            // ... + mask
            Entry::TrainStepMaskedShard => n + 4,
            // blocks + m + v + t (one scalar tensor per block) + sched +
            // step + tokens + targets + mask
            Entry::TrainStepFused => 4 * n + 5,
            Entry::TrainStepLora { .. } => n + nl + 2,
            Entry::EvalLoss => n + 2,
            Entry::DecodeStep => n + 1,
            Entry::Prefill => n + 1,
            Entry::DecodeStepKv => n + 4,
            Entry::LoraMerge { .. } => 2,
            Entry::AdamWUpdate => 6,
            Entry::AdamWUpdateInplace => 7,
            Entry::GradNormSq => 1,
        }
    }
}

/// A "loaded executable": an entry tag bound to a preset (or shared).
pub struct RefExe {
    pub name: String,
    /// Input arity asserted against the manifest at load time.
    pub n_inputs: usize,
    entry: Entry,
    preset: Option<String>,
}

/// The pure-Rust CPU executor (the crate's default backend).
pub struct ReferenceBackend {
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<RefExe>>>,
    /// Step-scoped buffer arena shared by all entrypoints (warm after the
    /// first execute; steady-state steps allocate nothing).
    ws: RefCell<Workspace>,
    /// Device-buffer registry: every live tensor plus recyclable freed
    /// storage (strong count 1 ⇒ only the registry holds it).
    registry: RefCell<Vec<Rc<RefCell<TensorData>>>>,
    stats: Cell<TransferStats>,
}

/// Registry size above which freed buffers are garbage-collected on the
/// next registration (keeps long explore phases from hoarding storage).
const REGISTRY_GC_LEN: usize = 512;

impl Default for ReferenceBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ReferenceBackend {
    /// Backend over the built-in preset catalog (no artifacts needed).
    pub fn new() -> Self {
        Self::with_manifest(Manifest::builtin())
    }

    /// Backend over an explicit manifest (e.g. one loaded from an
    /// artifacts directory, for strict topology parity with a PJRT run).
    pub fn with_manifest(manifest: Manifest) -> Self {
        Self {
            manifest,
            cache: RefCell::new(HashMap::new()),
            ws: RefCell::new(Workspace::new()),
            registry: RefCell::new(Vec::new()),
            stats: Cell::new(TransferStats::default()),
        }
    }

    /// Snapshot of the compute arena's accounting: high-water bytes (the
    /// measured per-step activation/scratch footprint) and the slab-grow
    /// counter (unchanged between two snapshots ⇒ the interval ran
    /// allocation-free).
    pub fn workspace_stats(&self) -> WorkspaceStats {
        self.ws.borrow().stats()
    }

    /// Restart the arena's peak tracking (see
    /// [`Workspace::reset_high_water`]) so the next
    /// [`ReferenceBackend::workspace_stats`] reports the footprint of
    /// just the steps executed since — how the bench measures the masked
    /// (exploit) step's reduced activation footprint separately from the
    /// full step's.
    pub fn reset_workspace_high_water(&self) {
        self.ws.borrow_mut().reset_high_water();
    }

    /// Run `f` against the backend's shared workspace arena — the hook
    /// the serving fast path (`serve::KvBackend`) uses to execute the
    /// in-place prefill/decode kernels without going through the
    /// stateless `execute` interface, while still sharing the warm slab
    /// pool with every other entrypoint.
    pub(crate) fn with_workspace<R>(&self, f: impl FnOnce(&mut Workspace) -> R) -> R {
        f(&mut self.ws.borrow_mut())
    }

    fn bump(&self, f: impl FnOnce(&mut TransferStats)) {
        let mut s = self.stats.get();
        f(&mut s);
        self.stats.set(s);
    }

    /// Register freshly-allocated tensor storage (a buffer-pool miss).
    fn adopt(&self, data: TensorData) -> RefTensor {
        let bytes = match &data {
            TensorData::F32 { data, .. } => data.len() * 4,
            TensorData::I32 { data, .. } => data.len() * 4,
        };
        self.bump(|s| {
            s.buffer_allocs += 1;
            s.buffer_alloc_bytes += bytes as u64;
        });
        let mut reg = self.registry.borrow_mut();
        if reg.len() >= REGISTRY_GC_LEN {
            reg.retain(|c| Rc::strong_count(c) > 1);
        }
        let t = RefTensor::new(data);
        reg.push(t.cell.clone());
        t
    }

    /// Allocate an f32 tensor, preferring a freed same-size buffer from
    /// the registry (a pool hit allocates nothing).
    fn alloc_f32(&self, numel: usize, dims: Vec<usize>) -> RefTensor {
        {
            let reg = self.registry.borrow();
            for cell in reg.iter() {
                if Rc::strong_count(cell) != 1 {
                    continue;
                }
                let mut d = cell.borrow_mut();
                if let TensorData::F32 { data, dims: dd } = &mut *d {
                    if data.len() == numel {
                        *dd = dims;
                        drop(d);
                        return RefTensor { cell: cell.clone() };
                    }
                }
            }
        }
        self.adopt(TensorData::F32 { data: vec![0.0; numel], dims })
    }

    /// Allocate an i32 tensor from the pool (see [`Self::alloc_f32`]).
    fn alloc_i32(&self, numel: usize, dims: Vec<usize>) -> RefTensor {
        {
            let reg = self.registry.borrow();
            for cell in reg.iter() {
                if Rc::strong_count(cell) != 1 {
                    continue;
                }
                let mut d = cell.borrow_mut();
                if let TensorData::I32 { data, dims: dd } = &mut *d {
                    if data.len() == numel {
                        *dd = dims;
                        drop(d);
                        return RefTensor { cell: cell.clone() };
                    }
                }
            }
        }
        self.adopt(TensorData::I32 { data: vec![0; numel], dims })
    }

    /// Hand a kernel-produced vector out as a device tensor (the output
    /// buffer an XLA executable would have allocated for it).
    fn out_f32(&self, data: Vec<f32>, dims: Vec<usize>) -> RefTensor {
        self.adopt(TensorData::F32 { data, dims })
    }

    /// Pool-backed scalar/loss output: reuses freed storage, so hot loops
    /// that drop their output handle each step allocate nothing.
    fn out_f32_pooled(&self, data: &[f32], dims: Vec<usize>) -> RefTensor {
        let t = self.alloc_f32(data.len(), dims);
        if let TensorData::F32 { data: dst, .. } = &mut *t.cell.borrow_mut() {
            dst.copy_from_slice(data);
        }
        t
    }

    fn parse_entry(entry: &str) -> Result<Entry> {
        Ok(match entry {
            // the Pallas-attention artifact computes the same function;
            // the reference backend has exactly one attention path
            "train_step" | "train_step_pallas" => Entry::TrainStep,
            "train_step_masked" => Entry::TrainStepMasked,
            "train_step_shard" => Entry::TrainStepShard,
            "train_step_masked_shard" => Entry::TrainStepMaskedShard,
            "train_step_fused" => Entry::TrainStepFused,
            "train_step_lora" => Entry::TrainStepLora { double: false },
            "train_step_lora2" => Entry::TrainStepLora { double: true },
            "eval_loss" => Entry::EvalLoss,
            "decode_step" => Entry::DecodeStep,
            "prefill" => Entry::Prefill,
            "decode_step_kv" => Entry::DecodeStepKv,
            "lora_merge" => Entry::LoraMerge { double: false },
            "lora_merge2" => Entry::LoraMerge { double: true },
            "adamw_update" => Entry::AdamWUpdate,
            "adamw_update_inplace" => Entry::AdamWUpdateInplace,
            "grad_norm_sq" => Entry::GradNormSq,
            other => return Err(anyhow!("reference backend has no entrypoint {other:?}")),
        })
    }

    fn preset(&self, exe: &RefExe) -> Result<&Preset> {
        let name = exe
            .preset
            .as_deref()
            .ok_or_else(|| anyhow!("{}: entry needs a preset", exe.name))?;
        self.manifest.preset(name)
    }

    /// Borrow `args` as f32 slices (block tables of the forward kernels).
    fn f32_guards<'a>(&self, args: &'a [&RefTensor]) -> Result<Vec<Ref<'a, [f32]>>> {
        args.iter().map(|a| a.as_f32()).collect()
    }

    fn run(&self, exe: &RefExe, args: &[&RefTensor]) -> Result<Vec<RefTensor>> {
        if args.len() != exe.n_inputs {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                exe.name,
                exe.n_inputs,
                args.len()
            ));
        }
        let pad = self.manifest.tokenizer.pad;
        match exe.entry {
            Entry::TrainStep => {
                let p = self.preset(exe)?;
                let n = p.blocks.len();
                let guards = self.f32_guards(&args[..n])?;
                let flats: Vec<&[f32]> = guards.iter().map(|g| &**g).collect();
                let tokens = args[n].as_i32()?;
                let targets = args[n + 1].as_i32()?;
                let mut ws = self.ws.borrow_mut();
                let (loss, grads) = forward::train_step_in(
                    &mut ws, &p.model, &p.blocks, &flats, &tokens, &targets, pad,
                )?;
                drop(ws);
                let mut out = vec![self.out_f32_pooled(&[loss], vec![1])];
                out.extend(grads.into_iter().map(|g| {
                    let dims = vec![g.len()];
                    self.out_f32(g, dims)
                }));
                Ok(out)
            }
            Entry::TrainStepMasked => {
                // blocks..., tokens, targets, mask (i32[n_blocks], nonzero
                // = selected). Outputs: loss + one gradient flat per
                // *selected* block in ascending block order — unselected
                // gradients never exist, so they cannot cross this
                // boundary.
                let p = self.preset(exe)?;
                let n = p.blocks.len();
                let guards = self.f32_guards(&args[..n])?;
                let flats: Vec<&[f32]> = guards.iter().map(|g| &**g).collect();
                let tokens = args[n].as_i32()?;
                let targets = args[n + 1].as_i32()?;
                let mask: Vec<bool> = args[n + 2].as_i32()?.iter().map(|&x| x != 0).collect();
                let mut ws = self.ws.borrow_mut();
                let (loss, grads) = forward::train_step_masked_in(
                    &mut ws, &p.model, &p.blocks, &flats, &tokens, &targets, pad, &mask,
                )?;
                drop(ws);
                let mut out = vec![self.out_f32_pooled(&[loss], vec![1])];
                out.extend(grads.into_iter().map(|g| {
                    let dims = vec![g.len()];
                    self.out_f32(g, dims)
                }));
                Ok(out)
            }
            Entry::TrainStepShard | Entry::TrainStepMaskedShard => {
                // Shard-local data-parallel step: blocks..., tokens,
                // targets, denom (i32[1], the globally summed non-pad
                // target count), and for the masked form a trailing
                // mask i32[n_blocks]. The local batch is derived from
                // the token tensor, so one loaded executable serves any
                // shard width that divides the preset batch. Outputs:
                // the **undivided** shard loss partial + gradient
                // subtree partials (all blocks, or the selected subset
                // in ascending block order for the masked form) — the
                // coordinator tree-folds rank partials bit-exactly
                // (see forward::train_step_shard_in).
                let p = self.preset(exe)?;
                let n = p.blocks.len();
                let guards = self.f32_guards(&args[..n])?;
                let flats: Vec<&[f32]> = guards.iter().map(|g| &**g).collect();
                let tokens = args[n].as_i32()?;
                let targets = args[n + 1].as_i32()?;
                let denom_t = args[n + 2].as_i32()?;
                let denom = *denom_t
                    .first()
                    .ok_or_else(|| anyhow!("{}: empty denom input", exe.name))?;
                if denom < 0 {
                    return Err(anyhow!("{}: negative denom {denom}", exe.name));
                }
                let s = p.model.seq_len;
                if s == 0 || tokens.len() % s != 0 || tokens.is_empty() {
                    return Err(anyhow!(
                        "{}: {} tokens do not tile into rows of seq_len {s}",
                        exe.name,
                        tokens.len()
                    ));
                }
                let mut spec = p.model.clone();
                spec.batch = tokens.len() / s;
                let mask: Option<Vec<bool>> = if exe.entry == Entry::TrainStepMaskedShard {
                    Some(args[n + 3].as_i32()?.iter().map(|&x| x != 0).collect())
                } else {
                    None
                };
                let mut ws = self.ws.borrow_mut();
                let (loss_partial, grads) = match &mask {
                    Some(m) => forward::train_step_masked_shard_in(
                        &mut ws,
                        &spec,
                        &p.blocks,
                        &flats,
                        &tokens,
                        &targets,
                        pad,
                        m,
                        denom as usize,
                    )?,
                    None => forward::train_step_shard_in(
                        &mut ws,
                        &spec,
                        &p.blocks,
                        &flats,
                        &tokens,
                        &targets,
                        pad,
                        denom as usize,
                    )?,
                };
                drop(ws);
                // grads go through the pool (not `out_f32`): the sharded
                // trainer drops its output handles every step, so a
                // steady-state shard loop reuses the same grad buffers —
                // `buffer_allocs` stays flat, the invariant the sharded
                // bench and tests/sharded_parity.rs pin.
                let mut out = vec![self.out_f32_pooled(&[loss_partial], vec![1])];
                out.extend(grads.into_iter().map(|g| {
                    let dims = vec![g.len()];
                    self.out_f32_pooled(&g, dims)
                }));
                Ok(out)
            }
            Entry::TrainStepFused => {
                // The fully device-resident exploit step. Inputs:
                // blocks[n] | m[n] | v[n] | t[n] (f32[1] step counts) |
                // sched f32[4] = [lr, warmup, total, min_lr_frac] |
                // step f32[1] (global step, for the lr schedule) |
                // tokens | targets | mask i32[n].
                //
                // Runs the masked backward, then applies fused AdamW to
                // the selected blocks **in place** (donated p/m/v/t
                // buffers), advances their step counts and the global
                // step. Single output: the loss scalar — gradients and
                // optimizer state never cross the boundary. Global-norm
                // clipping is not part of this entry; the trainer routes
                // clipped runs through the composed
                // masked-backward + `grad_norm_sq` + `adamw_update_inplace`
                // path instead.
                let p = self.preset(exe)?;
                let n = p.blocks.len();
                let (blocks_a, rest) = args.split_at(n);
                let (m_a, rest) = rest.split_at(n);
                let (v_a, rest) = rest.split_at(n);
                let (t_a, rest) = rest.split_at(n);
                let sched: Vec<f32> = rest[0].as_f32()?.to_vec();
                if sched.len() != 4 {
                    return Err(anyhow!("{}: sched must be f32[4]", exe.name));
                }
                let step_f = rest[1]
                    .as_f32()?
                    .first()
                    .copied()
                    .ok_or_else(|| anyhow!("{}: empty step input", exe.name))?;
                let mask: Vec<bool> = rest[4].as_i32()?.iter().map(|&x| x != 0).collect();

                let (loss, grads) = {
                    let guards = self.f32_guards(blocks_a)?;
                    let flats: Vec<&[f32]> = guards.iter().map(|g| &**g).collect();
                    let tokens = rest[2].as_i32()?;
                    let targets = rest[3].as_i32()?;
                    let mut ws = self.ws.borrow_mut();
                    forward::train_step_masked_in(
                        &mut ws, &p.model, &p.blocks, &flats, &tokens, &targets, pad, &mask,
                    )?
                };

                let lr = lr_cosine(sched[0], sched[1], sched[2], sched[3], step_f);
                let hp = AdamWParams::from(self.manifest.adamw);
                let selected: Vec<usize> =
                    (0..n).filter(|&b| mask.get(b).copied().unwrap_or(false)).collect();
                for (j, &b) in selected.iter().enumerate() {
                    let mut pm = blocks_a[b].as_f32_mut()?;
                    let mut mm = m_a[b].as_f32_mut()?;
                    let mut vm = v_a[b].as_f32_mut()?;
                    let mut tm = t_a[b].as_f32_mut()?;
                    let g = &grads[j];
                    if pm.len() != g.len() || mm.len() != g.len() || vm.len() != g.len() {
                        return Err(anyhow!("{}: block {b} p/m/v/grad size mismatch", exe.name));
                    }
                    if tm.is_empty() {
                        return Err(anyhow!("{}: block {b} step count must be f32[1]", exe.name));
                    }
                    let before = tm[0];
                    tm[0] += 1.0;
                    if tm[0] == before {
                        // f32 integers saturate at 2^24; the host-loop
                        // oracle's u64 counter would keep going, so fail
                        // loudly instead of silently diverging
                        return Err(anyhow!(
                            "{}: block {b} step count saturated f32 at {before}",
                            exe.name
                        ));
                    }
                    fused_adamw(&mut pm, g, &mut mm, &mut vm, lr, tm[0] as u64, hp);
                }
                let mut sm = rest[1].as_f32_mut()?;
                if sm.is_empty() {
                    return Err(anyhow!("{}: step must be f32[1]", exe.name));
                }
                let before = sm[0];
                sm[0] += 1.0;
                if sm[0] == before {
                    return Err(anyhow!("{}: global step saturated f32 at {before}", exe.name));
                }
                drop(sm);
                Ok(vec![self.out_f32_pooled(&[loss], vec![1])])
            }
            Entry::TrainStepLora { double } => {
                let p = self.preset(exe)?;
                let lblocks = if double { &p.lora_blocks2 } else { &p.lora_blocks };
                let (n, nl) = (p.blocks.len(), lblocks.len());
                let base_g = self.f32_guards(&args[..n])?;
                let base: Vec<&[f32]> = base_g.iter().map(|g| &**g).collect();
                let lora_g = self.f32_guards(&args[n..n + nl])?;
                let lora: Vec<&[f32]> = lora_g.iter().map(|g| &**g).collect();
                let tokens = args[n + nl].as_i32()?;
                let targets = args[n + nl + 1].as_i32()?;
                let mut ws = self.ws.borrow_mut();
                let (loss, grads) = forward::train_step_lora_in(
                    &mut ws, &p.model, &p.blocks, lblocks, &base, &lora, &tokens, &targets, pad,
                )?;
                drop(ws);
                let mut out = vec![self.out_f32_pooled(&[loss], vec![1])];
                out.extend(grads.into_iter().map(|g| {
                    let dims = vec![g.len()];
                    self.out_f32(g, dims)
                }));
                Ok(out)
            }
            Entry::EvalLoss => {
                let p = self.preset(exe)?;
                let n = p.blocks.len();
                let guards = self.f32_guards(&args[..n])?;
                let flats: Vec<&[f32]> = guards.iter().map(|g| &**g).collect();
                let tokens = args[n].as_i32()?;
                let targets = args[n + 1].as_i32()?;
                let mut ws = self.ws.borrow_mut();
                let loss = forward::eval_loss_in(
                    &mut ws, &p.model, &p.blocks, &flats, &tokens, &targets, pad,
                )?;
                drop(ws);
                Ok(vec![self.out_f32_pooled(&[loss], vec![1])])
            }
            Entry::DecodeStep => {
                let p = self.preset(exe)?;
                let n = p.blocks.len();
                let guards = self.f32_guards(&args[..n])?;
                let flats: Vec<&[f32]> = guards.iter().map(|g| &**g).collect();
                let tokens = args[n].as_i32()?;
                let mut ws = self.ws.borrow_mut();
                let logits =
                    forward::decode_logits_in(&mut ws, &p.model, &p.blocks, &flats, &tokens)?;
                drop(ws);
                let dims = vec![logits.len()];
                Ok(vec![self.out_f32(logits, dims)])
            }
            // The two serving entries in their stateless functional form
            // (cache-in/cache-out, mirroring what an XLA lowering returns):
            // the high-throughput path bypasses `execute` and runs the
            // in-place kernels against slot-pooled caches (`serve`).
            Entry::Prefill => {
                let p = self.preset(exe)?;
                let n = p.blocks.len();
                let guards = self.f32_guards(&args[..n])?;
                let flats: Vec<&[f32]> = guards.iter().map(|g| &**g).collect();
                let tokens = args[n].as_i32()?;
                let m = &p.model;
                let d = m.n_heads * m.d_head;
                let t = tokens.len();
                if t == 0 {
                    return Err(anyhow!("{}: empty prompt", exe.name));
                }
                // functional form: cache capacity == prompt length
                let mut k_store = vec![0.0f32; m.n_layers * t * d];
                let mut v_store = vec![0.0f32; m.n_layers * t * d];
                let logits = {
                    // one whole-sequence page: the functional flat
                    // [n_layers, t, d] layout, unchanged on the wire
                    let mut seq = forward::KvView::contiguous(
                        &mut k_store,
                        &mut v_store,
                        m.n_layers,
                        d,
                        0,
                    )?;
                    let mut ws = self.ws.borrow_mut();
                    forward::prefill_in(&mut ws, m, &p.blocks, &flats, &tokens, &mut seq)?
                };
                let (ld, kd) = (vec![logits.len()], vec![k_store.len()]);
                Ok(vec![
                    self.out_f32(logits, ld),
                    self.out_f32(k_store, kd.clone()),
                    self.out_f32(v_store, kd),
                ])
            }
            Entry::DecodeStepKv => {
                let p = self.preset(exe)?;
                let n = p.blocks.len();
                let guards = self.f32_guards(&args[..n])?;
                let flats: Vec<&[f32]> = guards.iter().map(|g| &**g).collect();
                let m = &p.model;
                let d = m.n_heads * m.d_head;
                let mut k_store = args[n].as_f32()?.to_vec();
                let mut v_store = args[n + 1].as_f32()?.to_vec();
                let token = *args[n + 2]
                    .as_i32()?
                    .first()
                    .ok_or_else(|| anyhow!("{}: empty token input", exe.name))?;
                let pos = *args[n + 3]
                    .as_i32()?
                    .first()
                    .ok_or_else(|| anyhow!("{}: empty position input", exe.name))?;
                if pos < 0 {
                    return Err(anyhow!("{}: negative position {pos}", exe.name));
                }
                if k_store.is_empty()
                    || k_store.len() != v_store.len()
                    || m.n_layers == 0
                    || k_store.len() % (m.n_layers * d) != 0
                {
                    return Err(anyhow!(
                        "{}: cache size {} does not tile into {} layer planes of width {d}",
                        exe.name,
                        k_store.len(),
                        m.n_layers
                    ));
                }
                let logits = {
                    let seq = forward::KvView::contiguous(
                        &mut k_store,
                        &mut v_store,
                        m.n_layers,
                        d,
                        pos as usize,
                    )?;
                    let mut seqs = [seq];
                    let mut ws = self.ws.borrow_mut();
                    forward::decode_step_kv_in(&mut ws, m, &p.blocks, &flats, &[token], &mut seqs)?
                };
                let (ld, kd) = (vec![logits.len()], vec![k_store.len()]);
                Ok(vec![
                    self.out_f32(logits, ld),
                    self.out_f32(k_store, kd.clone()),
                    self.out_f32(v_store, kd),
                ])
            }
            Entry::LoraMerge { double } => {
                let p = self.preset(exe)?;
                let lblocks = if double { &p.lora_blocks2 } else { &p.lora_blocks };
                if p.model.n_layers == 0 {
                    return Err(anyhow!("{}: preset has no layers", exe.name));
                }
                let base = args[0].as_f32()?;
                let lora = args[1].as_f32()?;
                let merged = forward::lora_merge(&p.blocks[1], &lblocks[0], &base, &lora)?;
                let dims = vec![merged.len()];
                Ok(vec![self.out_f32(merged, dims)])
            }
            Entry::AdamWUpdate => {
                // Functional form (p, g, m, v, lr, step) -> (p', m', v'):
                // kept for the chunked HloAdamW parity path; the trainer's
                // device-resident loop uses `adamw_update_inplace`.
                let mut p = args[0].as_f32()?.to_vec();
                let g = args[1].as_f32()?;
                let mut m = args[2].as_f32()?.to_vec();
                let mut v = args[3].as_f32()?.to_vec();
                let lr = *args[4]
                    .as_f32()?
                    .first()
                    .ok_or_else(|| anyhow!("adamw_update: empty lr input"))?;
                let step_f = *args[5]
                    .as_f32()?
                    .first()
                    .ok_or_else(|| anyhow!("adamw_update: empty step input"))?;
                if g.len() != p.len() || m.len() != p.len() || v.len() != p.len() {
                    return Err(anyhow!("adamw_update: p/g/m/v length mismatch"));
                }
                let hp = AdamWParams::from(self.manifest.adamw);
                fused_adamw(&mut p, &g, &mut m, &mut v, lr, step_f.round() as u64, hp);
                drop(g);
                let dims = vec![p.len()];
                Ok(vec![
                    self.out_f32(p, dims.clone()),
                    self.out_f32(m, dims.clone()),
                    self.out_f32(v, dims),
                ])
            }
            Entry::AdamWUpdateInplace => {
                // Donating form (p, g, m, v, t, lr, scale): p/m/v are
                // updated in place, t (the block's f32[1] step count) is
                // advanced, and `g * scale` feeds the moments (the
                // global-norm clip multiply). No outputs — the composed
                // device-resident optimizer path over handles.
                let g = args[1].as_f32()?;
                let lr = *args[5]
                    .as_f32()?
                    .first()
                    .ok_or_else(|| anyhow!("adamw_update_inplace: empty lr input"))?;
                let scale = *args[6]
                    .as_f32()?
                    .first()
                    .ok_or_else(|| anyhow!("adamw_update_inplace: empty scale input"))?;
                let mut p = args[0].as_f32_mut()?;
                let mut m = args[2].as_f32_mut()?;
                let mut v = args[3].as_f32_mut()?;
                let mut t = args[4].as_f32_mut()?;
                if g.len() != p.len() || m.len() != p.len() || v.len() != p.len() {
                    return Err(anyhow!("adamw_update_inplace: p/g/m/v length mismatch"));
                }
                if t.is_empty() {
                    return Err(anyhow!("adamw_update_inplace: step count must be f32[1]"));
                }
                let hp = AdamWParams::from(self.manifest.adamw);
                let before = t[0];
                t[0] += 1.0;
                if t[0] == before {
                    // see TrainStepFused: f32 integers saturate at 2^24
                    return Err(anyhow!("adamw_update_inplace: step saturated f32 at {before}"));
                }
                fused_adamw_scaled(&mut p, &g, &mut m, &mut v, scale, lr, t[0] as u64, hp);
                Ok(Vec::new())
            }
            Entry::GradNormSq => {
                let g = args[0].as_f32()?;
                let norm = block_norm_sq(&g) as f32;
                drop(g);
                Ok(vec![self.out_f32_pooled(&[norm], vec![1])])
            }
        }
    }
}

impl Backend for ReferenceBackend {
    type Buffer = RefTensor;
    type Exe = RefExe;

    fn platform(&self) -> String {
        "reference-cpu".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load_preset_exe(&self, preset: &str, entry: &str) -> Result<Rc<RefExe>> {
        // mirror the PJRT engine: loading fails for entries the preset
        // does not export (e.g. train_step_pallas on non-Pallas presets)
        let p = self.manifest.preset(preset)?;
        let info = p.artifact(entry)?;
        let key = format!("{preset}:{entry}");
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let tag = Self::parse_entry(entry)?;
        let arity = tag.arity(p.blocks.len(), p.lora_blocks.len());
        if info.n_inputs != arity {
            return Err(anyhow!(
                "{key}: manifest declares {} inputs, executable takes {arity}",
                info.n_inputs
            ));
        }
        let exe = Rc::new(RefExe {
            name: key.clone(),
            n_inputs: arity,
            entry: tag,
            preset: Some(preset.to_string()),
        });
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    fn load_shared_exe(&self, entry: &str) -> Result<Rc<RefExe>> {
        let info = self
            .manifest
            .shared
            .get(entry)
            .ok_or_else(|| anyhow!("no shared artifact {entry:?}"))?;
        let key = format!("shared:{entry}");
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let tag = Self::parse_entry(entry)?;
        let arity = tag.arity(0, 0);
        if info.n_inputs != arity {
            return Err(anyhow!(
                "{key}: manifest declares {} inputs, executable takes {arity}",
                info.n_inputs
            ));
        }
        let exe = Rc::new(RefExe { name: key.clone(), n_inputs: arity, entry: tag, preset: None });
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<RefTensor> {
        let numel: usize = dims.iter().product();
        if numel != data.len() {
            return Err(anyhow!("upload f32: {} elements vs dims {dims:?}", data.len()));
        }
        let t = self.alloc_f32(numel, dims.to_vec());
        if let TensorData::F32 { data: dst, .. } = &mut *t.cell.borrow_mut() {
            dst.copy_from_slice(data);
        }
        self.bump(|s| {
            s.h2d_bytes += (data.len() * 4) as u64;
            s.h2d_transfers += 1;
        });
        Ok(t)
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<RefTensor> {
        let numel: usize = dims.iter().product();
        if numel != data.len() {
            return Err(anyhow!("upload i32: {} elements vs dims {dims:?}", data.len()));
        }
        let t = self.alloc_i32(numel, dims.to_vec());
        if let TensorData::I32 { data: dst, .. } = &mut *t.cell.borrow_mut() {
            dst.copy_from_slice(data);
        }
        self.bump(|s| {
            s.h2d_bytes += (data.len() * 4) as u64;
            s.h2d_transfers += 1;
        });
        Ok(t)
    }

    fn write_f32(&self, dst: &RefTensor, data: &[f32]) -> Result<()> {
        let mut d = dst.as_f32_mut()?;
        if d.len() != data.len() {
            return Err(anyhow!("write f32: {} elements into tensor of {}", data.len(), d.len()));
        }
        d.copy_from_slice(data);
        drop(d);
        self.bump(|s| {
            s.h2d_bytes += (data.len() * 4) as u64;
            s.h2d_transfers += 1;
        });
        Ok(())
    }

    fn write_i32(&self, dst: &RefTensor, data: &[i32]) -> Result<()> {
        let mut cell = dst
            .cell
            .try_borrow_mut()
            .map_err(|_| anyhow!("tensor is aliased by another borrow"))?;
        match &mut *cell {
            TensorData::I32 { data: d, .. } if d.len() == data.len() => d.copy_from_slice(data),
            TensorData::I32 { data: d, .. } => {
                return Err(anyhow!("write i32: {} elements into tensor of {}", data.len(), d.len()))
            }
            TensorData::F32 { .. } => return Err(anyhow!("write i32 into an f32 tensor")),
        }
        drop(cell);
        self.bump(|s| {
            s.h2d_bytes += (data.len() * 4) as u64;
            s.h2d_transfers += 1;
        });
        Ok(())
    }

    fn meta(&self, buf: &RefTensor) -> TensorMeta {
        buf.meta()
    }

    fn execute(&self, exe: &RefExe, args: &[&RefTensor]) -> Result<DeviceOutputs<RefTensor>> {
        let t0 = Stopwatch::start();
        let outputs = self.run(exe, args)?;
        Ok(DeviceOutputs { outputs, execute_s: t0.elapsed_s() })
    }

    fn read_f32(&self, buf: &RefTensor) -> Result<Vec<f32>> {
        let data = buf.as_f32()?.to_vec();
        self.bump(|s| {
            s.d2h_bytes += (data.len() * 4) as u64;
            s.d2h_transfers += 1;
        });
        Ok(data)
    }

    fn read_scalar_f32(&self, buf: &RefTensor) -> Result<f32> {
        let g = buf.as_f32()?;
        let x = g.first().copied().ok_or_else(|| anyhow!("read scalar from empty tensor"))?;
        drop(g);
        self.bump(|s| {
            s.d2h_bytes += 4;
            s.d2h_transfers += 1;
        });
        Ok(x)
    }

    fn supports_donation(&self) -> bool {
        // handles are RefCell-backed host vectors; in-place entries
        // genuinely mutate them
        true
    }

    fn transfer_stats(&self) -> TransferStats {
        self.stats.get()
    }

    fn audit_report(&self) -> Vec<String> {
        self.ws.borrow().audit_check()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exe_cache_dedups() {
        let b = ReferenceBackend::new();
        let a = b.load_shared_exe("adamw_update").unwrap();
        let c = b.load_shared_exe("adamw_update").unwrap();
        assert!(Rc::ptr_eq(&a, &c));
        let t1 = b.load_preset_exe("test-tiny", "train_step").unwrap();
        let t2 = b.load_preset_exe("test-tiny", "train_step").unwrap();
        assert!(Rc::ptr_eq(&t1, &t2));
    }

    #[test]
    fn unknown_entries_rejected() {
        let b = ReferenceBackend::new();
        assert!(b.load_preset_exe("test-tiny", "nope").is_err());
        assert!(b.load_preset_exe("no-such-preset", "train_step").is_err());
        assert!(b.load_shared_exe("nope").is_err());
        // pallas artifact exists only for the pallas presets
        assert!(b.load_preset_exe("test-tiny", "train_step_pallas").is_ok());
        assert!(b.load_preset_exe("e2e", "train_step_pallas").is_err());
    }

    #[test]
    fn manifest_arity_asserted_at_load() {
        // a manifest that lies about an entry's input count must be
        // rejected when the executable is loaded, not at execute time
        let mut m = Manifest::builtin();
        let preset = m.presets.get_mut("test-tiny").unwrap();
        preset.artifacts.get_mut("train_step").unwrap().n_inputs = 3;
        let b = ReferenceBackend::with_manifest(m);
        let err = b.load_preset_exe("test-tiny", "train_step").unwrap_err();
        assert!(format!("{err}").contains("declares 3 inputs"), "{err}");
    }

    #[test]
    fn grad_norm_sq_entry_matches_native() {
        let b = ReferenceBackend::new();
        let exe = b.load_shared_exe("grad_norm_sq").unwrap();
        let g = vec![2.0f32; 1000];
        let buf = b.upload_f32(&g, &[g.len()]).unwrap();
        let out = b.execute_to_host(&exe, &[&buf]).unwrap();
        let norm = out.scalar_f32(0).unwrap();
        assert!((norm - 4000.0).abs() < 1e-3, "{norm}");
    }

    #[test]
    fn workspace_reaches_steady_state_after_warmup() {
        let b = ReferenceBackend::new();
        let p = b.manifest().preset("test-tiny").unwrap().clone();
        let exe = b.load_preset_exe("test-tiny", "train_step").unwrap();
        let state = crate::model::ModelState::init(&p.blocks, 2);
        let blocks: Vec<_> =
            state.flats.iter().map(|f| b.upload_f32(f, &[f.len()]).unwrap()).collect();
        let (bb, ss) = (p.model.batch, p.model.seq_len);
        let tokens: Vec<i32> = (0..bb * ss).map(|i| 4 + (i % 40) as i32).collect();
        let tok = b.upload_i32(&tokens, &[bb, ss]).unwrap();
        let mut args: Vec<_> = blocks.iter().collect();
        args.push(&tok);
        args.push(&tok);
        let out0 = b.execute_to_host(&exe, &args).unwrap();
        let warm = b.workspace_stats();
        assert!(warm.high_water_bytes > 0);
        for _ in 0..3 {
            let out = b.execute_to_host(&exe, &args).unwrap();
            assert_eq!(out.outputs, out0.outputs, "arena reuse must stay bit-deterministic");
        }
        let steady = b.workspace_stats();
        assert_eq!(steady.grows, warm.grows, "steady-state steps must not allocate slabs");
        assert_eq!(steady.high_water_bytes, warm.high_water_bytes);
    }

    #[test]
    fn upload_validates_dims() {
        let b = ReferenceBackend::new();
        assert!(b.upload_i32(&[1, 2, 3], &[2, 2]).is_err());
        assert!(b.upload_i32(&[1, 2, 3, 4], &[2, 2]).is_ok());
        assert!(b.upload_f32(&[1.0; 3], &[4]).is_err());
        let t = b.upload_f32(&[1.0; 6], &[2, 3]).unwrap();
        let meta = b.meta(&t);
        assert_eq!(meta.dtype, DType::F32);
        assert_eq!(meta.dims, vec![2, 3]);
        assert_eq!(meta.bytes(), 24);
    }

    #[test]
    fn transfer_counters_observe_boundary_bytes() {
        let b = ReferenceBackend::new();
        let before = b.transfer_stats();
        let t = b.upload_f32(&[1.0; 8], &[8]).unwrap();
        let after_up = b.transfer_stats().delta_since(&before);
        assert_eq!(after_up.h2d_bytes, 32);
        assert_eq!(after_up.h2d_transfers, 1);
        assert_eq!(after_up.d2h_bytes, 0);

        let v = b.read_f32(&t).unwrap();
        assert_eq!(v, vec![1.0; 8]);
        let after_read = b.transfer_stats().delta_since(&before);
        assert_eq!(after_read.d2h_bytes, 32);

        b.write_f32(&t, &[2.0; 8]).unwrap();
        assert_eq!(b.read_scalar_f32(&t).unwrap(), 2.0);
        let fin = b.transfer_stats().delta_since(&before);
        assert_eq!(fin.h2d_bytes, 64);
        assert_eq!(fin.d2h_bytes, 36);
        // one tensor was ever allocated; the write reused it in place
        assert_eq!(fin.buffer_allocs, 1);
    }

    #[test]
    fn buffer_pool_recycles_dropped_tensors() {
        let b = ReferenceBackend::new();
        let t = b.upload_f32(&[1.0; 64], &[64]).unwrap();
        let one = b.transfer_stats().buffer_allocs;
        drop(t);
        // same-size upload after the drop must be a pool hit
        let t2 = b.upload_f32(&[3.0; 64], &[64]).unwrap();
        assert_eq!(b.transfer_stats().buffer_allocs, one, "freed buffer must be reused");
        assert_eq!(b.read_f32(&t2).unwrap(), vec![3.0; 64]);
        // a different size is a genuine new allocation
        let _t3 = b.upload_f32(&[0.0; 65], &[65]).unwrap();
        assert_eq!(b.transfer_stats().buffer_allocs, one + 1);
    }

    #[test]
    fn adamw_update_inplace_donates_buffers() {
        let b = ReferenceBackend::new();
        let exe = b.load_shared_exe("adamw_update_inplace").unwrap();
        let n = 32;
        let (p_host, g_host) = (vec![0.5f32; n], vec![0.1f32; n]);
        let zeros = vec![0.0f32; n];
        let p = b.upload_f32(&p_host, &[n]).unwrap();
        let g = b.upload_f32(&g_host, &[n]).unwrap();
        let m = b.upload_f32(&zeros, &[n]).unwrap();
        let v = b.upload_f32(&zeros, &[n]).unwrap();
        let t = b.upload_f32(&[0.0], &[1]).unwrap();
        let lr = b.upload_f32(&[1e-2], &[1]).unwrap();
        let scale = b.upload_f32(&[1.0], &[1]).unwrap();
        let out = b.execute(&exe, &[&p, &g, &m, &v, &t, &lr, &scale]).unwrap();
        assert!(out.outputs.is_empty(), "in-place entry returns no outputs");

        // native oracle over the same inputs
        let mut po = p_host;
        let mut mo = vec![0.0f32; n];
        let mut vo = vec![0.0f32; n];
        let hp = AdamWParams::from(b.manifest().adamw);
        fused_adamw(&mut po, &g_host, &mut mo, &mut vo, 1e-2, 1, hp);
        assert_eq!(b.read_f32(&p).unwrap(), po, "p updated in place");
        assert_eq!(b.read_f32(&m).unwrap(), mo, "m updated in place");
        assert_eq!(b.read_f32(&v).unwrap(), vo, "v updated in place");
        assert_eq!(b.read_scalar_f32(&t).unwrap(), 1.0, "step count advanced");

        // aliasing p and m is rejected, not silently corrupted
        assert!(b.execute(&exe, &[&p, &g, &p, &v, &t, &lr, &scale]).is_err());
    }
}
