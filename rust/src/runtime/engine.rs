//! PJRT engine: client + executable wrappers (cargo feature `pjrt`).
//!
//! Wraps the `xla` crate's PJRT CPU client: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute_b`.
//! Executables are compiled once at startup and cached by artifact name.
//!
//! The engine implements the handle-based [`Backend`] contract with
//! [`EngineTensor`]: a typed wrapper around a `PjRtBuffer` whose inner
//! buffer is swappable. PJRT buffers are immutable, so "in-place" writes
//! and donation are expressed functionally — a new device buffer is
//! created and swapped into the handle, which is exactly how XLA's
//! input→output aliasing behaves from the caller's perspective. Transfer
//! counters track every host↔device literal copy.
//!
//! One honest limitation of the vendored binding subset: `execute_b`
//! returns a single tuple buffer and the API exposes no on-device tuple
//! decomposition, so [`Backend::execute`] materializes the tuple on the
//! host and re-uploads per-output buffers (both directions counted). Real
//! bindings with untupled results would return output buffers directly.
//! Default builds use `runtime::ReferenceBackend` instead and never touch
//! this module; in offline CI the feature is type-checked against the
//! in-tree `rust/vendor/xla` stub.

use std::cell::{Cell, Ref, RefCell};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, XlaComputation};

use super::backend::{Backend, DType, DeviceOutputs, TensorMeta, TransferStats};
use super::manifest::Manifest;
use crate::telemetry::Stopwatch;

/// Typed device-tensor handle of the PJRT engine (see module docs for the
/// swap-based in-place semantics).
pub struct EngineTensor {
    buf: RefCell<PjRtBuffer>,
    dtype: DType,
    dims: Vec<usize>,
}

/// PJRT client + artifact directory + manifest + executable cache.
pub struct Engine {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Exe>>>,
    stats: Cell<TransferStats>,
}

impl Engine {
    /// Create a CPU PJRT client and read the manifest from `artifacts_dir`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: Cell::new(TransferStats::default()),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    fn bump(&self, f: impl FnOnce(&mut TransferStats)) {
        let mut s = self.stats.get();
        f(&mut s);
        self.stats.set(s);
    }

    /// Compile (or fetch from cache) the executable stored in `file`.
    pub fn load_exe(&self, file: &str) -> Result<Rc<Exe>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(file);
        let t0 = Stopwatch::start();
        let proto = HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {file}: {e}"))?;
        let exe = Rc::new(Exe {
            exe,
            name: file.to_string(),
            compile_s: t0.elapsed_s(),
        });
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    fn device_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32{dims:?}: {e}"))
    }
}

/// One compiled artifact.
pub struct Exe {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub compile_s: f64,
}

impl Exe {
    /// Execute with device-resident inputs, leave outputs on device.
    pub fn run_device(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let mut out = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("{}: execute_b: {e}", self.name))?;
        Ok(out.swap_remove(0))
    }
}

impl Backend for Engine {
    type Buffer = EngineTensor;
    type Exe = Exe;

    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load_preset_exe(&self, preset: &str, entry: &str) -> Result<Rc<Exe>> {
        let file = self.manifest.preset(preset)?.artifact(entry)?.file.clone();
        self.load_exe(&file)
    }

    fn load_shared_exe(&self, entry: &str) -> Result<Rc<Exe>> {
        let info = self
            .manifest
            .shared
            .get(entry)
            .ok_or_else(|| anyhow!("no shared artifact {entry:?}"))?;
        self.load_exe(&info.file)
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<EngineTensor> {
        let numel: usize = dims.iter().product();
        if numel != data.len() {
            return Err(anyhow!("upload f32: {} elements vs dims {dims:?}", data.len()));
        }
        let buf = self.device_f32(data, dims)?;
        self.bump(|s| {
            s.h2d_bytes += (data.len() * 4) as u64;
            s.h2d_transfers += 1;
            s.buffer_allocs += 1;
            s.buffer_alloc_bytes += (data.len() * 4) as u64;
        });
        Ok(EngineTensor { buf: RefCell::new(buf), dtype: DType::F32, dims: dims.to_vec() })
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<EngineTensor> {
        let numel: usize = dims.iter().product();
        if numel != data.len() {
            return Err(anyhow!("upload i32: {} elements vs dims {dims:?}", data.len()));
        }
        let buf = self
            .client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32{dims:?}: {e}"))?;
        self.bump(|s| {
            s.h2d_bytes += (data.len() * 4) as u64;
            s.h2d_transfers += 1;
            s.buffer_allocs += 1;
            s.buffer_alloc_bytes += (data.len() * 4) as u64;
        });
        Ok(EngineTensor { buf: RefCell::new(buf), dtype: DType::I32, dims: dims.to_vec() })
    }

    fn write_f32(&self, dst: &EngineTensor, data: &[f32]) -> Result<()> {
        let numel: usize = dst.dims.iter().product();
        if dst.dtype != DType::F32 {
            return Err(anyhow!("write f32 into an i32 tensor"));
        }
        if numel != data.len() {
            return Err(anyhow!("write f32: {} elements into tensor of {numel}", data.len()));
        }
        // PJRT buffers are immutable: swap a fresh device buffer into the
        // handle (every clone of the handle observes the new contents).
        *dst.buf.borrow_mut() = self.device_f32(data, &dst.dims)?;
        self.bump(|s| {
            s.h2d_bytes += (data.len() * 4) as u64;
            s.h2d_transfers += 1;
        });
        Ok(())
    }

    fn write_i32(&self, dst: &EngineTensor, data: &[i32]) -> Result<()> {
        let numel: usize = dst.dims.iter().product();
        if dst.dtype != DType::I32 {
            return Err(anyhow!("write i32 into an f32 tensor"));
        }
        if numel != data.len() {
            return Err(anyhow!("write i32: {} elements into tensor of {numel}", data.len()));
        }
        let buf = self
            .client
            .buffer_from_host_buffer(data, &dst.dims, None)
            .map_err(|e| anyhow!("upload i32{:?}: {e}", dst.dims))?;
        *dst.buf.borrow_mut() = buf;
        self.bump(|s| {
            s.h2d_bytes += (data.len() * 4) as u64;
            s.h2d_transfers += 1;
        });
        Ok(())
    }

    fn meta(&self, buf: &EngineTensor) -> TensorMeta {
        TensorMeta { dtype: buf.dtype, dims: buf.dims.clone() }
    }

    /// Execute and wrap each output in a fresh handle.
    ///
    /// The AOT path lowers with `return_tuple=True`, so the computation
    /// has a single tuple output; the vendored binding subset can only
    /// decompose it through a host literal, so elements round-trip (the
    /// traffic is counted — see the module docs).
    fn execute(&self, exe: &Exe, args: &[&EngineTensor]) -> Result<DeviceOutputs<EngineTensor>> {
        let guards: Vec<Ref<'_, PjRtBuffer>> = args.iter().map(|a| a.buf.borrow()).collect();
        let refs: Vec<&PjRtBuffer> = guards.iter().map(|g| &**g).collect();
        let t0 = Stopwatch::start();
        let out = exe.run_device(&refs)?;
        drop(guards);
        let execute_s = t0.elapsed_s();

        let root = out[0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: to_literal: {e}", exe.name))?;
        let literals = root
            .to_tuple()
            .map_err(|e| anyhow!("{}: decompose tuple: {e}", exe.name))?;
        let outputs: Vec<EngineTensor> = literals
            .iter()
            .enumerate()
            .map(|(i, lit)| {
                let host = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("{}: output {i} as f32 vec: {e}", exe.name))?;
                self.bump(|s| {
                    s.d2h_bytes += (host.len() * 4) as u64;
                    s.d2h_transfers += 1;
                });
                self.upload_f32(&host, &[host.len()])
            })
            .collect::<Result<_>>()?;
        Ok(DeviceOutputs { outputs, execute_s })
    }

    fn read_f32(&self, buf: &EngineTensor) -> Result<Vec<f32>> {
        if buf.dtype != DType::F32 {
            return Err(anyhow!("read_f32 on an i32 tensor"));
        }
        let lit = buf
            .buf
            .borrow()
            .to_literal_sync()
            .map_err(|e| anyhow!("read f32: to_literal: {e}"))?;
        let host = lit.to_vec::<f32>().map_err(|e| anyhow!("read f32: {e}"))?;
        self.bump(|s| {
            s.d2h_bytes += (host.len() * 4) as u64;
            s.d2h_transfers += 1;
        });
        Ok(host)
    }

    fn read_scalar_f32(&self, buf: &EngineTensor) -> Result<f32> {
        // the binding subset has no partial reads: the whole tensor
        // crosses, and the accounting says so
        self.read_f32(buf)?
            .first()
            .copied()
            .ok_or_else(|| anyhow!("read scalar from empty tensor"))
    }

    fn supports_donation(&self) -> bool {
        // execute() returns fresh handles and never swaps donated
        // argument handles — an in-place entry run here would silently
        // discard its updates, so the trainer must not pick the
        // device-resident mode on this engine until real bindings land
        // input→output aliasing (write_f32's swap covers host writes
        // only, not executable outputs).
        false
    }

    fn transfer_stats(&self) -> TransferStats {
        self.stats.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    // These exercise the real PJRT runtime and need `make artifacts` plus
    // an `xla` crate with actual bindings behind it; the in-tree stub
    // returns Unavailable, so they are ignored by default.
    #[test]
    #[ignore = "requires PJRT runtime + AOT artifacts"]
    fn engine_loads_and_compiles_shared() {
        let e = Engine::load(artifacts()).unwrap();
        assert_eq!(e.platform(), "cpu");
        let exe = e.load_shared_exe("grad_norm_sq").unwrap();
        let n = e.manifest.chunk_size;
        let g = vec![2.0f32; n];
        let buf = e.upload_f32(&g, &[n]).unwrap();
        let out = e.execute_to_host(&exe, &[&buf]).unwrap();
        let norm = out.scalar_f32(0).unwrap();
        assert!((norm - 4.0 * n as f32).abs() / (4.0 * n as f32) < 1e-5);
    }

    #[test]
    #[ignore = "requires PJRT runtime + AOT artifacts"]
    fn exe_cache_dedups() {
        let e = Engine::load(artifacts()).unwrap();
        let a = e.load_shared_exe("adamw_update").unwrap();
        let b = e.load_shared_exe("adamw_update").unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }
}
