//! PJRT engine: client + executable wrappers.
//!
//! Wraps the `xla` crate's PJRT CPU client: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute_b`.
//! Executables are compiled once at startup and cached by artifact name;
//! parameters live on the device as `PjRtBuffer`s between steps so the hot
//! loop only re-uploads the *blocks the optimizer actually touched* — the
//! device-side mirror of the paper's selective-update data movement.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, XlaComputation};

use super::manifest::Manifest;

/// PJRT client + artifact directory + manifest + executable cache.
pub struct Engine {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, std::rc::Rc<Exe>>>,
}

impl Engine {
    /// Create a CPU PJRT client and read the manifest from `artifacts_dir`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable stored in `file`.
    pub fn load_exe(&self, file: &str) -> Result<std::rc::Rc<Exe>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(file);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {file}: {e}"))?;
        let exe = std::rc::Rc::new(Exe {
            exe,
            name: file.to_string(),
            compile_s: t0.elapsed().as_secs_f64(),
        });
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Load the executable for a preset entrypoint (e.g. `"train_step"`).
    pub fn load_preset_exe(&self, preset: &str, entry: &str) -> Result<std::rc::Rc<Exe>> {
        let file = self.manifest.preset(preset)?.artifact(entry)?.file.clone();
        self.load_exe(&file)
    }

    /// Load a shared (preset-independent) executable, e.g. `"adamw_update"`.
    pub fn load_shared_exe(&self, entry: &str) -> Result<std::rc::Rc<Exe>> {
        let info = self
            .manifest
            .shared
            .get(entry)
            .ok_or_else(|| anyhow!("no shared artifact {entry:?}"))?;
        self.load_exe(&info.file)
    }

    /// Upload a flat f32 vector to the device.
    pub fn upload_f32(&self, data: &[f32]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, &[data.len()], None)
            .map_err(|e| anyhow!("upload f32[{}]: {e}", data.len()))
    }

    /// Upload an i32 matrix (row-major) of shape `dims`.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32{dims:?}: {e}"))
    }
}

/// One compiled artifact. `run` returns the decomposed output tuple.
pub struct Exe {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub compile_s: f64,
}

/// Host-side copy of an executable's output tuple.
pub struct HostOutputs {
    pub literals: Vec<Literal>,
    /// Wallclock of the execute call (device compute + sync).
    pub execute_s: f64,
    /// Wallclock of the device→host copy of the outputs.
    pub download_s: f64,
}

impl HostOutputs {
    pub fn scalar_f32(&self, idx: usize) -> Result<f32> {
        self.literals[idx]
            .to_vec::<f32>()
            .map(|v| v[0])
            .map_err(|e| anyhow!("output {idx} as f32 scalar: {e}"))
    }

    pub fn vec_f32(&self, idx: usize) -> Result<Vec<f32>> {
        self.literals[idx]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("output {idx} as f32 vec: {e}"))
    }
}

impl Exe {
    /// Execute with device-resident inputs, leave outputs on device.
    pub fn run_device(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let mut out = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("{}: execute_b: {e}", self.name))?;
        Ok(out.swap_remove(0))
    }

    /// Execute and copy the whole output tuple back to the host.
    ///
    /// The AOT path lowers with `return_tuple=True`, so the computation has
    /// a single tuple output which we decompose into per-element literals.
    pub fn run(&self, args: &[&PjRtBuffer]) -> Result<HostOutputs> {
        let t0 = Instant::now();
        let out = self.run_device(args)?;
        let execute_s = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let root = out[0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: to_literal: {e}", self.name))?;
        let literals = root
            .to_tuple()
            .map_err(|e| anyhow!("{}: decompose tuple: {e}", self.name))?;
        Ok(HostOutputs { literals, execute_s, download_s: t1.elapsed().as_secs_f64() })
    }

    /// Execute with literal (host) inputs — convenience for tests/benches.
    pub fn run_literals(&self, args: &[Literal]) -> Result<HostOutputs> {
        let t0 = Instant::now();
        let mut out = self
            .exe
            .execute::<Literal>(args)
            .map_err(|e| anyhow!("{}: execute: {e}", self.name))?;
        let execute_s = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let root = out
            .swap_remove(0)
            .swap_remove(0)
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: to_literal: {e}", self.name))?;
        let literals = root
            .to_tuple()
            .map_err(|e| anyhow!("{}: decompose tuple: {e}", self.name))?;
        Ok(HostOutputs { literals, execute_s, download_s: t1.elapsed().as_secs_f64() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn engine_loads_and_compiles_shared() {
        let e = Engine::load(artifacts()).unwrap();
        assert_eq!(e.platform(), "cpu");
        let exe = e.load_shared_exe("grad_norm_sq").unwrap();
        let n = e.manifest.chunk_size;
        let g = vec![2.0f32; n];
        let buf = e.upload_f32(&g).unwrap();
        let out = exe.run(&[&buf]).unwrap();
        let norm = out.vec_f32(0).unwrap()[0];
        assert!((norm - 4.0 * n as f32).abs() / (4.0 * n as f32) < 1e-5);
    }

    #[test]
    fn exe_cache_dedups() {
        let e = Engine::load(artifacts()).unwrap();
        let a = e.load_shared_exe("adamw_update").unwrap();
        let b = e.load_shared_exe("adamw_update").unwrap();
        assert!(std::rc::Rc::ptr_eq(&a, &b));
    }
}
