//! PJRT engine: client + executable wrappers (cargo feature `pjrt`).
//!
//! Wraps the `xla` crate's PJRT CPU client: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute_b`.
//! Executables are compiled once at startup and cached by artifact name;
//! parameters live on the device as `PjRtBuffer`s between steps so the hot
//! loop only re-uploads the *blocks the optimizer actually touched* — the
//! device-side mirror of the paper's selective-update data movement.
//!
//! Default builds use `runtime::ReferenceBackend` instead and never touch
//! this module; in offline CI the feature is type-checked against the
//! in-tree `rust/vendor/xla` stub.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, XlaComputation};

use super::backend::{Backend, HostOutputs};
use super::manifest::Manifest;

/// PJRT client + artifact directory + manifest + executable cache.
pub struct Engine {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Exe>>>,
}

impl Engine {
    /// Create a CPU PJRT client and read the manifest from `artifacts_dir`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Compile (or fetch from cache) the executable stored in `file`.
    pub fn load_exe(&self, file: &str) -> Result<Rc<Exe>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(file);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {file}: {e}"))?;
        let exe = Rc::new(Exe {
            exe,
            name: file.to_string(),
            compile_s: t0.elapsed().as_secs_f64(),
        });
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }
}

/// One compiled artifact.
pub struct Exe {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub compile_s: f64,
}

impl Exe {
    /// Execute with device-resident inputs, leave outputs on device.
    pub fn run_device(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let mut out = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("{}: execute_b: {e}", self.name))?;
        Ok(out.swap_remove(0))
    }
}

impl Backend for Engine {
    type Buffer = PjRtBuffer;
    type Exe = Exe;

    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load_preset_exe(&self, preset: &str, entry: &str) -> Result<Rc<Exe>> {
        let file = self.manifest.preset(preset)?.artifact(entry)?.file.clone();
        self.load_exe(&file)
    }

    fn load_shared_exe(&self, entry: &str) -> Result<Rc<Exe>> {
        let info = self
            .manifest
            .shared
            .get(entry)
            .ok_or_else(|| anyhow!("no shared artifact {entry:?}"))?;
        self.load_exe(&info.file)
    }

    fn upload_f32(&self, data: &[f32]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, &[data.len()], None)
            .map_err(|e| anyhow!("upload f32[{}]: {e}", data.len()))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32{dims:?}: {e}"))
    }

    /// Execute and copy the whole output tuple back to the host.
    ///
    /// The AOT path lowers with `return_tuple=True`, so the computation has
    /// a single tuple output which is decomposed into per-element vectors.
    fn execute(&self, exe: &Exe, args: &[&PjRtBuffer]) -> Result<HostOutputs> {
        let t0 = Instant::now();
        let out = exe.run_device(args)?;
        let execute_s = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let root = out[0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: to_literal: {e}", exe.name))?;
        let literals = root
            .to_tuple()
            .map_err(|e| anyhow!("{}: decompose tuple: {e}", exe.name))?;
        let outputs: Vec<Vec<f32>> = literals
            .iter()
            .enumerate()
            .map(|(i, lit)| {
                lit.to_vec::<f32>()
                    .map_err(|e| anyhow!("{}: output {i} as f32 vec: {e}", exe.name))
            })
            .collect::<Result<_>>()?;
        Ok(HostOutputs::new(outputs, execute_s, t1.elapsed().as_secs_f64()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    // These exercise the real PJRT runtime and need `make artifacts` plus
    // an `xla` crate with actual bindings behind it; the in-tree stub
    // returns Unavailable, so they are ignored by default.
    #[test]
    #[ignore = "requires PJRT runtime + AOT artifacts"]
    fn engine_loads_and_compiles_shared() {
        let e = Engine::load(artifacts()).unwrap();
        assert_eq!(e.platform(), "cpu");
        let exe = e.load_shared_exe("grad_norm_sq").unwrap();
        let n = e.manifest.chunk_size;
        let g = vec![2.0f32; n];
        let buf = e.upload_f32(&g).unwrap();
        let out = e.execute(&exe, &[&buf]).unwrap();
        let norm = out.scalar_f32(0).unwrap();
        assert!((norm - 4.0 * n as f32).abs() / (4.0 * n as f32) < 1e-5);
    }

    #[test]
    #[ignore = "requires PJRT runtime + AOT artifacts"]
    fn exe_cache_dedups() {
        let e = Engine::load(artifacts()).unwrap();
        let a = e.load_shared_exe("adamw_update").unwrap();
        let b = e.load_shared_exe("adamw_update").unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }
}
