//! # AdaGradSelect
//!
//! Production-oriented reproduction of *"AdaGradSelect: An adaptive
//! gradient-guided layer selection method for efficient fine-tuning of
//! SLMs"* as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: training loop, the
//!   AdaGradSelect bandit (Dirichlet exploitation + ε-greedy exploration),
//!   the custom selective AdamW with CPU↔GPU optimizer-state residency
//!   management, data pipeline, eval harness, memory accounting, and the
//!   experiment harness that regenerates every table/figure in the paper.
//! * **L2 (python/compile, build-time only)** — the transformer fwd/bwd as
//!   JAX, lowered once to HLO text (`make artifacts`).
//! * **L1 (python/compile/kernels)** — Pallas kernels for the compute
//!   hot-spots (flash attention, fused AdamW, grad-norm reduction).
//!
//! Python never runs on the training path: the binary loads
//! `artifacts/*.hlo.txt` through PJRT (`runtime`) and is self-contained.

pub mod config;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod lora;
pub mod memory;
pub mod model;
pub mod optimizer;
pub mod runtime;
pub mod selection;
pub mod telemetry;
pub mod train;
pub mod util;

pub use anyhow::{anyhow, Context, Result};

/// Lightweight stderr logger (the offline environment has no `tracing`).
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if std::env::var_os("AGSEL_QUIET").is_none() {
            eprintln!("[agsel] {}", format!($($arg)*));
        }
    };
}

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::config::{Method, RunConfig};
    pub use crate::data::{MathGen, Split, Tokenizer};
    pub use crate::eval::Evaluator;
    pub use crate::model::ModelState;
    pub use crate::runtime::Engine;
    pub use crate::selection::SelectionStrategy;
    pub use crate::train::{Trainer, TrainSummary};
    pub use crate::Result;
}
