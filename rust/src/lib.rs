//! # AdaGradSelect
//!
//! Production-oriented reproduction of *"AdaGradSelect: An adaptive
//! gradient-guided layer selection method for efficient fine-tuning of
//! SLMs"* with a pluggable compute backend:
//!
//! * **Coordinator (this crate)** — the training loop, the AdaGradSelect
//!   bandit (Dirichlet exploitation + ε-greedy exploration), the custom
//!   selective AdamW with CPU↔GPU optimizer-state residency management,
//!   data pipeline, eval harness, memory accounting, the KV-cached
//!   serving engine with a continuous-batching scheduler ([`serve`]), and
//!   the experiment harness that regenerates every table/figure in the
//!   paper.
//! * **[`runtime::ReferenceBackend`] (default)** — a pure-Rust CPU
//!   executor: native transformer fwd/bwd ([`model::forward`]) over the
//!   built-in preset catalog. Builds, trains and is verified everywhere —
//!   no Python, no artifacts, no external crates.
//! * **`runtime::Engine` (cargo feature `pjrt`)** — the PJRT path that
//!   loads HLO-text artifacts lowered once from the JAX/Pallas side
//!   (`python/compile`, `make artifacts`) through the `xla` crate.
//!
//! Both backends implement [`runtime::Backend`]; everything above them is
//! generic, and the backend-parity test suite holds the reference
//! executor to the JAX-derived golden trajectories.

// Every unsafe operation must sit in its own `unsafe { }` block with its
// own SAFETY argument, even inside `unsafe fn` — enforced here and by
// `scripts/lint_repo.py` (which requires the SAFETY comments themselves).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod audit;
pub mod config;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod lora;
pub mod memory;
pub mod model;
pub mod optimizer;
pub mod runtime;
pub mod selection;
pub mod serve;
pub mod telemetry;
pub mod train;
pub mod util;

pub use anyhow::{anyhow, Context, Result};

/// Lightweight stderr logger (the offline environment has no `tracing`).
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if std::env::var_os("AGSEL_QUIET").is_none() {
            eprintln!("[agsel] {}", format!($($arg)*));
        }
    };
}

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::config::{Method, RunConfig};
    pub use crate::data::{MathGen, Split, Tokenizer};
    pub use crate::eval::Evaluator;
    pub use crate::model::ModelState;
    #[cfg(feature = "pjrt")]
    pub use crate::runtime::Engine;
    pub use crate::runtime::{Backend, ReferenceBackend};
    pub use crate::selection::SelectionStrategy;
    pub use crate::serve::{KvBackend, ServeConfig, ServeEngine};
    pub use crate::train::{Trainer, TrainSummary};
    pub use crate::Result;
}
