//! LoRA coordinator support: adapter merging for evaluation.
//!
//! Training happens through the `train_step_lora*` entrypoints (base
//! frozen, adapter grads only). At eval time the adapters are folded into
//! the base weights — `W' = W + (α/r)·A·B` — via the per-layer
//! `lora_merge*` entrypoint, after which the plain `decode_step` serves
//! the merged model. This mirrors deployment practice (merge-then-serve)
//! and keeps a single decode path for every method and backend.

use anyhow::Result;

use crate::model::ModelState;
use crate::runtime::Backend;

/// Merge LoRA adapters into a copy of the base state.
///
/// `base` is the full block table (embed | layers | head); `lora` has one
/// adapter block per transformer layer. Only layer blocks change.
pub fn merge<B: Backend>(
    engine: &B,
    preset_name: &str,
    base: &ModelState,
    lora: &ModelState,
    double_rank: bool,
) -> Result<ModelState> {
    let preset = engine.manifest().preset(preset_name)?;
    let n_layers = preset.model.n_layers;
    let entry = if double_rank { "lora_merge2" } else { "lora_merge" };
    let exe = engine.load_preset_exe(preset_name, entry)?;

    let mut merged = base.clone();
    for layer in 0..n_layers {
        let block_idx = 1 + layer; // blocks: embed | layer0.. | head
        let bf = &base.flats[block_idx];
        let lf = &lora.flats[layer];
        let base_buf = engine.upload_f32(bf, &[bf.len()])?;
        let lora_buf = engine.upload_f32(lf, &[lf.len()])?;
        // one output handle; the merged block is read back explicitly
        let out = engine.execute(&exe, &[&base_buf, &lora_buf])?;
        merged.flats[block_idx] = engine.read_f32(&out.outputs[0])?;
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ReferenceBackend;

    #[test]
    fn merge_with_zero_b_is_identity() {
        let engine = ReferenceBackend::new();
        let preset = engine.manifest().preset("test-tiny").unwrap().clone();
        let base = ModelState::init(&preset.blocks, 1);
        // fresh adapters have B = 0 => merge must be a no-op
        let lora = ModelState::init(&preset.lora_blocks, 2);
        let merged = merge(&engine, "test-tiny", &base, &lora, false).unwrap();
        for (a, b) in base.flats.iter().zip(&merged.flats) {
            let max = a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(max < 1e-6, "max {max}");
        }
    }

    #[test]
    fn merge_with_nonzero_b_changes_layers_only() {
        let engine = ReferenceBackend::new();
        let preset = engine.manifest().preset("test-tiny").unwrap().clone();
        let base = ModelState::init(&preset.blocks, 1);
        let mut lora = ModelState::init(&preset.lora_blocks, 2);
        for f in lora.flats.iter_mut() {
            for x in f.iter_mut() {
                *x = 0.01; // make B nonzero
            }
        }
        let merged = merge(&engine, "test-tiny", &base, &lora, false).unwrap();
        // embed + head unchanged
        assert_eq!(base.flats[0], merged.flats[0]);
        assert_eq!(base.flats.last(), merged.flats.last());
        // layers changed
        assert_ne!(base.flats[1], merged.flats[1]);
    }
}
