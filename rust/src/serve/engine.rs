//! The continuous-batching serving engine.
//!
//! One [`ServeEngine`] owns the uploaded model weights, a paged [`KvPool`]
//! shared by all resident sequences, a [`PrefixCache`] of reusable prompt
//! stems, and a [`Scheduler`] request queue. Every [`ServeEngine::step`]
//! is one **mixed iteration**:
//!
//! 1. **Admission** — arrived prompts whose page demand fits the
//!    remaining page budget are admitted (highest priority first, then
//!    shortest job, see [`Scheduler::admit`]); each admitted prompt
//!    attaches any cached prefix pages (copy-on-write at the divergence
//!    page), then runs one
//!    [`prefill`](crate::model::forward::prefill_in) over the *uncovered
//!    suffix only* (filling its cache and producing its first token —
//!    TTFT ends here). A **resumed** (previously preempted) request
//!    re-feeds its prompt *plus* its already-generated tokens the same
//!    way, picking up the sampling stream at its step index, so its
//!    final output is bit-identical to an uninterrupted run;
//! 2. **Decode** — all active sequences advance by exactly one token via a
//!    single batched [`decode_step_kv`](crate::model::forward::decode_step_kv_in)
//!    call, mapping fresh pages on demand as they cross page boundaries;
//!    finished sequences release their slot and exclusive pages
//!    immediately, so the next iteration's admission can reuse them
//!    mid-stream.
//!
//! Requests therefore join and leave the batch continuously — no padding
//! to a preset batch size and no head-of-batch stragglers burning compute
//! for finished rows. Per-row kernel results are independent of
//! batch-mates, so each request's token stream is identical to what a
//! dedicated single-sequence decode (or the full-reforward oracle) would
//! produce, regardless of arrival interleaving. Sampled requests
//! ([`SamplingParams`], via [`ServeEngine::submit_sampled`]) keep the
//! same property: each draw depends only on the request's seed and step
//! index, so sampled output is bit-reproducible across batch
//! compositions too — including across preemptions, since a resumed
//! sequence re-enters the per-step `seed ^ splitmix(g)` stream at the
//! same `g`.
//!
//! Memory safety of admission is a policy choice ([`Reservation`]):
//!
//! * **Worst case** — a request is only admitted when `free pages +
//!   cache-evictable pages` cover its worst-case demand **plus** the
//!   worst-case remaining growth of everything already active, so a
//!   mid-decode page fault cannot happen. Safe but pessimistic: one
//!   long-tail request pins pages it may never touch while the queue
//!   waits.
//! * **Optimistic** (the default) — admission reserves only each active
//!   sequence's *next decode row*; pages are claimed just in time as
//!   sequences actually grow. When a decode step cannot map its next
//!   page even after evicting the prefix cache, the **preemption
//!   backstop** picks a victim (lowest priority, then most exclusive
//!   pages — frees the most memory — then fewest cached tokens to
//!   rebuild), parks its full pages in the prefix cache, releases its
//!   slot, and requeues it for later resumption. The pool is floored at
//!   one full-context sequence, so the backstop can always make the
//!   failing sequence fit by shrinking the active set — no out-of-pages
//!   deadlock.
//!
//! With the default worst-case pool ([`ServeConfig::kv_pages`] = 0)
//! optimistic admission never needs the backstop; overcommitting the pool
//! (`kv_pages` below `slots × pages-per-sequence`) trades preemption work
//! for strictly less memory.
//!
//! The engine clock is wallclock-based but skips idle gaps: when nothing
//! is active and the next arrival is in the future, the clock
//! fast-forwards instead of sleeping, so open-loop (Poisson) arrival
//! traces replay at full speed while latency accounting stays faithful.

use std::rc::Rc;

use anyhow::{anyhow, Result};

use crate::model::ModelState;
use crate::runtime::Preset;
use crate::telemetry::{CounterId, GaugeId, HistId, SpanId, Stopwatch, Telemetry};

use super::kv::KvPool;
use super::prefix::PrefixCache;
use super::sampling::{sample_token, stop_len, SamplingParams};
use super::scheduler::{Request, Scheduler};
use super::{greedy_step, KvBackend};

/// Preemption counters are labeled by priority tier; tiers at or above
/// this land in the last (`"7+"`) bucket so the label set — and thus the
/// registry — stays fixed at construction.
const N_PRIORITY_TIERS: usize = 8;

/// How admission accounts for pages not yet written.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Reservation {
    /// Reserve only each active sequence's next decode row; a mid-decode
    /// page shortfall preempts a victim instead of having been prevented
    /// up front (the default).
    #[default]
    Optimistic,
    /// Reserve every request's worst-case remaining growth at admission;
    /// never preempts, at the cost of idle reserved pages.
    WorstCase,
}

/// Engine construction knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Concurrently resident sequences (KV slots).
    pub slots: usize,
    /// Per-request generation cap when `submit` is given `0`.
    pub max_new_tokens: usize,
    /// KV pages to provision; `0` means the `slots × full-context` worst
    /// case (in-use bytes always track actual cached tokens). Smaller
    /// values overcommit the pool — admission then leans on preemption
    /// under pressure. Floored at one full-context sequence.
    pub kv_pages: usize,
    /// Page-reservation policy for admission (see [`Reservation`]).
    pub reservation: Reservation,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            slots: 1,
            max_new_tokens: 16,
            kv_pages: 0,
            reservation: Reservation::Optimistic,
        }
    }
}

/// One finished request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Generated token ids (prompt, EOS and matched stop sequences
    /// excluded) — for greedy requests, token-for-token what the
    /// full-reforward oracle would produce.
    pub tokens: Vec<i32>,
    pub n_prompt: usize,
    /// Prompt was empty or longer than the KV capacity: rejected at
    /// admission, nothing was generated (the `n_truncated` signal).
    pub truncated: bool,
    pub arrival_s: f64,
    /// Engine-clock time the first token (or the rejection) was produced.
    /// Stamped at the *first* emission only — a preemption and requeue
    /// never resets it, so TTFT reflects the original first token.
    pub first_token_s: f64,
    pub finish_s: f64,
    /// Times this request was preempted and later resumed.
    pub n_preemptions: u32,
}

impl Response {
    /// Time to first token.
    pub fn ttft_s(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    /// End-to-end request latency.
    pub fn latency_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
}

/// Aggregate engine counters (monotone over the engine's lifetime).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    pub n_prefills: u64,
    /// Prompt tokens actually run through prefill (prefix-cache hits
    /// excluded — the savings show up here).
    pub prefill_tokens: usize,
    pub prefill_s: f64,
    pub decode_steps: u64,
    /// Sequence-steps summed over all batched decode calls (= generated
    /// tokens sampled through the decode path).
    pub decode_tokens: usize,
    pub decode_s: f64,
    /// KV backing-store bytes provisioned at construction (the
    /// slot-model worst case; see `kv_peak_bytes` for measured use).
    pub kv_bytes: usize,
    /// Peak bytes of KV pages actually in use.
    pub kv_peak_bytes: usize,
    /// Prompt tokens served from the prefix cache instead of prefill.
    pub prefix_hit_tokens: usize,
    /// Copy-on-write page forks performed.
    pub cow_copies: u64,
    /// Fresh KV pages claimed (monotone; flat while every active
    /// sequence decodes within its last page).
    pub pages_allocated: u64,
    pub peak_active: usize,
    /// Running sequences preempted by the page backstop (each one was
    /// requeued and later resumed).
    pub n_preemptions: u64,
    /// Cached tokens released by preemptions — the work at risk; resumes
    /// recover it from the prefix cache or re-prefill it.
    pub preempted_tokens: usize,
}

struct ActiveSeq {
    id: u64,
    slot: usize,
    last: i32,
    generated: Vec<i32>,
    /// The original prompt, kept so a preemption can requeue the request.
    prompt: Vec<i32>,
    n_prompt: usize,
    max_new: usize,
    arrival_s: f64,
    first_token_s: f64,
    /// Engine-clock time of the latest emission, for the inter-token
    /// latency histogram (reset on resume, so ITL stays a pure decode-
    /// cadence metric and requeue waits show up in queue-wait instead).
    last_emit_s: f64,
    params: SamplingParams,
    /// Pages this sequence may ever need (worst-case admission reserves
    /// them; optimistic admission only consults them for diagnostics).
    worst_pages: usize,
    priority: u8,
    n_preemptions: u32,
}

/// Registered metric/span handles for the serve engine (all ids, cheap
/// to copy; the values live in the engine's [`Telemetry`] registry).
#[derive(Clone, Copy)]
struct ServeMetrics {
    admissions: CounterId,
    rejected: CounterId,
    requeues: CounterId,
    finished: CounterId,
    preemptions_by_tier: [CounterId; N_PRIORITY_TIERS],
    preempted_tokens: CounterId,
    prefills: CounterId,
    prefill_tokens: CounterId,
    decode_steps: CounterId,
    decode_tokens: CounterId,
    prefix_hit_tokens: CounterId,
    prefix_miss_tokens: CounterId,
    pages_allocated: CounterId,
    cow_copies: CounterId,
    prefix_evictions: CounterId,
    active: GaugeId,
    pending: GaugeId,
    free_pages: GaugeId,
    kv_bytes_in_use: GaugeId,
    ttft: HistId,
    itl: HistId,
    queue_wait: HistId,
    latency: HistId,
    sp_step: SpanId,
    sp_admission: SpanId,
    sp_prefill: SpanId,
    sp_decode: SpanId,
}

impl ServeMetrics {
    fn register(tel: &mut Telemetry) -> Self {
        let r = &mut tel.registry;
        let admissions = r.counter("serve_admissions_total");
        let rejected = r.counter("serve_rejected_total");
        let requeues = r.counter("serve_requeues_total");
        let finished = r.counter("serve_finished_total");
        let preemptions_by_tier = std::array::from_fn(|i| {
            let label =
                if i == N_PRIORITY_TIERS - 1 { format!("{i}+") } else { i.to_string() };
            r.counter_with("serve_preemptions_total", &[("tier", &label)])
        });
        Self {
            admissions,
            rejected,
            requeues,
            finished,
            preemptions_by_tier,
            preempted_tokens: r.counter("serve_preempted_tokens_total"),
            prefills: r.counter("serve_prefills_total"),
            prefill_tokens: r.counter("serve_prefill_tokens_total"),
            decode_steps: r.counter("serve_decode_steps_total"),
            decode_tokens: r.counter("serve_decode_tokens_total"),
            prefix_hit_tokens: r.counter("serve_prefix_hit_tokens_total"),
            prefix_miss_tokens: r.counter("serve_prefix_miss_tokens_total"),
            pages_allocated: r.counter("serve_kv_pages_allocated_total"),
            cow_copies: r.counter("serve_kv_cow_copies_total"),
            prefix_evictions: r.counter("serve_prefix_evictions_total"),
            active: r.gauge("serve_active_sequences"),
            pending: r.gauge("serve_pending_requests"),
            free_pages: r.gauge("serve_kv_free_pages"),
            kv_bytes_in_use: r.gauge("serve_kv_bytes_in_use"),
            ttft: r.histogram("serve_ttft_seconds"),
            itl: r.histogram("serve_itl_seconds"),
            queue_wait: r.histogram("serve_queue_wait_seconds"),
            latency: r.histogram("serve_latency_seconds"),
            sp_step: tel.tracer.register("serve/step"),
            sp_admission: tel.tracer.register("serve/admission"),
            sp_prefill: tel.tracer.register("serve/prefill"),
            sp_decode: tel.tracer.register("serve/decode_step"),
        }
    }
}

/// Pool/cache-internal monotone counters already mirrored into the
/// registry — [`ServeEngine::sync_registry`] adds only the per-step
/// delta so registry counters stay monotone too.
#[derive(Debug, Clone, Copy, Default)]
struct SyncedPoolCounters {
    pages_allocated: u64,
    cow_copies: u64,
    prefix_evictions: u64,
}

/// KV-cached continuous-batching engine over any [`KvBackend`].
pub struct ServeEngine<'e, B: KvBackend> {
    backend: &'e B,
    preset: Preset,
    blocks: Vec<B::Buffer>,
    pool: KvPool,
    cache: PrefixCache,
    sched: Scheduler,
    active: Vec<ActiveSeq>,
    reservation: Reservation,
    max_new_default: usize,
    eos: i32,
    t0: Stopwatch,
    skip_s: f64,
    stats: ServeStats,
    /// Shared so RAII span guards can borrow the hub while `&mut self`
    /// methods (preemption, pool mutation) run inside the span.
    tel: Rc<Telemetry>,
    m: ServeMetrics,
    synced: SyncedPoolCounters,
}

impl<'e, B: KvBackend> ServeEngine<'e, B> {
    pub fn new(
        backend: &'e B,
        preset_name: &str,
        state: &ModelState,
        cfg: ServeConfig,
    ) -> Result<Self> {
        let preset = backend.manifest().preset(preset_name)?.clone();
        if state.n_blocks() != preset.blocks.len() {
            return Err(anyhow!(
                "checkpoint has {} blocks, preset {preset_name} expects {}",
                state.n_blocks(),
                preset.blocks.len()
            ));
        }
        let blocks = state
            .flats
            .iter()
            .map(|f| backend.upload_f32(f, &[f.len()]))
            .collect::<Result<Vec<_>>>()?;
        let pool = if cfg.kv_pages == 0 {
            KvPool::new(&preset.model, cfg.slots.max(1))
        } else {
            KvPool::with_pages(
                &preset.model,
                cfg.slots.max(1),
                preset.model.seq_len,
                cfg.kv_pages,
            )
        };
        let kv_bytes = pool.capacity_bytes();
        let mut tel = Telemetry::new();
        let m = ServeMetrics::register(&mut tel);
        Ok(Self {
            backend,
            preset,
            blocks,
            pool,
            cache: PrefixCache::new(),
            sched: Scheduler::new(),
            active: Vec::new(),
            reservation: cfg.reservation,
            max_new_default: cfg.max_new_tokens,
            eos: backend.manifest().tokenizer.eos,
            t0: Stopwatch::start(),
            skip_s: 0.0,
            stats: ServeStats { kv_bytes, ..Default::default() },
            tel: Rc::new(tel),
            m,
            synced: SyncedPoolCounters::default(),
        })
    }

    /// The engine's observability hub: metric registry (recording by
    /// default) plus span tracer (enable via
    /// [`Telemetry::enable_tracing`]). Telemetry never changes tokens,
    /// clocks fed to sampling, or transfer behavior — instrumented and
    /// uninstrumented runs are bit-identical.
    pub fn telemetry(&self) -> &Telemetry {
        &self.tel
    }

    /// Engine-clock seconds since construction: wallclock plus any idle
    /// gaps [`ServeEngine::run_until_idle`] fast-forwarded across.
    pub fn now_s(&self) -> f64 {
        self.t0.elapsed_s() + self.skip_s
    }

    /// Enqueue a greedy prompt arriving at `arrival_s` on the engine
    /// clock (`max_new == 0` uses the engine default). Returns the
    /// request id.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize, arrival_s: f64) -> u64 {
        self.submit_sampled(prompt, max_new, arrival_s, SamplingParams::default())
    }

    /// Enqueue a prompt with explicit sampling parameters.
    pub fn submit_sampled(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        arrival_s: f64,
        params: SamplingParams,
    ) -> u64 {
        self.submit_prio(prompt, max_new, arrival_s, 0, params)
    }

    /// Enqueue a prompt in an explicit priority tier (higher admits
    /// first and is preempted last).
    pub fn submit_prio(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        arrival_s: f64,
        priority: u8,
        params: SamplingParams,
    ) -> u64 {
        let max_new = if max_new == 0 { self.max_new_default } else { max_new };
        self.sched.submit_prio(prompt, max_new, arrival_s, priority, params)
    }

    /// Enqueue a greedy prompt arriving now.
    pub fn submit_now(&mut self, prompt: Vec<i32>) -> u64 {
        let now = self.now_s();
        self.submit(prompt, 0, now)
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_empty() && self.sched.n_pending() == 0
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    pub fn n_pending(&self) -> usize {
        self.sched.n_pending()
    }

    pub fn stats(&self) -> ServeStats {
        let mut s = self.stats;
        s.peak_active = self.pool.peak_in_use();
        s.kv_peak_bytes = self.pool.peak_pages() * self.pool.page_bytes();
        s.cow_copies = self.pool.cow_copies();
        s.pages_allocated = self.pool.pages_allocated();
        s
    }

    pub fn kv_pool(&self) -> &KvPool {
        &self.pool
    }

    pub fn prefix_cache(&self) -> &PrefixCache {
        &self.cache
    }

    /// Drop every prefix-cache entry, returning cache-held pages to the
    /// free list (pages shared with live sequences keep their other
    /// references). Mostly for leak accounting in tests.
    pub fn clear_prefix_cache(&mut self) {
        self.cache.clear(&mut self.pool);
    }

    /// Every invariant violation the shadow-state auditors can find in
    /// the engine right now (empty = sound): the full KV refcount/ledger
    /// re-derivation plus the page-budget solvency law, both recomputed
    /// from the live structures rather than the engine's own counters.
    #[cfg(feature = "audit")]
    pub fn audit_violations(&self) -> Vec<String> {
        let mut v = crate::audit::check_kv_pool(&self.pool, &self.cache);
        // re-derive the budget inputs from first principles (the active
        // list and the pool), independent of page_budget()'s arithmetic
        let mut held = 0usize;
        let mut reserved = 0usize;
        for a in &self.active {
            let h = self.pool.pages_held(a.slot);
            held += h;
            reserved += match self.reservation {
                Reservation::WorstCase => a.worst_pages.saturating_sub(h),
                Reservation::Optimistic => {
                    let next = (self.pool.len(a.slot) + 1).min(self.pool.capacity());
                    self.pool.pages_for(next).saturating_sub(h)
                }
            };
        }
        v.extend(crate::audit::check_budget(
            reserved,
            held,
            self.pool.n_free_pages(),
            self.cache.evictable(&self.pool),
        ));
        v
    }

    /// Post-step audit hook: panic on the first invariant violation.
    #[cfg(feature = "audit")]
    fn audit_check(&self) {
        let v = self.audit_violations();
        assert!(v.is_empty(), "serve audit failed after step:\n{}", v.join("\n"));
    }

    /// Mutable pool access for audit negative tests (corrupt the state,
    /// then prove the auditor fires). Not part of the serving API.
    #[cfg(feature = "audit")]
    pub fn kv_pool_mut(&mut self) -> &mut KvPool {
        &mut self.pool
    }

    fn response(a: ActiveSeq, finish_s: f64) -> Response {
        Response {
            id: a.id,
            tokens: a.generated,
            n_prompt: a.n_prompt,
            truncated: false,
            arrival_s: a.arrival_s,
            first_token_s: a.first_token_s,
            finish_s,
            n_preemptions: a.n_preemptions,
        }
    }

    /// Pages a request may ever need (prompt + full generation budget,
    /// clamped to the context length); 0 for prompts the engine rejects
    /// outright, so they drain through admission without holding memory.
    fn worst_pages_for(&self, prompt_len: usize, max_new: usize) -> usize {
        if prompt_len == 0 || prompt_len > self.pool.capacity() {
            return 0;
        }
        self.pool.pages_for((prompt_len + max_new).min(self.pool.capacity()))
    }

    /// Pages admission may still promise: the free list plus whatever the
    /// prefix cache could give back, minus what is already promised to
    /// active sequences — their worst-case remaining growth under
    /// [`Reservation::WorstCase`], just their next decode row under
    /// [`Reservation::Optimistic`] (the preemption backstop covers the
    /// rest).
    fn page_budget(&self) -> usize {
        let mut held = 0usize;
        let mut reserved = 0usize;
        for a in &self.active {
            let h = self.pool.pages_held(a.slot);
            held += h;
            reserved += match self.reservation {
                Reservation::WorstCase => a.worst_pages.saturating_sub(h),
                Reservation::Optimistic => {
                    let next = (self.pool.len(a.slot) + 1).min(self.pool.capacity());
                    self.pool.pages_for(next).saturating_sub(h)
                }
            };
        }
        let free = self.pool.n_free_pages();
        let evictable = self.cache.evictable(&self.pool);
        // the saturating_sub below silently clamps an accounting bug to a
        // permanently-stalled budget of 0 — fail loudly instead: what is
        // promised can never exceed what exists (held + free + evictable)
        debug_assert!(
            reserved <= held + free + evictable,
            "page-budget drift: {reserved} pages promised but only \
             {held} held + {free} free + {evictable} evictable exist"
        );
        (free + evictable).saturating_sub(reserved)
    }

    /// Preemption victim: lowest priority first, then the sequence whose
    /// release frees the most exclusive pages, then the fewest cached
    /// tokens (least work to rebuild), newest id last — fully
    /// deterministic. `None` when fewer than two sequences are active
    /// (the last survivor is never preempted: the pool floor guarantees
    /// one full-context sequence always fits).
    fn pick_victim(&self) -> Option<usize> {
        if self.active.len() <= 1 {
            return None;
        }
        (0..self.active.len()).min_by_key(|&i| {
            let a = &self.active[i];
            (
                a.priority,
                std::cmp::Reverse(self.pool.exclusive_pages(a.slot)),
                self.pool.len(a.slot),
                std::cmp::Reverse(a.id),
            )
        })
    }

    /// Preempt `active[idx]`: park its full KV pages in the prefix cache
    /// (still evictable — pressure reclaims them like any cached stem, but
    /// an undisturbed resume re-attaches instead of re-prefilling),
    /// release its slot and exclusive pages, and requeue the request with
    /// its generated-so-far tokens as resume state.
    fn preempt(&mut self, idx: usize) {
        let a = self.active.remove(idx);
        let len = self.pool.len(a.slot);
        if self.backend.supports_chunked_prefill() && !a.generated.is_empty() {
            // cached rows = prompt + generated[..g-1] (the last emitted
            // token was not fed yet)
            let mut run = a.prompt.clone();
            run.extend_from_slice(&a.generated[..a.generated.len() - 1]);
            debug_assert_eq!(run.len(), len, "cached rows must match the fed history");
            let table = self.pool.table(a.slot).to_vec();
            self.cache.insert(&run, &table, &mut self.pool);
        }
        self.pool.release(a.slot);
        self.stats.n_preemptions += 1;
        self.stats.preempted_tokens += len;
        let tier = (a.priority as usize).min(N_PRIORITY_TIERS - 1);
        self.tel.registry.inc(self.m.preemptions_by_tier[tier]);
        self.tel.registry.add(self.m.preempted_tokens, len as u64);
        self.tel.registry.inc(self.m.requeues);
        self.sched.requeue(Request {
            id: a.id,
            prompt: a.prompt,
            max_new: a.max_new,
            arrival_s: a.arrival_s,
            params: a.params,
            priority: a.priority,
            generated: a.generated,
            n_preemptions: a.n_preemptions + 1,
            first_token_s: Some(a.first_token_s),
        });
    }

    /// Mirror pool/cache-internal monotone counters into the registry
    /// (as deltas, so the registry stays monotone) and refresh the
    /// occupancy gauges. Runs once per [`ServeEngine::step`] — cold
    /// path, no allocation.
    fn sync_registry(&mut self) {
        let tel = Rc::clone(&self.tel);
        let m = self.m;
        let pa = self.pool.pages_allocated();
        tel.registry.add(m.pages_allocated, pa - self.synced.pages_allocated);
        self.synced.pages_allocated = pa;
        let cow = self.pool.cow_copies();
        tel.registry.add(m.cow_copies, cow - self.synced.cow_copies);
        self.synced.cow_copies = cow;
        let ev = self.cache.evictions();
        tel.registry.add(m.prefix_evictions, ev - self.synced.prefix_evictions);
        self.synced.prefix_evictions = ev;
        tel.registry.set(m.active, self.active.len() as f64);
        tel.registry.set(m.pending, self.sched.n_pending() as f64);
        tel.registry.set(m.free_pages, self.pool.n_free_pages() as f64);
        let in_use = self.pool.pages_in_use() * self.pool.page_bytes();
        tel.registry.set(m.kv_bytes_in_use, in_use as f64);
    }

    /// `KvPool::ensure_room`, evicting prefix-cache entries to cover a
    /// dry free list (admission guarantees the pages exist somewhere).
    fn ensure_room_evicting(&mut self, slot: usize, rows: usize) -> Result<()> {
        let missing = self
            .pool
            .pages_for(rows.min(self.pool.capacity()))
            .saturating_sub(self.pool.pages_held(slot));
        if missing > self.pool.n_free_pages() {
            let shortfall = missing - self.pool.n_free_pages();
            self.cache.evict(&mut self.pool, shortfall);
        }
        self.pool.ensure_room(slot, rows)
    }

    /// Copy-on-write fork with the same eviction fallback.
    fn make_row_writable_evicting(&mut self, slot: usize, row: usize) -> Result<()> {
        if self.pool.n_free_pages() == 0 {
            self.cache.evict(&mut self.pool, 1);
        }
        self.pool.make_row_writable(slot, row)
    }

    /// Emit a sampled/greedy token into `a`, honoring stop sequences.
    /// Returns true when the sequence is finished.
    fn push_token(a: &mut ActiveSeq, emit: Option<i32>, finished: bool) -> bool {
        let Some(tok) = emit else { return true };
        a.generated.push(tok);
        a.last = tok;
        if let Some(k) = stop_len(&a.generated, &a.params.stop) {
            let keep = a.generated.len() - k;
            a.generated.truncate(keep);
            return true;
        }
        finished
    }

    /// One mixed prefill+decode iteration; returns the requests that
    /// finished during it.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let tel = Rc::clone(&self.tel);
        let m = self.m;
        let _sp_step = tel.tracer.span(m.sp_step);
        let mut done = Vec::new();

        // --- admission: fill freed slots with arrived prompts that fit
        // the page budget. Rejected (over-length/empty) requests never
        // occupy a slot or a page, so the outer loop re-asks the
        // scheduler until the free slots/pages are actually spent or
        // nothing admissible is left — a burst of bad prompts must not
        // delay a valid one behind it by a decode iteration.
        let now = self.now_s();
        let (cap, page_size) = (self.pool.capacity(), self.pool.page_size());
        let chunked = self.backend.supports_chunked_prefill();
        let reservation = self.reservation;
        let need = move |r: &Request| {
            if r.prompt.is_empty() || r.prompt.len() > cap {
                0
            } else {
                match reservation {
                    // everything the request may ever touch
                    Reservation::WorstCase => {
                        (r.prompt.len() + r.max_new).min(cap).div_ceil(page_size)
                    }
                    // just the fed history plus the first decode row; the
                    // preemption backstop underwrites later growth
                    Reservation::Optimistic => {
                        let fed = r.prompt.len() + r.generated.len();
                        (fed + 1).min(cap).div_ceil(page_size)
                    }
                }
            }
        };
        let sp_admission = tel.tracer.span(m.sp_admission);
        loop {
            let budget = self.page_budget();
            let batch = self.sched.admit(now, self.pool.n_free(), budget, &need);
            if batch.is_empty() {
                break;
            }
            for req in batch {
                let Request {
                    id,
                    prompt,
                    max_new,
                    arrival_s,
                    params,
                    priority,
                    generated,
                    n_preemptions,
                    first_token_s,
                } = req;
                if prompt.is_empty() || prompt.len() > self.pool.capacity() {
                    tel.registry.inc(m.rejected);
                    done.push(Response {
                        id,
                        tokens: Vec::new(),
                        n_prompt: prompt.len(),
                        truncated: true,
                        arrival_s,
                        first_token_s: now,
                        finish_s: now,
                        n_preemptions,
                    });
                    continue;
                }
                let fresh = first_token_s.is_none();
                tel.registry.inc(m.admissions);
                if fresh {
                    tel.registry.observe(m.queue_wait, (now - arrival_s).max(0.0));
                }
                let worst_pages = self.worst_pages_for(prompt.len(), max_new);
                let Some(slot) = self.pool.alloc() else {
                    // admit() is capped at n_free(), so this is an
                    // accounting bug — surface it instead of panicking
                    // the serving loop
                    return Err(anyhow!(
                        "admit() returned request {id} but no KV slot is free"
                    ));
                };

                // the rows to (re-)feed: the prompt plus, after a
                // preemption, every token generated so far — identical
                // cache state to the uninterrupted run at this step
                let mut run = prompt.clone();
                run.extend_from_slice(&generated);

                // prefix sharing: attach cached stem pages (refcounted, no
                // copy), leaving at least one token to prefill for logits.
                // A resumed request's own parked pages come back this way.
                let mut covered = 0usize;
                if chunked {
                    let chain = self.cache.lookup(&run, page_size);
                    covered = (chain.len() * page_size).min(run.len() - 1);
                    if covered > 0 {
                        let n_attach = covered.div_ceil(page_size);
                        self.pool.attach_shared(slot, &chain[..n_attach], covered);
                    }
                }
                self.ensure_room_evicting(slot, run.len())?;
                if covered > 0 {
                    // the divergence row may land mid-page: fork it first
                    self.make_row_writable_evicting(slot, covered)?;
                }

                let t_pre = Stopwatch::start();
                let logits = {
                    let _sp = tel.tracer.span(m.sp_prefill).arg((run.len() - covered) as f64);
                    let mut views = self.pool.views(&[slot])?;
                    let suffix = &run[covered..];
                    self.backend.kv_prefill(&self.preset, &self.blocks, suffix, &mut views[0])?
                };
                #[cfg(feature = "audit")]
                crate::audit::assert_finite("serve/prefill_logits", &logits);
                self.pool.set_len(slot, run.len());
                self.stats.prefill_s += t_pre.elapsed_s();
                self.stats.n_prefills += 1;
                self.stats.prefill_tokens += run.len() - covered;
                self.stats.prefix_hit_tokens += covered;
                tel.registry.inc(m.prefills);
                tel.registry.add(m.prefill_tokens, (run.len() - covered) as u64);
                tel.registry.add(m.prefix_hit_tokens, covered as u64);
                tel.registry.add(m.prefix_miss_tokens, (run.len() - covered) as u64);
                if chunked {
                    let table = self.pool.table(slot).to_vec();
                    self.cache.insert(&run, &table, &mut self.pool);
                }

                // first emission only: a resumed request keeps the stamp
                // from before its preemption
                let stamp = self.now_s();
                if fresh {
                    tel.registry.observe(m.ttft, (stamp - arrival_s).max(0.0));
                }
                let g0 = generated.len();
                let mut a = ActiveSeq {
                    id,
                    slot,
                    last: 0,
                    generated,
                    prompt,
                    n_prompt: run.len() - g0,
                    max_new,
                    arrival_s,
                    first_token_s: first_token_s.unwrap_or(stamp),
                    last_emit_s: stamp,
                    params,
                    worst_pages,
                    priority,
                    n_preemptions,
                };
                let (emit, finished) = greedy_step(
                    sample_token(&logits, &a.params, g0 as u64),
                    self.eos,
                    self.pool.len(slot),
                    self.pool.capacity(),
                    g0,
                    max_new,
                );
                if Self::push_token(&mut a, emit, finished) {
                    let finish_s = self.now_s();
                    tel.registry.inc(m.finished);
                    tel.registry.observe(m.latency, (finish_s - a.arrival_s).max(0.0));
                    self.pool.release(slot);
                    done.push(Self::response(a, finish_s));
                } else {
                    self.active.push(a);
                }
            }
        }
        drop(sp_admission);

        // --- one batched decode iteration over every active sequence ---
        if !self.active.is_empty() {
            let mut sp_decode = tel.tracer.span(m.sp_decode);
            let t_dec = Stopwatch::start();
            // map next-row pages up front (evicting prefix entries if the
            // free list is dry) so the views build cannot fault mid-batch.
            // Under optimistic reservation the free list may still run
            // dry here — the preemption backstop shrinks the active set
            // (never below one sequence: the pool floor fits it) and the
            // mapping pass restarts over the survivors.
            'mapping: loop {
                for i in 0..self.active.len() {
                    let s = self.active[i].slot;
                    let rows = (self.pool.len(s) + 1).min(self.pool.capacity());
                    if self.ensure_room_evicting(s, rows).is_err() {
                        let v = self.pick_victim().ok_or_else(|| {
                            anyhow!(
                                "kv pool: out of pages for the last active sequence \
                                 (accounting bug: the pool floor guarantees it fits)"
                            )
                        })?;
                        self.preempt(v);
                        continue 'mapping;
                    }
                }
                break;
            }
            sp_decode.set_arg(self.active.len() as f64);
            let slots: Vec<usize> = self.active.iter().map(|a| a.slot).collect();
            let tokens: Vec<i32> = self.active.iter().map(|a| a.last).collect();
            let logits = {
                let mut views = self.pool.views(&slots)?;
                self.backend.kv_decode_step(&self.preset, &self.blocks, &tokens, &mut views)?
            };
            #[cfg(feature = "audit")]
            crate::audit::assert_finite("serve/decode_logits", &logits);
            self.stats.decode_s += t_dec.elapsed_s();
            self.stats.decode_steps += 1;
            self.stats.decode_tokens += self.active.len();
            tel.registry.inc(m.decode_steps);
            tel.registry.add(m.decode_tokens, self.active.len() as u64);

            let vocab = self.preset.model.vocab;
            let now = self.now_s();
            let mut still = Vec::with_capacity(self.active.len());
            for (i, mut a) in self.active.drain(..).enumerate() {
                self.pool.advance(a.slot); // the fed token is now cached
                let (emit, finished) = greedy_step(
                    sample_token(
                        &logits[i * vocab..(i + 1) * vocab],
                        &a.params,
                        a.generated.len() as u64,
                    ),
                    self.eos,
                    self.pool.len(a.slot),
                    self.pool.capacity(),
                    a.generated.len(),
                    a.max_new,
                );
                if emit.is_some() {
                    tel.registry.observe(m.itl, (now - a.last_emit_s).max(0.0));
                    a.last_emit_s = now;
                }
                if Self::push_token(&mut a, emit, finished) {
                    tel.registry.inc(m.finished);
                    tel.registry.observe(m.latency, (now - a.arrival_s).max(0.0));
                    self.pool.release(a.slot);
                    done.push(Self::response(a, now));
                } else {
                    still.push(a);
                }
            }
            self.active = still;
        }
        self.sync_registry();
        #[cfg(feature = "audit")]
        self.audit_check();
        Ok(done)
    }

    /// Drive mixed iterations until queue and batch are empty,
    /// fast-forwarding the clock across idle gaps between arrivals.
    pub fn run_until_idle(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        loop {
            if self.active.is_empty() {
                match self.sched.next_arrival_s() {
                    None => break,
                    Some(t) => {
                        let now = self.now_s();
                        if t > now {
                            self.skip_s += t - now;
                        }
                    }
                }
            }
            out.extend(self.step()?);
        }
        Ok(out)
    }
}
