//! The continuous-batching serving engine.
//!
//! One [`ServeEngine`] owns the uploaded model weights, a paged [`KvPool`]
//! shared by all resident sequences, a [`PrefixCache`] of reusable prompt
//! stems, and a [`Scheduler`] request queue. Every [`ServeEngine::step`]
//! is one **mixed iteration**:
//!
//! 1. **Admission** — arrived prompts whose worst-case page demand fits
//!    the remaining page budget are admitted (shortest job first, see
//!    [`Scheduler::admit`]); each admitted prompt attaches any cached
//!    prefix pages (copy-on-write at the divergence page), then runs one
//!    [`prefill`](crate::model::forward::prefill_in) over the *uncovered
//!    suffix only* (filling its cache and producing its first token —
//!    TTFT ends here);
//! 2. **Decode** — all active sequences advance by exactly one token via a
//!    single batched [`decode_step_kv`](crate::model::forward::decode_step_kv_in)
//!    call, mapping fresh pages on demand as they cross page boundaries;
//!    finished sequences release their slot and exclusive pages
//!    immediately, so the next iteration's admission can reuse them
//!    mid-stream.
//!
//! Requests therefore join and leave the batch continuously — no padding
//! to a preset batch size and no head-of-batch stragglers burning compute
//! for finished rows. Per-row kernel results are independent of
//! batch-mates, so each request's token stream is identical to what a
//! dedicated single-sequence decode (or the full-reforward oracle) would
//! produce, regardless of arrival interleaving. Sampled requests
//! ([`SamplingParams`], via [`ServeEngine::submit_sampled`]) keep the
//! same property: each draw depends only on the request's seed and step
//! index, so sampled output is bit-reproducible across batch
//! compositions too.
//!
//! Memory safety of admission: a request is only admitted when `free
//! pages + cache-evictable pages` cover its worst-case demand **plus**
//! the worst-case remaining growth of everything already active, so a
//! mid-decode page fault cannot deadlock — any shortfall is served by
//! evicting LRU prefix-cache entries (preemption of *running* sequences
//! by page eviction is a non-goal here; see ROADMAP).
//!
//! The engine clock is wallclock-based but skips idle gaps: when nothing
//! is active and the next arrival is in the future, the clock
//! fast-forwards instead of sleeping, so open-loop (Poisson) arrival
//! traces replay at full speed while latency accounting stays faithful.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::model::ModelState;
use crate::runtime::Preset;

use super::kv::KvPool;
use super::prefix::PrefixCache;
use super::sampling::{sample_token, stop_len, SamplingParams};
use super::scheduler::{Request, Scheduler};
use super::{greedy_step, KvBackend};

/// Engine construction knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Concurrently resident sequences (KV slots). The paged pool is
    /// provisioned for this many full-context sequences — the worst case;
    /// in-use bytes track actual cached tokens.
    pub slots: usize,
    /// Per-request generation cap when `submit` is given `0`.
    pub max_new_tokens: usize,
}

/// One finished request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Generated token ids (prompt, EOS and matched stop sequences
    /// excluded) — for greedy requests, token-for-token what the
    /// full-reforward oracle would produce.
    pub tokens: Vec<i32>,
    pub n_prompt: usize,
    /// Prompt was empty or longer than the KV capacity: rejected at
    /// admission, nothing was generated (the `n_truncated` signal).
    pub truncated: bool,
    pub arrival_s: f64,
    /// Engine-clock time the first token (or the rejection) was produced.
    pub first_token_s: f64,
    pub finish_s: f64,
}

impl Response {
    /// Time to first token.
    pub fn ttft_s(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    /// End-to-end request latency.
    pub fn latency_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
}

/// Aggregate engine counters (monotone over the engine's lifetime).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    pub n_prefills: u64,
    /// Prompt tokens actually run through prefill (prefix-cache hits
    /// excluded — the savings show up here).
    pub prefill_tokens: usize,
    pub prefill_s: f64,
    pub decode_steps: u64,
    /// Sequence-steps summed over all batched decode calls (= generated
    /// tokens sampled through the decode path).
    pub decode_tokens: usize,
    pub decode_s: f64,
    /// KV backing-store bytes provisioned at construction (the
    /// slot-model worst case; see `kv_peak_bytes` for measured use).
    pub kv_bytes: usize,
    /// Peak bytes of KV pages actually in use.
    pub kv_peak_bytes: usize,
    /// Prompt tokens served from the prefix cache instead of prefill.
    pub prefix_hit_tokens: usize,
    /// Copy-on-write page forks performed.
    pub cow_copies: u64,
    /// Fresh KV pages claimed (monotone; flat while every active
    /// sequence decodes within its last page).
    pub pages_allocated: u64,
    pub peak_active: usize,
}

struct ActiveSeq {
    id: u64,
    slot: usize,
    last: i32,
    generated: Vec<i32>,
    n_prompt: usize,
    max_new: usize,
    arrival_s: f64,
    first_token_s: f64,
    params: SamplingParams,
    /// Pages this sequence may ever need (admission reserved them).
    worst_pages: usize,
}

/// KV-cached continuous-batching engine over any [`KvBackend`].
pub struct ServeEngine<'e, B: KvBackend> {
    backend: &'e B,
    preset: Preset,
    blocks: Vec<B::Buffer>,
    pool: KvPool,
    cache: PrefixCache,
    sched: Scheduler,
    active: Vec<ActiveSeq>,
    max_new_default: usize,
    eos: i32,
    t0: Instant,
    skip_s: f64,
    stats: ServeStats,
}

impl<'e, B: KvBackend> ServeEngine<'e, B> {
    pub fn new(
        backend: &'e B,
        preset_name: &str,
        state: &ModelState,
        cfg: ServeConfig,
    ) -> Result<Self> {
        let preset = backend.manifest().preset(preset_name)?.clone();
        if state.n_blocks() != preset.blocks.len() {
            return Err(anyhow!(
                "checkpoint has {} blocks, preset {preset_name} expects {}",
                state.n_blocks(),
                preset.blocks.len()
            ));
        }
        let blocks = state
            .flats
            .iter()
            .map(|f| backend.upload_f32(f, &[f.len()]))
            .collect::<Result<Vec<_>>>()?;
        let pool = KvPool::new(&preset.model, cfg.slots.max(1));
        let kv_bytes = pool.capacity_bytes();
        Ok(Self {
            backend,
            preset,
            blocks,
            pool,
            cache: PrefixCache::new(),
            sched: Scheduler::new(),
            active: Vec::new(),
            max_new_default: cfg.max_new_tokens,
            eos: backend.manifest().tokenizer.eos,
            t0: Instant::now(),
            skip_s: 0.0,
            stats: ServeStats { kv_bytes, ..Default::default() },
        })
    }

    /// Engine-clock seconds since construction: wallclock plus any idle
    /// gaps [`ServeEngine::run_until_idle`] fast-forwarded across.
    pub fn now_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() + self.skip_s
    }

    /// Enqueue a greedy prompt arriving at `arrival_s` on the engine
    /// clock (`max_new == 0` uses the engine default). Returns the
    /// request id.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize, arrival_s: f64) -> u64 {
        self.submit_sampled(prompt, max_new, arrival_s, SamplingParams::default())
    }

    /// Enqueue a prompt with explicit sampling parameters.
    pub fn submit_sampled(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        arrival_s: f64,
        params: SamplingParams,
    ) -> u64 {
        let max_new = if max_new == 0 { self.max_new_default } else { max_new };
        self.sched.submit_with(prompt, max_new, arrival_s, params)
    }

    /// Enqueue a greedy prompt arriving now.
    pub fn submit_now(&mut self, prompt: Vec<i32>) -> u64 {
        let now = self.now_s();
        self.submit(prompt, 0, now)
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_empty() && self.sched.n_pending() == 0
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    pub fn n_pending(&self) -> usize {
        self.sched.n_pending()
    }

    pub fn stats(&self) -> ServeStats {
        let mut s = self.stats;
        s.peak_active = self.pool.peak_in_use();
        s.kv_peak_bytes = self.pool.peak_pages() * self.pool.page_bytes();
        s.cow_copies = self.pool.cow_copies();
        s.pages_allocated = self.pool.pages_allocated();
        s
    }

    pub fn kv_pool(&self) -> &KvPool {
        &self.pool
    }

    pub fn prefix_cache(&self) -> &PrefixCache {
        &self.cache
    }

    fn response(a: ActiveSeq, finish_s: f64) -> Response {
        Response {
            id: a.id,
            tokens: a.generated,
            n_prompt: a.n_prompt,
            truncated: false,
            arrival_s: a.arrival_s,
            first_token_s: a.first_token_s,
            finish_s,
        }
    }

    /// Pages a request may ever need (prompt + full generation budget,
    /// clamped to the context length); 0 for prompts the engine rejects
    /// outright, so they drain through admission without holding memory.
    fn worst_pages_for(&self, prompt_len: usize, max_new: usize) -> usize {
        if prompt_len == 0 || prompt_len > self.pool.capacity() {
            return 0;
        }
        self.pool.pages_for((prompt_len + max_new).min(self.pool.capacity()))
    }

    /// Pages admission may still promise: the free list plus whatever the
    /// prefix cache could give back, minus the worst-case remaining
    /// growth already promised to active sequences.
    fn page_budget(&self) -> usize {
        let reserved: usize = self
            .active
            .iter()
            .map(|a| a.worst_pages.saturating_sub(self.pool.pages_held(a.slot)))
            .sum();
        (self.pool.n_free_pages() + self.cache.evictable(&self.pool)).saturating_sub(reserved)
    }

    /// `KvPool::ensure_room`, evicting prefix-cache entries to cover a
    /// dry free list (admission guarantees the pages exist somewhere).
    fn ensure_room_evicting(&mut self, slot: usize, rows: usize) -> Result<()> {
        let missing = self
            .pool
            .pages_for(rows.min(self.pool.capacity()))
            .saturating_sub(self.pool.pages_held(slot));
        if missing > self.pool.n_free_pages() {
            let shortfall = missing - self.pool.n_free_pages();
            self.cache.evict(&mut self.pool, shortfall);
        }
        self.pool.ensure_room(slot, rows)
    }

    /// Copy-on-write fork with the same eviction fallback.
    fn make_row_writable_evicting(&mut self, slot: usize, row: usize) -> Result<()> {
        if self.pool.n_free_pages() == 0 {
            self.cache.evict(&mut self.pool, 1);
        }
        self.pool.make_row_writable(slot, row)
    }

    /// Emit a sampled/greedy token into `a`, honoring stop sequences.
    /// Returns true when the sequence is finished.
    fn push_token(a: &mut ActiveSeq, emit: Option<i32>, finished: bool) -> bool {
        let Some(tok) = emit else { return true };
        a.generated.push(tok);
        a.last = tok;
        if let Some(k) = stop_len(&a.generated, &a.params.stop) {
            let keep = a.generated.len() - k;
            a.generated.truncate(keep);
            return true;
        }
        finished
    }

    /// One mixed prefill+decode iteration; returns the requests that
    /// finished during it.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();

        // --- admission: fill freed slots with arrived prompts that fit
        // the page budget. Rejected (over-length/empty) requests never
        // occupy a slot or a page, so the outer loop re-asks the
        // scheduler until the free slots/pages are actually spent or
        // nothing admissible is left — a burst of bad prompts must not
        // delay a valid one behind it by a decode iteration.
        let now = self.now_s();
        let (cap, page_size) = (self.pool.capacity(), self.pool.page_size());
        let chunked = self.backend.supports_chunked_prefill();
        let need = move |r: &Request| {
            if r.prompt.is_empty() || r.prompt.len() > cap {
                0
            } else {
                (r.prompt.len() + r.max_new).min(cap).div_ceil(page_size)
            }
        };
        loop {
            let budget = self.page_budget();
            let batch = self.sched.admit(now, self.pool.n_free(), budget, &need);
            if batch.is_empty() {
                break;
            }
            for req in batch {
                let Request { id, prompt, max_new, arrival_s, params } = req;
                if prompt.is_empty() || prompt.len() > self.pool.capacity() {
                    done.push(Response {
                        id,
                        tokens: Vec::new(),
                        n_prompt: prompt.len(),
                        truncated: true,
                        arrival_s,
                        first_token_s: now,
                        finish_s: now,
                    });
                    continue;
                }
                let worst_pages = self.worst_pages_for(prompt.len(), max_new);
                let slot = self.pool.alloc().expect("admit() never exceeds free slots");

                // prefix sharing: attach cached stem pages (refcounted, no
                // copy), leaving at least one token to prefill for logits
                let mut covered = 0usize;
                if chunked {
                    let chain = self.cache.lookup(&prompt, page_size);
                    covered = (chain.len() * page_size).min(prompt.len() - 1);
                    if covered > 0 {
                        let n_attach = covered.div_ceil(page_size);
                        self.pool.attach_shared(slot, &chain[..n_attach], covered);
                    }
                }
                self.ensure_room_evicting(slot, prompt.len())?;
                if covered > 0 {
                    // the divergence row may land mid-page: fork it first
                    self.make_row_writable_evicting(slot, covered)?;
                }

                let t_pre = Instant::now();
                let logits = {
                    let mut views = self.pool.views(&[slot])?;
                    let suffix = &prompt[covered..];
                    self.backend.kv_prefill(&self.preset, &self.blocks, suffix, &mut views[0])?
                };
                self.pool.set_len(slot, prompt.len());
                self.stats.prefill_s += t_pre.elapsed().as_secs_f64();
                self.stats.n_prefills += 1;
                self.stats.prefill_tokens += prompt.len() - covered;
                self.stats.prefix_hit_tokens += covered;
                if chunked {
                    let table = self.pool.table(slot).to_vec();
                    self.cache.insert(&prompt, &table, &mut self.pool);
                }

                let first_token_s = self.now_s();
                let mut a = ActiveSeq {
                    id,
                    slot,
                    last: 0,
                    generated: Vec::new(),
                    n_prompt: prompt.len(),
                    max_new,
                    arrival_s,
                    first_token_s,
                    params,
                    worst_pages,
                };
                let (emit, finished) = greedy_step(
                    sample_token(&logits, &a.params, 0),
                    self.eos,
                    self.pool.len(slot),
                    self.pool.capacity(),
                    0,
                    max_new,
                );
                if Self::push_token(&mut a, emit, finished) {
                    self.pool.release(slot);
                    done.push(Self::response(a, first_token_s));
                } else {
                    self.active.push(a);
                }
            }
        }

        // --- one batched decode iteration over every active sequence ---
        if !self.active.is_empty() {
            let t_dec = Instant::now();
            // map next-row pages up front (evicting prefix entries if the
            // free list is dry) so the views build cannot fault mid-batch
            let slots: Vec<usize> = self.active.iter().map(|a| a.slot).collect();
            for &s in &slots {
                let rows = (self.pool.len(s) + 1).min(self.pool.capacity());
                self.ensure_room_evicting(s, rows)?;
            }
            let tokens: Vec<i32> = self.active.iter().map(|a| a.last).collect();
            let logits = {
                let mut views = self.pool.views(&slots)?;
                self.backend.kv_decode_step(&self.preset, &self.blocks, &tokens, &mut views)?
            };
            self.stats.decode_s += t_dec.elapsed().as_secs_f64();
            self.stats.decode_steps += 1;
            self.stats.decode_tokens += self.active.len();

            let vocab = self.preset.model.vocab;
            let now = self.now_s();
            let mut still = Vec::with_capacity(self.active.len());
            for (i, mut a) in self.active.drain(..).enumerate() {
                self.pool.advance(a.slot); // the fed token is now cached
                let (emit, finished) = greedy_step(
                    sample_token(
                        &logits[i * vocab..(i + 1) * vocab],
                        &a.params,
                        a.generated.len() as u64,
                    ),
                    self.eos,
                    self.pool.len(a.slot),
                    self.pool.capacity(),
                    a.generated.len(),
                    a.max_new,
                );
                if Self::push_token(&mut a, emit, finished) {
                    self.pool.release(a.slot);
                    done.push(Self::response(a, now));
                } else {
                    still.push(a);
                }
            }
            self.active = still;
        }
        Ok(done)
    }

    /// Drive mixed iterations until queue and batch are empty,
    /// fast-forwarding the clock across idle gaps between arrivals.
    pub fn run_until_idle(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        loop {
            if self.active.is_empty() {
                match self.sched.next_arrival_s() {
                    None => break,
                    Some(t) => {
                        let now = self.now_s();
                        if t > now {
                            self.skip_s += t - now;
                        }
                    }
                }
            }
            out.extend(self.step()?);
        }
        Ok(out)
    }
}
