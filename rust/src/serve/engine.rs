//! The continuous-batching serving engine.
//!
//! One [`ServeEngine`] owns the uploaded model weights, a [`KvPool`] of
//! per-sequence caches, and a [`Scheduler`] request queue. Every
//! [`ServeEngine::step`] is one **mixed iteration**:
//!
//! 1. **Admission** — freed slots are filled with arrived prompts; each
//!    admitted prompt runs one [`prefill`](crate::model::forward::prefill_in)
//!    (filling its cache and producing its first token — TTFT ends here);
//! 2. **Decode** — all active sequences advance by exactly one token via a
//!    single batched [`decode_step_kv`](crate::model::forward::decode_step_kv_in)
//!    call; finished sequences release their slot immediately, so the next
//!    iteration's admission can reuse it mid-stream.
//!
//! Requests therefore join and leave the batch continuously — no padding
//! to a preset batch size and no head-of-batch stragglers burning compute
//! for finished rows. Per-row kernel results are independent of
//! batch-mates, so each request's token stream is identical to what a
//! dedicated single-sequence decode (or the full-reforward oracle) would
//! produce, regardless of arrival interleaving.
//!
//! The engine clock is wallclock-based but skips idle gaps: when nothing
//! is active and the next arrival is in the future, the clock
//! fast-forwards instead of sleeping, so open-loop (Poisson) arrival
//! traces replay at full speed while latency accounting stays faithful.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::eval::argmax;
use crate::model::ModelState;
use crate::runtime::Preset;

use super::kv::KvPool;
use super::scheduler::{Request, Scheduler};
use super::{greedy_step, KvBackend};

/// Engine construction knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Concurrently resident sequences (KV slots).
    pub slots: usize,
    /// Per-request generation cap when `submit` is given `0`.
    pub max_new_tokens: usize,
}

/// One finished request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Generated token ids (prompt and EOS excluded) — token-for-token
    /// what the full-reforward oracle would produce.
    pub tokens: Vec<i32>,
    pub n_prompt: usize,
    /// Prompt was empty or longer than the KV capacity: rejected at
    /// admission, nothing was generated (the `n_truncated` signal).
    pub truncated: bool,
    pub arrival_s: f64,
    /// Engine-clock time the first token (or the rejection) was produced.
    pub first_token_s: f64,
    pub finish_s: f64,
}

impl Response {
    /// Time to first token.
    pub fn ttft_s(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    /// End-to-end request latency.
    pub fn latency_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
}

/// Aggregate engine counters (monotone over the engine's lifetime).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    pub n_prefills: u64,
    pub prefill_tokens: usize,
    pub prefill_s: f64,
    pub decode_steps: u64,
    /// Sequence-steps summed over all batched decode calls (= generated
    /// tokens sampled through the decode path).
    pub decode_tokens: usize,
    pub decode_s: f64,
    /// KV backing-store bytes (constant; allocated at construction).
    pub kv_bytes: usize,
    pub peak_active: usize,
}

struct ActiveSeq {
    id: u64,
    slot: usize,
    last: i32,
    generated: Vec<i32>,
    n_prompt: usize,
    max_new: usize,
    arrival_s: f64,
    first_token_s: f64,
}

/// KV-cached continuous-batching engine over any [`KvBackend`].
pub struct ServeEngine<'e, B: KvBackend> {
    backend: &'e B,
    preset: Preset,
    blocks: Vec<B::Buffer>,
    pool: KvPool,
    sched: Scheduler,
    active: Vec<ActiveSeq>,
    max_new_default: usize,
    eos: i32,
    t0: Instant,
    skip_s: f64,
    stats: ServeStats,
}

impl<'e, B: KvBackend> ServeEngine<'e, B> {
    pub fn new(
        backend: &'e B,
        preset_name: &str,
        state: &ModelState,
        cfg: ServeConfig,
    ) -> Result<Self> {
        let preset = backend.manifest().preset(preset_name)?.clone();
        if state.n_blocks() != preset.blocks.len() {
            return Err(anyhow!(
                "checkpoint has {} blocks, preset {preset_name} expects {}",
                state.n_blocks(),
                preset.blocks.len()
            ));
        }
        let blocks = state
            .flats
            .iter()
            .map(|f| backend.upload_f32(f, &[f.len()]))
            .collect::<Result<Vec<_>>>()?;
        let pool = KvPool::new(&preset.model, cfg.slots.max(1));
        let kv_bytes = pool.bytes();
        Ok(Self {
            backend,
            preset,
            blocks,
            pool,
            sched: Scheduler::new(),
            active: Vec::new(),
            max_new_default: cfg.max_new_tokens,
            eos: backend.manifest().tokenizer.eos,
            t0: Instant::now(),
            skip_s: 0.0,
            stats: ServeStats { kv_bytes, ..Default::default() },
        })
    }

    /// Engine-clock seconds since construction: wallclock plus any idle
    /// gaps [`ServeEngine::run_until_idle`] fast-forwarded across.
    pub fn now_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() + self.skip_s
    }

    /// Enqueue a prompt arriving at `arrival_s` on the engine clock
    /// (`max_new == 0` uses the engine default). Returns the request id.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize, arrival_s: f64) -> u64 {
        let max_new = if max_new == 0 { self.max_new_default } else { max_new };
        self.sched.submit(prompt, max_new, arrival_s)
    }

    /// Enqueue a prompt arriving now.
    pub fn submit_now(&mut self, prompt: Vec<i32>) -> u64 {
        let now = self.now_s();
        self.submit(prompt, 0, now)
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_empty() && self.sched.n_pending() == 0
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    pub fn n_pending(&self) -> usize {
        self.sched.n_pending()
    }

    pub fn stats(&self) -> ServeStats {
        let mut s = self.stats;
        s.peak_active = self.pool.peak_in_use();
        s
    }

    pub fn kv_pool(&self) -> &KvPool {
        &self.pool
    }

    fn response(a: ActiveSeq, finish_s: f64) -> Response {
        Response {
            id: a.id,
            tokens: a.generated,
            n_prompt: a.n_prompt,
            truncated: false,
            arrival_s: a.arrival_s,
            first_token_s: a.first_token_s,
            finish_s,
        }
    }

    /// One mixed prefill+decode iteration; returns the requests that
    /// finished during it.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();

        // --- admission: fill freed slots with arrived prompts. Rejected
        // (over-length/empty) requests never occupy a slot, so the outer
        // loop re-asks the scheduler until the free slots are actually
        // spent or nothing admissible is left — a burst of bad prompts
        // must not delay a valid one behind it by a decode iteration.
        let now = self.now_s();
        loop {
            let batch = self.sched.admit(now, self.pool.n_free());
            if batch.is_empty() {
                break;
            }
            for req in batch {
                let Request { id, prompt, max_new, arrival_s } = req;
                if prompt.is_empty() || prompt.len() > self.pool.capacity() {
                    done.push(Response {
                        id,
                        tokens: Vec::new(),
                        n_prompt: prompt.len(),
                        truncated: true,
                        arrival_s,
                        first_token_s: now,
                        finish_s: now,
                    });
                    continue;
                }
                let slot = self.pool.alloc().expect("admit() never exceeds free slots");
                let t_pre = Instant::now();
                let logits = {
                    let mut views = self.pool.views(&[slot])?;
                    self.backend.kv_prefill(&self.preset, &self.blocks, &prompt, &mut views[0])?
                };
                self.pool.set_len(slot, prompt.len());
                self.stats.prefill_s += t_pre.elapsed().as_secs_f64();
                self.stats.n_prefills += 1;
                self.stats.prefill_tokens += prompt.len();

                let first_token_s = self.now_s();
                let mut a = ActiveSeq {
                    id,
                    slot,
                    last: 0,
                    generated: Vec::new(),
                    n_prompt: prompt.len(),
                    max_new,
                    arrival_s,
                    first_token_s,
                };
                let (emit, finished) = greedy_step(
                    argmax(&logits),
                    self.eos,
                    self.pool.len(slot),
                    self.pool.capacity(),
                    0,
                    max_new,
                );
                if let Some(tok) = emit {
                    a.generated.push(tok);
                    a.last = tok;
                }
                if finished {
                    self.pool.release(slot);
                    done.push(Self::response(a, first_token_s));
                } else {
                    self.active.push(a);
                }
            }
        }

        // --- one batched decode iteration over every active sequence ---
        if !self.active.is_empty() {
            let t_dec = Instant::now();
            let tokens: Vec<i32> = self.active.iter().map(|a| a.last).collect();
            let slots: Vec<usize> = self.active.iter().map(|a| a.slot).collect();
            let logits = {
                let mut views = self.pool.views(&slots)?;
                self.backend.kv_decode_step(&self.preset, &self.blocks, &tokens, &mut views)?
            };
            self.stats.decode_s += t_dec.elapsed().as_secs_f64();
            self.stats.decode_steps += 1;
            self.stats.decode_tokens += self.active.len();

            let vocab = self.preset.model.vocab;
            let now = self.now_s();
            let mut still = Vec::with_capacity(self.active.len());
            for (i, mut a) in self.active.drain(..).enumerate() {
                self.pool.advance(a.slot); // the fed token is now cached
                let (emit, finished) = greedy_step(
                    argmax(&logits[i * vocab..(i + 1) * vocab]),
                    self.eos,
                    self.pool.len(a.slot),
                    self.pool.capacity(),
                    a.generated.len(),
                    a.max_new,
                );
                if let Some(tok) = emit {
                    a.generated.push(tok);
                    a.last = tok;
                }
                if finished {
                    self.pool.release(a.slot);
                    done.push(Self::response(a, now));
                } else {
                    still.push(a);
                }
            }
            self.active = still;
        }
        Ok(done)
    }

    /// Drive mixed iterations until queue and batch are empty,
    /// fast-forwarding the clock across idle gaps between arrivals.
    pub fn run_until_idle(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        loop {
            if self.active.is_empty() {
                match self.sched.next_arrival_s() {
                    None => break,
                    Some(t) => {
                        let now = self.now_s();
                        if t > now {
                            self.skip_s += t - now;
                        }
                    }
                }
            }
            out.extend(self.step()?);
        }
        Ok(out)
    }
}
