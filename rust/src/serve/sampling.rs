//! Seeded sampling over next-token logits: temperature / top-k / top-p
//! plus stop sequences, all on the repo's deterministic
//! [`crate::util::rng::Rng`].
//!
//! Reproducibility contract: the token sampled at generation step `g` of
//! a request depends only on (`logits`, [`SamplingParams`], `g`) — each
//! step derives a fresh RNG from `seed ^ hash(g)` instead of streaming
//! one RNG across steps. Since per-row logits are independent of
//! batch-mates (pinned in `tests/serve_decode.rs`), a sampled generation
//! is **bit-reproducible regardless of batch composition, slot
//! assignment, and arrival interleaving** — pinned in
//! `tests/serve_sampling.rs`.
//!
//! Greedy (`temperature == 0`) delegates to the same NaN-hardened argmax
//! the oracle decode loop uses, so a greedy `SamplingParams` is
//! token-for-token the oracle path.

use crate::util::rng::Rng;

/// Per-request sampling configuration, carried on
/// [`crate::serve::Request`].
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; `<= 0` means greedy argmax (the default).
    pub temperature: f32,
    /// Keep only the `top_k` highest logits before sampling (0 = all).
    pub top_k: usize,
    /// Keep the smallest logit-sorted prefix with cumulative probability
    /// `>= top_p` (1.0 = all).
    pub top_p: f32,
    /// Seed for the per-request sampling stream.
    pub seed: u64,
    /// Stop sequences: generation ends when the emitted tail equals any
    /// of these token runs (the matched run is trimmed from the output).
    pub stop: Vec<Vec<i32>>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 0, stop: Vec::new() }
    }
}

impl SamplingParams {
    /// Greedy mode: plain argmax, no RNG involved.
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

/// Sample a token id from `logits` for generation step `n_generated` of a
/// request. Greedy params short-circuit to the oracle argmax. Returns
/// `None` when no finite logit survives (NaN-poisoned row — the caller
/// stops the sequence, same as greedy).
pub fn sample_token(logits: &[f32], params: &SamplingParams, n_generated: u64) -> Option<usize> {
    if params.is_greedy() {
        return crate::eval::argmax(logits);
    }
    // candidates: non-NaN logits, sorted by descending logit (ascending
    // index on ties — same tie order as argmax). total_cmp keeps the
    // sort panic-free even if a NaN ever slips past the filter (the same
    // skip-NaN policy as eval::argmax; an all-NaN row returns None and
    // the caller ends the sequence)
    let mut cand: Vec<(usize, f32)> =
        logits.iter().copied().enumerate().filter(|(_, l)| !l.is_nan()).collect();
    if cand.is_empty() {
        return None;
    }
    cand.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    if params.top_k > 0 && cand.len() > params.top_k {
        cand.truncate(params.top_k);
    }
    // f64 softmax keeps the cumulative sums deterministic and stable
    let maxl = cand[0].1;
    let invt = 1.0 / params.temperature as f64;
    let mut probs: Vec<f64> =
        cand.iter().map(|&(_, l)| (((l - maxl) as f64) * invt).exp()).collect();
    let mut total: f64 = probs.iter().sum();
    if params.top_p < 1.0 {
        // nucleus: smallest sorted prefix reaching top_p (≥ 1 kept)
        let target = total * params.top_p.max(0.0) as f64;
        let mut cum = 0.0;
        let mut keep = probs.len();
        for (i, &p) in probs.iter().enumerate() {
            cum += p;
            if cum >= target {
                keep = i + 1;
                break;
            }
        }
        probs.truncate(keep);
        total = cum;
    }
    // one fresh RNG per (seed, step): sampling depends on the step index,
    // never on how many RNG draws other requests or earlier batches made
    let mut rng =
        Rng::seed_from_u64(params.seed ^ n_generated.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let u = rng.gen_f64() * total;
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return Some(cand[i].0);
        }
    }
    // float round-off fell past the last bucket
    Some(cand[probs.len() - 1].0)
}

/// If the emitted tail of `generated` matches any stop sequence, return
/// the longest match's length (to trim); `None` otherwise. Empty stop
/// sequences never match.
pub fn stop_len(generated: &[i32], stop: &[Vec<i32>]) -> Option<usize> {
    stop.iter()
        .filter(|s| !s.is_empty() && generated.ends_with(s))
        .map(|s| s.len())
        .max()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits() -> Vec<f32> {
        vec![0.1, 2.5, -1.0, 2.4, 0.0, 1.5]
    }

    #[test]
    fn greedy_params_are_exact_argmax() {
        let l = logits();
        let p = SamplingParams::default();
        assert!(p.is_greedy());
        assert_eq!(sample_token(&l, &p, 0), crate::eval::argmax(&l));
        assert_eq!(sample_token(&l, &p, 7), Some(1));
    }

    #[test]
    fn top_k_one_is_argmax_at_any_temperature() {
        let l = logits();
        let p = SamplingParams { temperature: 5.0, top_k: 1, ..Default::default() };
        for g in 0..20 {
            assert_eq!(sample_token(&l, &p, g), Some(1));
        }
    }

    #[test]
    fn sampling_is_reproducible_per_seed_and_step() {
        let l = logits();
        let p = SamplingParams { temperature: 1.0, seed: 42, ..Default::default() };
        let a: Vec<_> = (0..50).map(|g| sample_token(&l, &p, g)).collect();
        let b: Vec<_> = (0..50).map(|g| sample_token(&l, &p, g)).collect();
        assert_eq!(a, b, "same seed and steps, same draws");
        assert!(a.iter().any(|&t| t != a[0]), "temperature 1 must actually vary");
        let other = SamplingParams { seed: 43, ..p };
        let c: Vec<_> = (0..50).map(|g| sample_token(&l, &other, g)).collect();
        assert_ne!(a, c, "different seeds diverge");
    }

    #[test]
    fn top_p_collapses_to_the_nucleus() {
        // two near-ties far above the rest: a tight nucleus keeps only them
        let l = vec![10.0, 9.9, -5.0, -6.0, -7.0];
        let p = SamplingParams { temperature: 1.0, top_p: 0.5, seed: 9, ..Default::default() };
        for g in 0..100 {
            let t = sample_token(&l, &p, g).unwrap();
            assert!(t <= 1, "step {g} sampled outside the nucleus: {t}");
        }
    }

    #[test]
    fn nan_poisoned_rows_sample_nothing() {
        let l = vec![f32::NAN, f32::NAN];
        let p = SamplingParams { temperature: 1.0, ..Default::default() };
        assert_eq!(sample_token(&l, &p, 0), None);
        // NaNs are skipped, not propagated
        let l = vec![f32::NAN, 1.0];
        assert_eq!(sample_token(&l, &p, 0), Some(1));
    }

    #[test]
    fn stop_len_matches_tails_only() {
        let stop = vec![vec![7, 8], vec![8], vec![]];
        assert_eq!(stop_len(&[1, 7, 8], &stop), Some(2), "longest match wins");
        assert_eq!(stop_len(&[1, 8], &stop), Some(1));
        assert_eq!(stop_len(&[7, 8, 1], &stop), None, "mid-sequence is no match");
        assert_eq!(stop_len(&[], &stop), None, "empty stop sequences never fire");
    }
}
