//! Prefix cache: retains full K/V pages of finished prompt stems keyed by
//! their token runs, so requests sharing a system prompt / few-shot
//! preamble store and prefill the stem **once**.
//!
//! Keys are page-aligned full token prefixes (`prompt[..k·page_size]` for
//! every full page `k`), each mapped to the page holding that prefix's
//! last `page_size` rows. A lookup walks the chain `k = 1, 2, …` until
//! the first miss; the hit pages are attached to the new slot via
//! [`KvPool::attach_shared`] (refcount, no copy) and only the divergent
//! suffix is prefilled. Because row `j`'s K/V depend only on tokens
//! `0..=j` (causality) and every key is the *entire* token run up to that
//! page, any re-composed chain is bit-correct — including pages cached by
//! different requests at different times.
//!
//! Entries hold one pool reference per page, so a cached page survives
//! its sequences; under page pressure the engine evicts LRU entries whose
//! page is referenced by the cache alone ([`PrefixCache::evict`]),
//! returning those pages to the free list. Deeper pages of a chain are
//! stamped older than shallower ones so chains unwind tail-first.
//!
//! The cache doubles as the engine's **preemption parking lot**: a
//! preempted sequence's full pages are inserted keyed by its fed history
//! (prompt + generated tokens), so an undisturbed resume re-attaches them
//! instead of re-prefilling — and under further pressure they are
//! reclaimed like any other cached stem, which is exactly the
//! release-under-pressure semantics preemption wants. (Follow-on in
//! ROADMAP: priority-aware retention, so high-priority parked state
//! outlives best-effort stems.)

use std::collections::HashMap;

use super::kv::KvPool;

struct Entry {
    page: u32,
    /// LRU stamp: `(clock << 16) | (0xFFFF - depth)` — later touches win,
    /// and within one touch deeper pages stamp older, so eviction peels
    /// chains from the tail and never orphans a reachable parent first.
    stamp: u64,
}

/// Map from page-aligned token prefixes to cached K/V pages.
#[derive(Default)]
pub struct PrefixCache {
    entries: HashMap<Vec<i32>, Entry>,
    clock: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    hit_tokens: u64,
    miss_tokens: u64,
}

impl PrefixCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cached entries (diagnostics).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Prefix-page lookups that hit / missed (one count per request).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Prompt tokens covered by cached pages across all lookups (the
    /// token-weighted counterpart of [`PrefixCache::hits`] — long shared
    /// stems weigh more than short ones).
    pub fn hit_tokens(&self) -> u64 {
        self.hit_tokens
    }

    /// Prompt tokens lookups could *not* cover — the tokens a prefill
    /// still had to compute.
    pub fn miss_tokens(&self) -> u64 {
        self.miss_tokens
    }

    /// One LRU stamp: all pages touched by a single lookup/insert share
    /// the clock tick, with depth as the tiebreak (deeper = older), so
    /// chains unwind tail-first under eviction.
    fn stamp(now: u64, depth: usize) -> u64 {
        (now << 16) | (0xFFFF - depth.min(0xFFFE) as u64)
    }

    /// Longest chain of cached pages covering a prefix of `prompt`
    /// (page-aligned). Returns the page ids in row order; the caller
    /// attaches them with [`KvPool::attach_shared`] **before** anything
    /// else can evict them. Counts one hit (non-empty chain) or miss.
    pub fn lookup(&mut self, prompt: &[i32], page_size: usize) -> Vec<u32> {
        let now = self.clock;
        self.clock += 1;
        let mut chain = Vec::new();
        let mut k = 1;
        while k * page_size <= prompt.len() {
            match self.entries.get_mut(&prompt[..k * page_size]) {
                Some(e) => {
                    e.stamp = Self::stamp(now, k - 1);
                    chain.push(e.page);
                }
                None => break,
            }
            k += 1;
        }
        if chain.is_empty() {
            self.misses += 1;
        } else {
            self.hits += 1;
        }
        let covered = (chain.len() * page_size) as u64;
        self.hit_tokens += covered;
        self.miss_tokens += prompt.len() as u64 - covered;
        chain
    }

    /// Register `slot`'s freshly-prefilled prompt pages: every full page
    /// of `prompt` not yet cached gains an entry and one pool reference.
    /// First writer wins — an existing entry is only LRU-touched, its
    /// page stays (equal keys imply bit-identical contents, so there is
    /// nothing to reconcile).
    pub fn insert(&mut self, prompt: &[i32], table: &[u32], pool: &mut KvPool) {
        let page_size = pool.page_size();
        let now = self.clock;
        self.clock += 1;
        let mut k = 1;
        while k * page_size <= prompt.len() && k <= table.len() {
            let key = &prompt[..k * page_size];
            let stamp = Self::stamp(now, k - 1);
            match self.entries.get_mut(key) {
                Some(e) => e.stamp = stamp,
                None => {
                    let page = table[k - 1];
                    pool.retain_page(page);
                    self.entries.insert(key.to_vec(), Entry { page, stamp });
                }
            }
            k += 1;
        }
    }

    /// Page ids held by cache entries, in arbitrary order (one per entry;
    /// the shadow-refcount auditor counts these against the pool).
    pub fn entry_pages(&self) -> impl Iterator<Item = u32> + '_ {
        self.entries.values().map(|e| e.page)
    }

    /// Entries whose page only the cache still references — the pages
    /// [`PrefixCache::evict`] could free right now.
    pub fn evictable(&self, pool: &KvPool) -> usize {
        self.entries.values().filter(|e| pool.page_ref(e.page) == 1).count()
    }

    /// Evict up to `n` LRU entries whose page is unreferenced outside the
    /// cache, releasing their pages; returns how many pages were freed.
    /// Entries still shared with live sequences are skipped (freeing them
    /// would gain nothing — the page cannot return to the free list).
    pub fn evict(&mut self, pool: &mut KvPool, n: usize) -> usize {
        let mut freed = 0;
        while freed < n {
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| pool.page_ref(e.page) == 1)
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone());
            let Some(key) = victim else { break };
            let Some(e) = self.entries.remove(&key) else {
                debug_assert!(false, "victim key vanished between scan and removal");
                break;
            };
            pool.release_page(e.page);
            self.evictions += 1;
            freed += 1;
        }
        freed
    }

    /// Drop every entry, releasing all cache-held references.
    pub fn clear(&mut self, pool: &mut KvPool) {
        for (_, e) in self.entries.drain() {
            pool.release_page(e.page);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Manifest, ModelSpec};

    fn model() -> ModelSpec {
        Manifest::builtin().preset("test-tiny").unwrap().model.clone()
    }

    /// A slot with `rows` cached rows and an arbitrary (zeroed) table.
    fn filled_slot(pool: &mut KvPool, rows: usize) -> usize {
        let s = pool.alloc().unwrap();
        pool.ensure_room(s, rows).unwrap();
        pool.set_len(s, rows);
        s
    }

    #[test]
    fn lookup_walks_the_longest_cached_chain() {
        let m = model();
        let mut pool = KvPool::new(&m, 2);
        let mut cache = PrefixCache::new();
        let p = pool.page_size();
        let prompt: Vec<i32> = (0..(2 * p + 3) as i32).collect();
        assert!(cache.lookup(&prompt, p).is_empty(), "cold cache misses");
        let s = filled_slot(&mut pool, prompt.len());
        let table = pool.table(s).to_vec();
        cache.insert(&prompt, &table, &mut pool);
        assert_eq!(cache.len(), 2, "only full pages are cached");
        // full-chain hit
        assert_eq!(cache.lookup(&prompt, p), table[..2].to_vec());
        // shared stem, divergent second page: chain stops after page 1
        let mut other = prompt.clone();
        other[p + 1] ^= 1;
        assert_eq!(cache.lookup(&other, p), table[..1].to_vec());
        // different first token: no chain at all
        let mut cold = prompt.clone();
        cold[0] ^= 1;
        assert!(cache.lookup(&cold, p).is_empty());
        assert_eq!(cache.hits(), 2);
        assert_eq!(cache.misses(), 2);
        // cache references pin the pages across the slot's release
        pool.release(s);
        assert_eq!(pool.page_ref(table[0]), 1, "cache still holds page 0");
        assert_eq!(cache.evictable(&pool), 2);
    }

    #[test]
    fn insert_is_first_writer_wins() {
        let m = model();
        let mut pool = KvPool::new(&m, 2);
        let mut cache = PrefixCache::new();
        let p = pool.page_size();
        let prompt: Vec<i32> = (0..p as i32).collect();
        let a = filled_slot(&mut pool, p);
        let table_a = pool.table(a).to_vec();
        cache.insert(&prompt, &table_a, &mut pool);
        let b = filled_slot(&mut pool, p);
        let table_b = pool.table(b).to_vec();
        cache.insert(&prompt, &table_b, &mut pool);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.lookup(&prompt, p), table_a[..1].to_vec(), "first entry kept");
        assert_eq!(pool.page_ref(table_a[0]), 2, "slot + cache, not double-cached");
    }

    #[test]
    fn evict_frees_lru_unreferenced_pages_only() {
        let m = model();
        let mut pool = KvPool::new(&m, 3);
        let mut cache = PrefixCache::new();
        let p = pool.page_size();
        let live: Vec<i32> = (0..p as i32).collect();
        let dead: Vec<i32> = (100..100 + p as i32).collect();
        let a = filled_slot(&mut pool, p);
        let table_a = pool.table(a).to_vec();
        cache.insert(&live, &table_a, &mut pool);
        let b = filled_slot(&mut pool, p);
        let table_b = pool.table(b).to_vec();
        let dead_page = table_b[0];
        cache.insert(&dead, &table_b, &mut pool);
        pool.release(b); // only the cache references `dead_page` now
        assert_eq!(cache.evictable(&pool), 1, "the live entry is pinned by slot a");
        let free_before = pool.n_free_pages();
        assert_eq!(cache.evict(&mut pool, 10), 1, "only the dead entry can free a page");
        assert_eq!(pool.n_free_pages(), free_before + 1);
        assert_eq!(pool.page_ref(dead_page), 0);
        assert_eq!(cache.len(), 1);
        assert!(cache.lookup(&live, p).len() == 1, "live entry survived");
        // releasing the slot makes the survivor evictable too
        pool.release(a);
        cache.clear(&mut pool);
        assert_eq!(pool.bytes(), 0, "clear returns every cached page");
    }

    #[test]
    fn chains_unwind_tail_first() {
        let m = model();
        let mut pool = KvPool::new(&m, 1);
        let mut cache = PrefixCache::new();
        let p = pool.page_size();
        let prompt: Vec<i32> = (0..(2 * p) as i32).collect();
        let s = filled_slot(&mut pool, 2 * p);
        let table = pool.table(s).to_vec();
        cache.insert(&prompt, &table, &mut pool);
        pool.release(s);
        // evicting one page must drop the chain's tail, keeping the stem
        assert_eq!(cache.evict(&mut pool, 1), 1);
        assert_eq!(cache.lookup(&prompt, p), table[..1].to_vec(), "stem page survives");
    }
}
