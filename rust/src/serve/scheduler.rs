//! Request queue + admission policy for continuous batching.
//!
//! The scheduler is deliberately dumb and fully deterministic: requests
//! wait in a FIFO ordered by arrival time, and [`Scheduler::admit`] hands
//! out at most `free_slots` requests whose arrival time has passed. All
//! timing is the caller's notion of "now" (the engine's virtual clock),
//! so the same submission set replays identically in tests.
//!
//! Head-of-line behavior is intentional: a prompt that cannot be admitted
//! yet (not arrived) blocks later arrivals, preserving request order —
//! the property the interleaving-independence tests lean on.

use std::collections::VecDeque;

/// One queued generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Engine-clock time at which the request becomes visible.
    pub arrival_s: f64,
}

/// FIFO request queue ordered by arrival time.
#[derive(Debug, Default)]
pub struct Scheduler {
    pending: VecDeque<Request>,
    next_id: u64,
    n_submitted: u64,
}

impl Scheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a request; returns its id. Arrivals are kept sorted, so
    /// out-of-order submission times are fine (O(1) for the common
    /// monotone case).
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize, arrival_s: f64) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.n_submitted += 1;
        let at = self
            .pending
            .iter()
            .rposition(|r| r.arrival_s <= arrival_s)
            .map(|i| i + 1)
            .unwrap_or(0);
        self.pending.insert(at, Request { id, prompt, max_new, arrival_s });
        id
    }

    /// Pop up to `free_slots` requests that have arrived by `now_s`,
    /// strictly in queue order.
    pub fn admit(&mut self, now_s: f64, free_slots: usize) -> Vec<Request> {
        let mut out = Vec::new();
        while out.len() < free_slots {
            match self.pending.front() {
                Some(r) if r.arrival_s <= now_s => out.push(self.pending.pop_front().unwrap()),
                _ => break,
            }
        }
        out
    }

    pub fn n_pending(&self) -> usize {
        self.pending.len()
    }

    pub fn n_submitted(&self) -> u64 {
        self.n_submitted
    }

    /// Arrival time of the next queued request (for clock fast-forward
    /// when the engine is idle).
    pub fn next_arrival_s(&self) -> Option<f64> {
        self.pending.front().map(|r| r.arrival_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_admission_respects_arrivals_and_slots() {
        let mut s = Scheduler::new();
        let a = s.submit(vec![1], 4, 0.0);
        let b = s.submit(vec![2], 4, 1.0);
        let c = s.submit(vec![3], 4, 2.0);
        assert_eq!([a, b, c], [0, 1, 2]);
        assert_eq!(s.n_pending(), 3);

        // nothing arrived before t=0? a has
        let got = s.admit(0.5, 8);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![a]);
        // b+c arrived by t=2 but only one slot free
        let got = s.admit(2.0, 1);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![b]);
        assert_eq!(s.next_arrival_s(), Some(2.0));
        let got = s.admit(2.0, 1);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![c]);
        assert_eq!(s.n_pending(), 0);
        assert_eq!(s.n_submitted(), 3);
    }

    #[test]
    fn head_of_line_blocks_until_arrival() {
        let mut s = Scheduler::new();
        s.submit(vec![1], 4, 5.0);
        s.submit(vec![2], 4, 6.0);
        assert!(s.admit(4.9, 8).is_empty(), "nothing has arrived yet");
        assert_eq!(s.n_pending(), 2);
        let got = s.admit(10.0, 8);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].id, 0, "queue order preserved");
    }

    #[test]
    fn out_of_order_submissions_sort_by_arrival() {
        let mut s = Scheduler::new();
        let late = s.submit(vec![1], 4, 9.0);
        let early = s.submit(vec![2], 4, 1.0);
        let got = s.admit(100.0, 8);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![early, late]);
    }
}
