//! Request queue + admission policy for continuous batching.
//!
//! Requests wait in a queue ordered by arrival time; [`Scheduler::admit`]
//! hands out at most `free_slots` arrived requests whose **page demand**
//! (computed by the caller's `page_need` closure — worst-case or
//! optimistic, the engine's choice) fits the remaining page budget —
//! admit-by-free-pages, so a request is only started when the paged
//! [`super::KvPool`] can see it through (or, under optimistic
//! reservation, until the engine's preemption backstop steps in). Among
//! arrived candidates, admission orders by **priority** (higher
//! [`Request::priority`] first), then prefers the **shortest job**
//! (fewest pages needed), falling back to arrival order and then
//! submission id among equals — fully deterministic: all timing is the
//! caller's notion of "now" (the engine's virtual clock), so the same
//! submission set replays identically in tests.
//!
//! Shortest-job-first alone can starve a long prompt behind an endless
//! stream of short ones, so the scheduler tracks how many admission
//! rounds the queue head has been bypassed; after
//! [`STARVATION_ROUNDS`] rounds the head becomes the only admissible
//! request until it fits (this fairness guard deliberately outranks
//! priority: a starving low-priority head briefly blocks admission rather
//! than being bypassed forever). A prompt that has not *arrived* yet
//! still blocks nothing — only arrived requests compete.
//!
//! Preempted sequences return through [`Scheduler::requeue`], which keeps
//! the request's id and original arrival time and carries its
//! already-generated tokens, so a re-admission resumes instead of
//! restarting and latency accounting stays anchored to the true arrival.

use std::collections::VecDeque;

use super::sampling::SamplingParams;

/// Admission rounds the queue head may be bypassed by shorter jobs
/// before the scheduler insists on admitting it first.
pub const STARVATION_ROUNDS: u32 = 8;

/// One queued generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Engine-clock time at which the request becomes visible.
    pub arrival_s: f64,
    /// Decoding configuration (greedy by default).
    pub params: SamplingParams,
    /// Admission priority: higher values admit first and are preempted
    /// last (0 = default best-effort tier).
    pub priority: u8,
    /// Tokens already emitted before a preemption (empty for a fresh
    /// request); counts against `max_new` and is re-fed on re-admission.
    pub generated: Vec<i32>,
    /// Times this request has been preempted and requeued.
    pub n_preemptions: u32,
    /// Engine-clock stamp of the first emitted token, carried across a
    /// requeue so TTFT never counts queue re-entry as a fresh start.
    pub first_token_s: Option<f64>,
}

/// Arrival-ordered request queue with paged admission.
#[derive(Debug, Default)]
pub struct Scheduler {
    pending: VecDeque<Request>,
    next_id: u64,
    n_submitted: u64,
    /// Anti-starvation bookkeeping: the head request last bypassed, and
    /// how many admission rounds it has been bypassed in a row.
    starved_id: Option<u64>,
    head_skips: u32,
    n_requeued: u64,
}

impl Scheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a greedy request; returns its id. Arrivals are kept
    /// sorted, so out-of-order submission times are fine (O(1) for the
    /// common monotone case).
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize, arrival_s: f64) -> u64 {
        self.submit_with(prompt, max_new, arrival_s, SamplingParams::default())
    }

    /// Enqueue a request with explicit sampling parameters.
    pub fn submit_with(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        arrival_s: f64,
        params: SamplingParams,
    ) -> u64 {
        self.submit_prio(prompt, max_new, arrival_s, 0, params)
    }

    /// Enqueue a request with an explicit priority tier.
    pub fn submit_prio(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        arrival_s: f64,
        priority: u8,
        params: SamplingParams,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.n_submitted += 1;
        self.insert_sorted(Request {
            id,
            prompt,
            max_new,
            arrival_s,
            params,
            priority,
            generated: Vec::new(),
            n_preemptions: 0,
            first_token_s: None,
        });
        id
    }

    /// Re-enqueue a preempted request, keeping its id, priority, original
    /// arrival time and resume state (`generated`, `first_token_s`).
    /// Because the original arrival is old, the victim re-sorts near the
    /// queue front; it does not count as a new submission (it counts in
    /// [`Scheduler::n_requeued`] instead).
    pub fn requeue(&mut self, req: Request) {
        self.n_requeued += 1;
        self.insert_sorted(req);
    }

    /// Arrival-sorted insert shared by fresh submissions and requeues.
    fn insert_sorted(&mut self, req: Request) {
        let at = self
            .pending
            .iter()
            .rposition(|r| r.arrival_s <= req.arrival_s)
            .map(|i| i + 1)
            .unwrap_or(0);
        self.pending.insert(at, req);
    }

    /// Requests re-enqueued after a preemption (monotone; fresh
    /// submissions never count).
    pub fn n_requeued(&self) -> u64 {
        self.n_requeued
    }

    /// Pop up to `free_slots` arrived requests whose summed page demand
    /// fits `free_pages`. `page_need` maps a request to its page demand
    /// (0 for requests the engine will reject outright, so they drain
    /// without holding memory). Selection: highest priority first, then
    /// shortest job (fewest pages), then arrival, then id — except when
    /// the queue head has been bypassed [`STARVATION_ROUNDS`] times, in
    /// which case it is admitted first or nothing is.
    pub fn admit(
        &mut self,
        now_s: f64,
        free_slots: usize,
        free_pages: usize,
        page_need: &dyn Fn(&Request) -> usize,
    ) -> Vec<Request> {
        let n_arrived =
            self.pending.iter().take_while(|r| r.arrival_s <= now_s).count();
        if n_arrived == 0 || free_slots == 0 {
            return Vec::new();
        }
        let needs: Vec<usize> =
            self.pending.iter().take(n_arrived).map(|r| page_need(r)).collect();
        // candidate order: highest priority, then cheapest, arrival/id as
        // deterministic ties
        let mut order: Vec<usize> = (0..n_arrived).collect();
        order.sort_by(|&a, &b| {
            self.pending[b]
                .priority
                .cmp(&self.pending[a].priority)
                .then(needs[a].cmp(&needs[b]))
                // total_cmp: arrival times are finite in practice, but a
                // NaN must not panic the scheduler (it sorts last-ish
                // deterministically instead)
                .then(self.pending[a].arrival_s.total_cmp(&self.pending[b].arrival_s))
                .then(self.pending[a].id.cmp(&self.pending[b].id))
        });

        let head_id = self.pending[0].id;
        let starving =
            self.starved_id == Some(head_id) && self.head_skips >= STARVATION_ROUNDS;

        let mut budget = free_pages;
        let mut picked: Vec<usize> = Vec::new();
        for &i in &order {
            if picked.len() >= free_slots {
                break;
            }
            if starving && picked.is_empty() && i != 0 {
                // the starving head is served first or nobody is
                if needs[0] > budget {
                    break;
                }
                continue;
            }
            if needs[i] <= budget {
                budget -= needs[i];
                picked.push(i);
            }
        }

        // starvation bookkeeping: did this round bypass the head again?
        if picked.contains(&0) {
            self.starved_id = None;
            self.head_skips = 0;
        } else if !picked.is_empty() {
            if self.starved_id == Some(head_id) {
                self.head_skips += 1;
            } else {
                self.starved_id = Some(head_id);
                self.head_skips = 1;
            }
        }

        // extract in candidate order (indices shift as we remove)
        picked.sort_unstable();
        let mut out: Vec<(usize, Request)> = Vec::with_capacity(picked.len());
        for (removed, &i) in picked.iter().enumerate() {
            let Some(req) = self.pending.remove(i - removed) else {
                debug_assert!(false, "picked index {i} out of range after {removed} removals");
                continue;
            };
            out.push((i, req));
        }
        // hand back in selection (cheapest-first) order, deterministically;
        // rank[i] = i's position in `order` (every picked index came from
        // `order`, so the usize::MAX sentinel is never compared)
        let mut rank = vec![usize::MAX; n_arrived];
        for (pos, &o) in order.iter().enumerate() {
            rank[o] = pos;
        }
        out.sort_by_key(|&(i, _)| rank[i]);
        out.into_iter().map(|(_, r)| r).collect()
    }

    pub fn n_pending(&self) -> usize {
        self.pending.len()
    }

    pub fn n_submitted(&self) -> u64 {
        self.n_submitted
    }

    /// Arrival time of the next queued request (for clock fast-forward
    /// when the engine is idle).
    pub fn next_arrival_s(&self) -> Option<f64> {
        self.pending.front().map(|r| r.arrival_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unit page demand + unbounded budget: the slot-count FIFO the
    /// engine used before paging.
    fn admit_slots(s: &mut Scheduler, now_s: f64, free_slots: usize) -> Vec<Request> {
        s.admit(now_s, free_slots, usize::MAX, &|_| 1)
    }

    #[test]
    fn fifo_admission_respects_arrivals_and_slots() {
        let mut s = Scheduler::new();
        let a = s.submit(vec![1], 4, 0.0);
        let b = s.submit(vec![2], 4, 1.0);
        let c = s.submit(vec![3], 4, 2.0);
        assert_eq!([a, b, c], [0, 1, 2]);
        assert_eq!(s.n_pending(), 3);

        // nothing arrived before t=0? a has
        let got = admit_slots(&mut s, 0.5, 8);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![a]);
        // b+c arrived by t=2 but only one slot free
        let got = admit_slots(&mut s, 2.0, 1);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![b]);
        assert_eq!(s.next_arrival_s(), Some(2.0));
        let got = admit_slots(&mut s, 2.0, 1);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![c]);
        assert_eq!(s.n_pending(), 0);
        assert_eq!(s.n_submitted(), 3);
    }

    #[test]
    fn head_of_line_blocks_until_arrival() {
        let mut s = Scheduler::new();
        s.submit(vec![1], 4, 5.0);
        s.submit(vec![2], 4, 6.0);
        assert!(admit_slots(&mut s, 4.9, 8).is_empty(), "nothing has arrived yet");
        assert_eq!(s.n_pending(), 2);
        let got = admit_slots(&mut s, 10.0, 8);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].id, 0, "queue order preserved");
    }

    #[test]
    fn out_of_order_submissions_sort_by_arrival() {
        let mut s = Scheduler::new();
        let late = s.submit(vec![1], 4, 9.0);
        let early = s.submit(vec![2], 4, 1.0);
        let got = admit_slots(&mut s, 100.0, 8);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![early, late]);
    }

    #[test]
    fn admission_is_gated_by_page_budget() {
        let mut s = Scheduler::new();
        let big = s.submit(vec![0; 64], 4, 0.0);
        let small = s.submit(vec![0; 4], 4, 0.0);
        // 3 pages free: the 5-page head cannot start, the 1-page job can
        let need = |r: &Request| r.prompt.len().div_ceil(16) + 1;
        let got = s.admit(1.0, 8, 3, &need);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![small]);
        assert_eq!(s.n_pending(), 1, "the big request stays queued, not dropped");
        // with room, the head goes through
        let got = s.admit(1.0, 8, 8, &need);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![big]);
    }

    #[test]
    fn shortest_job_first_with_arrival_ties() {
        let mut s = Scheduler::new();
        let long = s.submit(vec![0; 40], 4, 0.0);
        let short_a = s.submit(vec![0; 4], 4, 0.0);
        let short_b = s.submit(vec![0; 4], 4, 0.0);
        let need = |r: &Request| r.prompt.len().div_ceil(16);
        let got = s.admit(0.0, 3, usize::MAX, &need);
        assert_eq!(
            got.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![short_a, short_b, long],
            "cheapest first; equals keep submission order"
        );
    }

    #[test]
    fn priority_outranks_shortest_job() {
        let mut s = Scheduler::new();
        let cheap_low = s.submit(vec![0; 4], 4, 0.0);
        let costly_high =
            s.submit_prio(vec![0; 40], 4, 0.0, 2, SamplingParams::default());
        let cheap_mid = s.submit_prio(vec![0; 4], 4, 0.0, 1, SamplingParams::default());
        let need = |r: &Request| r.prompt.len().div_ceil(16);
        let got = s.admit(0.0, 3, usize::MAX, &need);
        assert_eq!(
            got.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![costly_high, cheap_mid, cheap_low],
            "priority first, page demand only breaks ties within a tier"
        );
    }

    #[test]
    fn requeue_keeps_id_arrival_and_resume_state() {
        let mut s = Scheduler::new();
        let a = s.submit(vec![1], 8, 0.0);
        let b = s.submit(vec![2], 8, 5.0);
        let mut got = admit_slots(&mut s, 10.0, 2);
        assert_eq!(got.len(), 2);
        // preempt `a` after two generated tokens
        let mut victim = got.remove(0);
        assert_eq!(victim.id, a);
        victim.generated = vec![7, 9];
        victim.n_preemptions = 1;
        victim.first_token_s = Some(0.5);
        assert_eq!(s.n_requeued(), 0, "fresh submissions never count as requeues");
        s.requeue(victim);
        assert_eq!(s.n_pending(), 1);
        assert_eq!(s.n_submitted(), 2, "a requeue is not a new submission");
        assert_eq!(s.n_requeued(), 1);
        assert_eq!(s.next_arrival_s(), Some(0.0), "original arrival preserved");
        let got = admit_slots(&mut s, 10.0, 2);
        assert_eq!(got[0].id, a);
        assert_ne!(got[0].id, b);
        assert_eq!(got[0].generated, vec![7, 9], "resume state survives the queue");
        assert_eq!(got[0].n_preemptions, 1);
        assert_eq!(got[0].first_token_s, Some(0.5));
    }

    #[test]
    fn requeued_victim_sorts_by_original_arrival() {
        let mut s = Scheduler::new();
        let old = s.submit(vec![1], 8, 0.0);
        let _mid = s.submit(vec![2], 8, 1.0);
        let mut got = admit_slots(&mut s, 2.0, 1);
        let victim = got.remove(0);
        assert_eq!(victim.id, old);
        s.submit(vec![3], 8, 2.0);
        s.requeue(victim);
        // the victim's t=0 arrival puts it back at the queue head
        let got = admit_slots(&mut s, 3.0, 3);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>()[0], old);
    }

    #[test]
    fn bypassed_head_is_eventually_forced_through() {
        let mut s = Scheduler::new();
        let long = s.submit(vec![0; 64], 8, 0.0);
        let need = |r: &Request| r.prompt.len().div_ceil(16);
        // a stream of short jobs keeps fitting the 2-page budget; the
        // 4-page head is bypassed until the starvation guard trips and
        // admission goes quiet (head or nothing)
        let mut rounds = 0u32;
        loop {
            s.submit(vec![0; 8], 4, 0.0);
            let got = s.admit(1.0, 1, 2, &need);
            if got.is_empty() {
                break; // guard tripped: nothing but the head may start
            }
            assert!(got.iter().all(|r| r.id != long), "2 pages cannot fit the head");
            rounds += 1;
            assert!(rounds <= 2 * STARVATION_ROUNDS, "starvation guard never tripped");
        }
        // while starving, shorter jobs stay blocked no matter how many queue
        for _ in 0..3 {
            assert!(s.admit(1.0, 1, 2, &need).is_empty(), "head or nothing");
        }
        // once the budget covers the head (pool drained), it goes first
        let got = s.admit(1.0, 2, 8, &need);
        assert_eq!(got[0].id, long, "the starving head is admitted first");
    }
}
