//! Slot-pooled K/V cache storage for the serving engine.
//!
//! One [`KvPool`] owns the K/V backing store for every concurrently
//! resident sequence: `n_slots` slots, each holding `n_layers` planes of
//! `[capacity, d]` rotary-encoded keys and raw values (`d = n_heads ·
//! d_head`). Storage is allocated once up front — admission, decoding and
//! eviction never touch the allocator, they only move slot ids between
//! the free stack and the active set.
//!
//! The pool is the single source of truth for per-slot lengths. Kernel
//! calls borrow ephemeral [`SeqKv`] views ([`KvPool::views`]) that are
//! rebuilt from the pool's lengths each step; after a successful step the
//! caller syncs the pool via [`KvPool::set_len`] (prefill) or
//! [`KvPool::advance`] (decode).
//!
//! Memory: `bytes() = 2 · n_slots · n_layers · capacity · d · 4` — the
//! same quantity [`crate::memory::kv_cache_bytes`] models and
//! `MemoryReport::with_kv_cache` surfaces in the capacity accounting.

use anyhow::{anyhow, Result};

use crate::model::forward::{KvLayer, SeqKv};
use crate::runtime::ModelSpec;

/// Fixed-capacity pool of per-sequence K/V cache slots.
pub struct KvPool {
    n_layers: usize,
    d: usize,
    capacity: usize,
    n_slots: usize,
    /// `[slot, layer, capacity, d]` row-major (one slot's planes are
    /// contiguous).
    k: Vec<f32>,
    v: Vec<f32>,
    lens: Vec<usize>,
    in_use: Vec<bool>,
    free: Vec<usize>,
    peak_in_use: usize,
}

impl KvPool {
    /// Pool with per-slot capacity equal to the model context length.
    pub fn new(model: &ModelSpec, n_slots: usize) -> Self {
        Self::with_capacity(model, n_slots, model.seq_len)
    }

    /// Pool with an explicit per-slot row capacity.
    pub fn with_capacity(model: &ModelSpec, n_slots: usize, capacity: usize) -> Self {
        let d = model.n_heads * model.d_head;
        let total = n_slots * model.n_layers * capacity * d;
        Self {
            n_layers: model.n_layers,
            d,
            capacity,
            n_slots,
            k: vec![0.0; total],
            v: vec![0.0; total],
            lens: vec![0; n_slots],
            in_use: vec![false; n_slots],
            // pop order: lowest slot id first (purely cosmetic/determinism)
            free: (0..n_slots).rev().collect(),
            peak_in_use: 0,
        }
    }

    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    /// Rows (tokens) each slot can hold.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Cached tokens in a slot.
    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    pub fn is_empty(&self, slot: usize) -> bool {
        self.lens[slot] == 0
    }

    /// Highest number of slots simultaneously in use since creation.
    pub fn peak_in_use(&self) -> usize {
        self.peak_in_use
    }

    /// Backing-store bytes (K + V), the measured KV footprint.
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    /// Claim a free slot (length reset to 0), or `None` when the pool is
    /// fully occupied.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        self.lens[slot] = 0;
        self.in_use[slot] = true;
        let active = self.n_slots - self.free.len();
        if active > self.peak_in_use {
            self.peak_in_use = active;
        }
        Some(slot)
    }

    /// Return a finished sequence's slot to the pool.
    pub fn release(&mut self, slot: usize) {
        assert!(self.in_use[slot], "release of a slot that is not in use");
        self.in_use[slot] = false;
        self.lens[slot] = 0;
        self.free.push(slot);
    }

    /// Record that `slot` now caches `len` tokens (after a prefill).
    pub fn set_len(&mut self, slot: usize, len: usize) {
        assert!(self.in_use[slot] && len <= self.capacity);
        self.lens[slot] = len;
    }

    /// Record one more cached token (after a decode step).
    pub fn advance(&mut self, slot: usize) {
        assert!(self.in_use[slot] && self.lens[slot] < self.capacity);
        self.lens[slot] += 1;
    }

    fn plane_elems(&self) -> usize {
        self.capacity * self.d
    }

    /// Build per-layer mutable cache views for a set of **distinct**,
    /// in-use slots (one [`SeqKv`] per slot, `pos` taken from the pool's
    /// lengths). The views borrow the pool mutably, so they must be
    /// dropped before the lengths are synced back.
    pub fn views(&mut self, slots: &[usize]) -> Result<Vec<SeqKv<'_>>> {
        let mut seen = vec![false; self.n_slots];
        for &s in slots {
            if s >= self.n_slots {
                return Err(anyhow!("kv pool: slot {s} out of range 0..{}", self.n_slots));
            }
            if !self.in_use[s] {
                return Err(anyhow!("kv pool: slot {s} is not allocated"));
            }
            if seen[s] {
                return Err(anyhow!("kv pool: slot {s} requested twice"));
            }
            seen[s] = true;
        }
        let plane = self.plane_elems();
        let kp = self.k.as_mut_ptr();
        let vp = self.v.as_mut_ptr();
        Ok(slots
            .iter()
            .map(|&s| {
                let layers = (0..self.n_layers)
                    .map(|l| {
                        let off = (s * self.n_layers + l) * plane;
                        // safety: slots are distinct and in range (checked
                        // above), so every (slot, layer) plane is a disjoint
                        // subslice of k/v; lifetimes are tied to &mut self
                        unsafe {
                            KvLayer {
                                k: std::slice::from_raw_parts_mut(kp.add(off), plane),
                                v: std::slice::from_raw_parts_mut(vp.add(off), plane),
                            }
                        }
                    })
                    .collect();
                SeqKv { layers, pos: self.lens[s] }
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn model() -> ModelSpec {
        Manifest::builtin().preset("test-tiny").unwrap().model.clone()
    }

    #[test]
    fn alloc_release_cycles_slots() {
        let m = model();
        let mut pool = KvPool::new(&m, 2);
        assert_eq!(pool.n_free(), 2);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_ne!(a, b);
        assert!(pool.alloc().is_none(), "pool exhausted");
        pool.set_len(a, 5);
        assert_eq!(pool.len(a), 5);
        pool.release(a);
        assert_eq!(pool.n_free(), 1);
        let c = pool.alloc().unwrap();
        assert_eq!(c, a, "freed slot is reused");
        assert_eq!(pool.len(c), 0, "reused slot starts empty");
        assert_eq!(pool.peak_in_use(), 2);
    }

    #[test]
    fn views_are_disjoint_and_sized() {
        let m = model();
        let mut pool = KvPool::new(&m, 3);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        pool.set_len(b, 3);
        let d = m.n_heads * m.d_head;
        let mut views = pool.views(&[a, b]).unwrap();
        assert_eq!(views.len(), 2);
        assert_eq!(views[0].layers.len(), m.n_layers);
        assert_eq!(views[0].pos, 0);
        assert_eq!(views[1].pos, 3);
        assert_eq!(views[0].capacity(d), m.seq_len);
        // writes through one view land in that slot only
        views[0].layers[0].k[0] = 7.0;
        views[1].layers[0].k[0] = 9.0;
        drop(views);
        let views = pool.views(&[a]).unwrap();
        assert_eq!(views[0].layers[0].k[0], 7.0);
    }

    #[test]
    fn views_reject_bad_slot_sets() {
        let m = model();
        let mut pool = KvPool::new(&m, 2);
        let a = pool.alloc().unwrap();
        assert!(pool.views(&[a, a]).is_err(), "duplicate slot");
        assert!(pool.views(&[9]).is_err(), "out of range");
        let b = 1 - a;
        assert!(pool.views(&[b]).is_err(), "unallocated slot");
    }

    #[test]
    fn bytes_match_layout() {
        let m = model();
        let pool = KvPool::new(&m, 4);
        let d = m.n_heads * m.d_head;
        assert_eq!(pool.bytes(), 2 * 4 * m.n_layers * m.seq_len * d * 4);
    }
}
