//! Paged K/V cache storage for the serving engine.
//!
//! One [`KvPool`] owns the K/V backing store for every concurrently
//! resident sequence, split into fixed-size **pages** of
//! [`DEFAULT_PAGE_SIZE`] tokens. A page holds `page_size` rows of
//! rotary-encoded keys and raw values for **all** layers (`[page, layer,
//! page_size, d]` row-major, `d = n_heads · d_head`), so one refcount
//! covers a token run's whole-model K/V. Each slot maps logical rows to
//! pages through a per-slot **page table**; pages are claimed from a free
//! list on demand as decode advances and returned when the sequence
//! finishes — in-use bytes ([`KvPool::bytes`]) scale with tokens actually
//! cached, not `slots × capacity` ([`KvPool::capacity_bytes`], the old
//! slot model and still the worst case).
//!
//! Pages may be **shared** between slots (and with the serving engine's
//! prefix cache) via refcounts: a prompt stem common to N requests is
//! stored once, each slot's table pointing at the same pages. Shared
//! pages are read-only; before a sequence writes into a row of a shared
//! page, [`KvPool::make_row_writable`] copies that page out
//! (copy-on-write) so the writer gets an exclusive one. The backing store
//! is allocated once up front — page churn only moves ids between the
//! free list and the tables, never touches the allocator.
//!
//! The pool is the single source of truth for per-slot lengths. Kernel
//! calls borrow ephemeral [`KvView`] views ([`KvPool::views`]) that are
//! rebuilt from the pool's tables each step; after a successful step the
//! caller syncs the pool via [`KvPool::set_len`] (prefill) or
//! [`KvPool::advance`] (decode).
//!
//! Memory: [`crate::memory::kv_cache_bytes`] models the slot-capacity
//! worst case (`== capacity_bytes()` when the page size divides the
//! context length) and [`crate::memory::kv_page_bytes`] one page;
//! `MemoryReport::with_kv_cache` surfaces the measured peak.

use anyhow::{anyhow, Result};

use crate::model::forward::KvView;
use crate::runtime::ModelSpec;

/// Tokens per KV page. 16 balances internal fragmentation (≤15 wasted
/// rows per active sequence) against table length and free-list churn.
pub const DEFAULT_PAGE_SIZE: usize = 16;

/// Paged pool of K/V cache storage shared by all resident sequences.
pub struct KvPool {
    n_layers: usize,
    d: usize,
    /// Logical per-slot row capacity (the model context length).
    capacity: usize,
    page_size: usize,
    n_slots: usize,
    n_pages: usize,
    /// `[page, layer, page_size, d]` row-major.
    k: Vec<f32>,
    v: Vec<f32>,
    /// Per-page reference counts (0 = free).
    refc: Vec<u32>,
    /// Free page ids, pop order: lowest id first (determinism).
    free_pages: Vec<u32>,
    /// Per-slot page tables (row `r` lives in `tables[slot][r / page_size]`).
    tables: Vec<Vec<u32>>,
    lens: Vec<usize>,
    in_use: Vec<bool>,
    free_slots: Vec<usize>,
    peak_in_use: usize,
    peak_pages: usize,
    pages_allocated: u64,
    pages_released: u64,
    cow_copies: u64,
}

impl KvPool {
    /// Pool with per-slot capacity equal to the model context length and
    /// the default page size.
    pub fn new(model: &ModelSpec, n_slots: usize) -> Self {
        Self::with_capacity(model, n_slots, model.seq_len)
    }

    /// Pool with an explicit per-slot row capacity. Backs `n_slots` full
    /// sequences (the worst case), in pages of
    /// `min(DEFAULT_PAGE_SIZE, capacity)` tokens.
    pub fn with_capacity(model: &ModelSpec, n_slots: usize, capacity: usize) -> Self {
        let page_size = DEFAULT_PAGE_SIZE.min(capacity.max(1));
        Self::with_pages(model, n_slots, capacity, n_slots * capacity.div_ceil(page_size))
    }

    /// Pool with an explicit page count — **overcommitted** relative to
    /// the `n_slots × capacity` worst case, for engines that preempt
    /// running sequences instead of reserving worst-case memory up front.
    /// `n_pages` is raised to at least one full-context sequence, so a
    /// lone sequence can always run to completion (the no-deadlock floor).
    pub fn with_pages(
        model: &ModelSpec,
        n_slots: usize,
        capacity: usize,
        n_pages: usize,
    ) -> Self {
        let d = model.n_heads * model.d_head;
        let page_size = DEFAULT_PAGE_SIZE.min(capacity.max(1));
        let n_pages = n_pages.max(capacity.div_ceil(page_size));
        let total = n_pages * model.n_layers * page_size * d;
        Self {
            n_layers: model.n_layers,
            d,
            capacity,
            page_size,
            n_slots,
            n_pages,
            k: vec![0.0; total],
            v: vec![0.0; total],
            refc: vec![0; n_pages],
            free_pages: (0..n_pages as u32).rev().collect(),
            tables: vec![Vec::new(); n_slots],
            lens: vec![0; n_slots],
            in_use: vec![false; n_slots],
            free_slots: (0..n_slots).rev().collect(),
            peak_in_use: 0,
            peak_pages: 0,
            pages_allocated: 0,
            pages_released: 0,
            cow_copies: 0,
        }
    }

    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    /// Free **slots** (sequence identities, not memory).
    pub fn n_free(&self) -> usize {
        self.free_slots.len()
    }

    /// Rows (tokens) each slot can hold.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Tokens per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Pages on the free list.
    pub fn n_free_pages(&self) -> usize {
        self.free_pages.len()
    }

    /// Total pages the pool was provisioned with.
    pub fn n_pages(&self) -> usize {
        self.n_pages
    }

    /// Pages in `slot`'s table that only `slot` references — the pages a
    /// preemption of this sequence would return to the free list
    /// immediately (shared prefix pages just drop one reference).
    pub fn exclusive_pages(&self, slot: usize) -> usize {
        self.tables[slot].iter().filter(|&&p| self.refc[p as usize] == 1).count()
    }

    /// Pages currently backing cached rows (allocated, refcount ≥ 1).
    pub fn pages_in_use(&self) -> usize {
        self.n_pages - self.free_pages.len()
    }

    /// Highest `pages_in_use` since creation.
    pub fn peak_pages(&self) -> usize {
        self.peak_pages
    }

    /// Fresh page claims since creation (monotonic; a steady-state decode
    /// step that stays inside its last page claims none).
    pub fn pages_allocated(&self) -> u64 {
        self.pages_allocated
    }

    /// Pages returned to the free list since creation (monotonic;
    /// `pages_allocated - pages_released == pages_in_use` at any time).
    pub fn pages_released(&self) -> u64 {
        self.pages_released
    }

    /// Copy-on-write page copies since creation.
    pub fn cow_copies(&self) -> u64 {
        self.cow_copies
    }

    /// Pages needed to hold `rows` cached tokens.
    pub fn pages_for(&self, rows: usize) -> usize {
        rows.div_ceil(self.page_size)
    }

    /// Cached tokens in a slot.
    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    pub fn is_empty(&self, slot: usize) -> bool {
        self.lens[slot] == 0
    }

    /// Pages mapped by a slot's table.
    pub fn pages_held(&self, slot: usize) -> usize {
        self.tables[slot].len()
    }

    /// Rows a slot can cache without claiming another page.
    pub fn mapped_rows(&self, slot: usize) -> usize {
        self.tables[slot].len() * self.page_size
    }

    /// A slot's page table (row `r` lives in entry `r / page_size`).
    pub fn table(&self, slot: usize) -> &[u32] {
        &self.tables[slot]
    }

    /// Whether `slot` is currently allocated to a sequence.
    pub fn is_in_use(&self, slot: usize) -> bool {
        self.in_use[slot]
    }

    /// The free list's page ids (pop order: last first). Exposed for the
    /// shadow-state auditor, which re-checks that the list is in range,
    /// duplicate-free, and holds exactly the zero-refcount pages.
    pub fn free_page_ids(&self) -> &[u32] {
        &self.free_pages
    }

    /// A page's reference count (0 = free).
    pub fn page_ref(&self, page: u32) -> u32 {
        self.refc[page as usize]
    }

    /// Highest number of slots simultaneously in use since creation.
    pub fn peak_in_use(&self) -> usize {
        self.peak_in_use
    }

    /// Bytes per page (K + V, all layers).
    pub fn page_bytes(&self) -> usize {
        2 * self.n_layers * self.page_size * self.d * std::mem::size_of::<f32>()
    }

    /// **In-use** backing-store bytes (K + V of allocated pages) — the
    /// measured KV footprint, which grows with cached tokens and shrinks
    /// when sequences finish.
    pub fn bytes(&self) -> usize {
        self.pages_in_use() * self.page_bytes()
    }

    /// Full backing-store bytes — the slot-model worst case the pool was
    /// provisioned for (what `bytes()` used to report when every slot
    /// owned `capacity` rows unconditionally).
    pub fn capacity_bytes(&self) -> usize {
        self.n_pages * self.page_bytes()
    }

    /// Claim a free slot (length reset to 0, no pages mapped yet), or
    /// `None` when the pool is fully occupied.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free_slots.pop()?;
        debug_assert!(self.tables[slot].is_empty());
        self.lens[slot] = 0;
        self.in_use[slot] = true;
        let active = self.n_slots - self.free_slots.len();
        if active > self.peak_in_use {
            self.peak_in_use = active;
        }
        Some(slot)
    }

    /// Return a finished sequence's slot and its exclusive pages to the
    /// pool (shared pages just drop one reference).
    ///
    /// Double-releases and out-of-range slots are a caller bug — they
    /// panic under `debug_assertions` and return idempotently in release
    /// builds instead of corrupting the free lists (a slot pushed twice
    /// would later be handed to two sequences at once).
    pub fn release(&mut self, slot: usize) {
        if slot >= self.n_slots || !self.in_use[slot] {
            debug_assert!(false, "release of slot {slot} that is not in use");
            return;
        }
        let table = std::mem::take(&mut self.tables[slot]);
        for page in table {
            self.release_page(page);
        }
        self.in_use[slot] = false;
        self.lens[slot] = 0;
        self.free_slots.push(slot);
    }

    /// Record that `slot` now caches `len` tokens (after a prefill).
    pub fn set_len(&mut self, slot: usize, len: usize) {
        assert!(self.in_use[slot] && len <= self.capacity && len <= self.mapped_rows(slot));
        self.lens[slot] = len;
    }

    /// Record one more cached token (after a decode step).
    pub fn advance(&mut self, slot: usize) {
        assert!(self.in_use[slot] && self.lens[slot] < self.capacity);
        assert!(self.lens[slot] < self.mapped_rows(slot), "advance into an unmapped row");
        self.lens[slot] += 1;
    }

    fn alloc_page(&mut self) -> Result<u32> {
        let page = self
            .free_pages
            .pop()
            .ok_or_else(|| anyhow!("kv pool: out of pages ({} total)", self.n_pages))?;
        debug_assert_eq!(self.refc[page as usize], 0);
        self.refc[page as usize] = 1;
        self.pages_allocated += 1;
        let in_use = self.pages_in_use();
        if in_use > self.peak_pages {
            self.peak_pages = in_use;
        }
        Ok(page)
    }

    /// Take one more reference on a page (prefix-cache retention).
    pub fn retain_page(&mut self, page: u32) {
        debug_assert!(self.refc[page as usize] > 0, "retain of a free page");
        self.refc[page as usize] += 1;
    }

    /// Drop one reference; the page returns to the free list at zero.
    pub fn release_page(&mut self, page: u32) {
        let rc = &mut self.refc[page as usize];
        debug_assert!(*rc > 0, "release of a free page");
        *rc -= 1;
        if *rc == 0 {
            self.pages_released += 1;
            self.free_pages.push(page);
        }
    }

    /// Map enough pages for `slot` to cache `rows` tokens. Errors when
    /// `rows` exceeds the slot capacity or the free list runs dry (the
    /// caller may free shareable pages — e.g. evict the prefix cache —
    /// and retry).
    pub fn ensure_room(&mut self, slot: usize, rows: usize) -> Result<()> {
        assert!(self.in_use[slot], "ensure_room on a free slot");
        if rows > self.capacity {
            return Err(anyhow!("kv pool: {rows} rows exceed the {}-row capacity", self.capacity));
        }
        while self.tables[slot].len() < self.pages_for(rows) {
            let page = self.alloc_page()?;
            self.tables[slot].push(page);
        }
        Ok(())
    }

    /// Extend `slot`'s (empty) table with shared pages covering `covered`
    /// already-computed rows — the prefix-sharing attach. Each page gains
    /// a reference; none is copied.
    pub fn attach_shared(&mut self, slot: usize, pages: &[u32], covered: usize) {
        assert!(self.in_use[slot] && self.tables[slot].is_empty() && self.lens[slot] == 0);
        assert!(covered <= pages.len() * self.page_size && covered <= self.capacity);
        for &page in pages {
            self.retain_page(page);
            self.tables[slot].push(page);
        }
        self.lens[slot] = covered;
        let in_use = self.pages_in_use();
        if in_use > self.peak_pages {
            self.peak_pages = in_use;
        }
    }

    /// Make the page holding `row` exclusively owned by `slot`, copying it
    /// out first when shared (**copy-on-write**). A no-op for unmapped
    /// rows (nothing to copy — `ensure_room` hands out exclusive pages)
    /// and for already-exclusive pages.
    pub fn make_row_writable(&mut self, slot: usize, row: usize) -> Result<()> {
        assert!(self.in_use[slot]);
        let idx = row / self.page_size;
        if idx >= self.tables[slot].len() {
            return Ok(());
        }
        let old = self.tables[slot][idx];
        if self.refc[old as usize] <= 1 {
            return Ok(());
        }
        let fresh = self.alloc_page()?;
        let elems = self.n_layers * self.page_size * self.d;
        let (src, dst) = (old as usize * elems, fresh as usize * elems);
        self.k.copy_within(src..src + elems, dst);
        self.v.copy_within(src..src + elems, dst);
        self.refc[old as usize] -= 1;
        self.tables[slot][idx] = fresh;
        self.cow_copies += 1;
        Ok(())
    }

    /// Build mutable cache views for a set of **distinct**, in-use slots
    /// (one [`KvView`] per slot, `pos` taken from the pool's lengths).
    /// The views borrow the pool mutably, so they must be dropped before
    /// the lengths are synced back.
    ///
    /// Each view is guaranteed room for its next row (`len + 1`, the
    /// decode contract) — mapping a fresh page on a boundary if needed.
    /// Callers prefilling further than that call [`KvPool::ensure_room`]
    /// first. Errors if any page a kernel may write (covering rows
    /// `>= len`) is still shared — writers must run
    /// [`KvPool::make_row_writable`] beforehand.
    pub fn views(&mut self, slots: &[usize]) -> Result<Vec<KvView<'_>>> {
        let mut seen = vec![false; self.n_slots];
        for &s in slots {
            if s >= self.n_slots {
                return Err(anyhow!("kv pool: slot {s} out of range 0..{}", self.n_slots));
            }
            if !self.in_use[s] {
                return Err(anyhow!("kv pool: slot {s} is not allocated"));
            }
            if seen[s] {
                return Err(anyhow!("kv pool: slot {s} requested twice"));
            }
            seen[s] = true;
        }
        for &s in slots {
            let next = (self.lens[s] + 1).min(self.capacity);
            self.ensure_room(s, next)?;
            // pages covering writable rows (>= len) must be exclusive;
            // fully-covered pages may be shared (read-only under the
            // KvView safety discipline)
            for (pi, &page) in self.tables[s].iter().enumerate() {
                if (pi + 1) * self.page_size > self.lens[s] && self.refc[page as usize] != 1 {
                    return Err(anyhow!(
                        "kv pool: slot {s} would write shared page {page} (make_row_writable first)"
                    ));
                }
            }
        }
        let kp = self.k.as_mut_ptr();
        let vp = self.v.as_mut_ptr();
        // SAFETY: `kp`/`vp` point into this pool's backing store, which
        // the views' `&mut self` borrow keeps alive and un-reallocated
        // for their whole lifetime. Slots are distinct and in use
        // (checked above), every table entry is < n_pages (pool
        // invariant), writable pages (covering rows >= len) are exclusive
        // to their slot (checked above), and shared pages are only ever
        // read — the KvView constructor contract.
        Ok(slots
            .iter()
            .map(|&s| unsafe {
                KvView::from_pool(
                    kp,
                    vp,
                    self.tables[s].clone(),
                    self.lens[s],
                    self.page_size,
                    self.n_layers,
                    self.d,
                    self.capacity,
                )
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn model() -> ModelSpec {
        Manifest::builtin().preset("test-tiny").unwrap().model.clone()
    }

    #[test]
    fn alloc_release_cycles_slots() {
        let m = model();
        let mut pool = KvPool::new(&m, 2);
        assert_eq!(pool.n_free(), 2);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_ne!(a, b);
        assert!(pool.alloc().is_none(), "pool exhausted");
        pool.ensure_room(a, 5).unwrap();
        pool.set_len(a, 5);
        assert_eq!(pool.len(a), 5);
        pool.release(a);
        assert_eq!(pool.n_free(), 1);
        let c = pool.alloc().unwrap();
        assert_eq!(c, a, "freed slot is reused");
        assert_eq!(pool.len(c), 0, "reused slot starts empty");
        assert_eq!(pool.pages_held(c), 0, "reused slot starts with no pages");
        assert_eq!(pool.peak_in_use(), 2);
    }

    #[test]
    fn views_are_disjoint_and_sized() {
        let m = model();
        let d = m.n_heads * m.d_head;
        let mut pool = KvPool::new(&m, 3);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        pool.ensure_room(b, 3).unwrap();
        pool.set_len(b, 3);
        let mut views = pool.views(&[a, b]).unwrap();
        assert_eq!(views.len(), 2);
        assert_eq!(views[0].n_layers(), m.n_layers);
        assert_eq!(views[0].pos, 0);
        assert_eq!(views[1].pos, 3);
        assert_eq!(views[0].capacity(), m.seq_len);
        // writes through one view land in that slot only
        let (krow, vrow) = (vec![7.0f32; d], vec![70.0f32; d]);
        views[0].write_rows(0, 0, &krow, &vrow).unwrap();
        let (krow_b, vrow_b) = (vec![9.0f32; d], vec![90.0f32; d]);
        views[1].write_rows(0, 0, &krow_b, &vrow_b).unwrap();
        drop(views);
        let views = pool.views(&[a]).unwrap();
        let (mut kr, mut vr) = (vec![0.0f32; d], vec![0.0f32; d]);
        views[0].read_rows(0, 1, &mut kr, &mut vr).unwrap();
        assert_eq!(kr, krow);
        assert_eq!(vr, vrow);
    }

    #[test]
    fn views_reject_bad_slot_sets() {
        let m = model();
        let mut pool = KvPool::new(&m, 2);
        let a = pool.alloc().unwrap();
        assert!(pool.views(&[a, a]).is_err(), "duplicate slot");
        assert!(pool.views(&[9]).is_err(), "out of range");
        let b = 1 - a;
        assert!(pool.views(&[b]).is_err(), "unallocated slot");
    }

    #[test]
    fn bytes_scale_with_pages_not_capacity() {
        let m = model();
        let mut pool = KvPool::new(&m, 4);
        let d = m.n_heads * m.d_head;
        let page_bytes = 2 * m.n_layers * pool.page_size() * d * 4;
        // the full store still covers slots × capacity
        assert_eq!(
            pool.capacity_bytes(),
            4 * m.seq_len.div_ceil(pool.page_size()) * page_bytes
        );
        assert_eq!(pool.bytes(), 0, "nothing cached, nothing in use");
        let a = pool.alloc().unwrap();
        assert_eq!(pool.bytes(), 0, "a bare slot maps no pages");
        pool.ensure_room(a, 1).unwrap();
        assert_eq!(pool.bytes(), page_bytes);
        pool.ensure_room(a, pool.page_size() + 1).unwrap();
        assert_eq!(pool.bytes(), 2 * page_bytes, "second page on crossing the boundary");
        assert!(pool.bytes() <= pool.capacity_bytes());
        pool.release(a);
        assert_eq!(pool.bytes(), 0, "release returns pages to the free list");
        assert_eq!(pool.peak_pages(), 2);
        assert_eq!(pool.pages_released(), pool.pages_allocated(), "books balance when idle");
        assert_eq!(
            pool.pages_allocated() - pool.pages_released(),
            pool.pages_in_use() as u64
        );
    }

    #[test]
    fn decode_views_auto_map_the_next_row() {
        let m = model();
        let mut pool = KvPool::new(&m, 1);
        let a = pool.alloc().unwrap();
        let p = pool.page_size();
        pool.ensure_room(a, p).unwrap();
        pool.set_len(a, p); // boundary: next row needs a fresh page
        let grabbed = pool.pages_allocated();
        let views = pool.views(&[a]).unwrap();
        assert!(views[0].mapped_rows() >= p + 1);
        drop(views);
        assert_eq!(pool.pages_allocated(), grabbed + 1);
        // within-page steps claim nothing: steady-state decode is
        // allocation-free at page granularity too
        pool.advance(a);
        let grabbed = pool.pages_allocated();
        for _ in 0..p - 1 {
            let v = pool.views(&[a]).unwrap();
            drop(v);
            pool.advance(a);
        }
        assert_eq!(pool.pages_allocated(), grabbed, "no page churn inside a page");
    }

    #[test]
    fn shared_pages_refcount_and_cow() {
        let m = model();
        let d = m.n_heads * m.d_head;
        let mut pool = KvPool::new(&m, 3);
        let p = pool.page_size();
        let a = pool.alloc().unwrap();
        pool.ensure_room(a, p + 1).unwrap();
        pool.set_len(a, p + 1);
        let stem = pool.table(a)[0];
        // b shares a's first page (a full, read-only stem page)
        let b = pool.alloc().unwrap();
        pool.attach_shared(b, &[stem], p);
        assert_eq!(pool.page_ref(stem), 2);
        assert_eq!(pool.len(b), p);
        // b decodes on: the next row sits in a fresh exclusive page, the
        // shared one is never written
        let views = pool.views(&[b]).unwrap();
        assert_eq!(views[0].pos, p);
        drop(views);
        pool.advance(b);
        assert_ne!(pool.table(b)[1], stem);
        // a COW write into the shared page forks it first
        let c = pool.alloc().unwrap();
        pool.attach_shared(c, &[stem], p - 1); // last stem row diverges
        let before = pool.cow_copies();
        assert!(pool.views(&[c]).is_err(), "writable shared page must be rejected");
        pool.make_row_writable(c, p - 1).unwrap();
        assert_eq!(pool.cow_copies(), before + 1);
        assert_ne!(pool.table(c)[0], stem);
        assert_eq!(pool.page_ref(stem), 2, "fork dropped c's reference");
        // the fork carried the page contents over
        let mut kv = (vec![0.0f32; (p - 1) * d], vec![0.0f32; (p - 1) * d]);
        let views = pool.views(&[c]).unwrap();
        views[0].read_rows(0, p - 1, &mut kv.0, &mut kv.1).unwrap();
        drop(views);
        let mut kv_a = (vec![0.0f32; (p - 1) * d], vec![0.0f32; (p - 1) * d]);
        let views = pool.views(&[a]).unwrap();
        views[0].read_rows(0, p - 1, &mut kv_a.0, &mut kv_a.1).unwrap();
        drop(views);
        assert_eq!(kv, kv_a);
        // releases unwind the sharing without double-freeing
        pool.release(b);
        pool.release(c);
        assert_eq!(pool.page_ref(stem), 1);
        pool.release(a);
        assert_eq!(pool.bytes(), 0);
        assert_eq!(pool.n_free_pages(), pool.pages_for(m.seq_len) * 3);
    }

    #[test]
    fn page_limited_pools_floor_at_one_full_sequence() {
        let m = model();
        let per_seq = m.seq_len.div_ceil(DEFAULT_PAGE_SIZE.min(m.seq_len));
        // overcommit: 3 slots share fewer pages than 3 worst cases
        let pool = KvPool::with_pages(&m, 3, m.seq_len, per_seq + 1);
        assert_eq!(pool.n_pages(), per_seq + 1);
        assert!(pool.n_pages() < 3 * per_seq);
        // a degenerate request is raised to the single-sequence floor
        let pool = KvPool::with_pages(&m, 3, m.seq_len, 1);
        assert_eq!(pool.n_pages(), per_seq, "one full sequence must always fit");
        // the default constructor is the worst case
        let pool = KvPool::new(&m, 3);
        assert_eq!(pool.n_pages(), 3 * per_seq);
    }

    #[test]
    fn exclusive_pages_ignore_shared_prefix_pages() {
        let m = model();
        let mut pool = KvPool::new(&m, 2);
        let p = pool.page_size();
        let a = pool.alloc().unwrap();
        pool.ensure_room(a, p + 1).unwrap();
        pool.set_len(a, p + 1);
        assert_eq!(pool.exclusive_pages(a), 2);
        // b shares a's first page: neither slot owns it exclusively
        let stem = pool.table(a)[0];
        let b = pool.alloc().unwrap();
        pool.attach_shared(b, &[stem], p);
        assert_eq!(pool.exclusive_pages(a), 1);
        assert_eq!(pool.exclusive_pages(b), 0);
        pool.release(b);
        assert_eq!(pool.exclusive_pages(a), 2, "release restores exclusivity");
        pool.release(a);
    }

    #[cfg(not(debug_assertions))]
    #[test]
    fn release_is_idempotent_in_release_builds() {
        // regression: a double release used to push the slot onto the
        // free list twice, handing it to two sequences at once
        let m = model();
        let mut pool = KvPool::new(&m, 2);
        let a = pool.alloc().unwrap();
        pool.ensure_room(a, 1).unwrap();
        pool.release(a);
        pool.release(a); // double release: ignored
        pool.release(9); // out of range: ignored
        assert_eq!(pool.n_free(), 2);
        assert_eq!(pool.n_free_pages(), 2 * m.seq_len.div_ceil(pool.page_size()));
        let b = pool.alloc().unwrap();
        let c = pool.alloc().unwrap();
        assert_ne!(b, c, "a double-released slot must not be handed out twice");
        assert!(pool.alloc().is_none());
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "not in use")]
    fn release_twice_panics_in_debug_builds() {
        let m = model();
        let mut pool = KvPool::new(&m, 2);
        let a = pool.alloc().unwrap();
        pool.release(a);
        pool.release(a);
    }
}
