//! KV-cached serving subsystem: paged caches, prefix sharing, sampling,
//! and continuous batching.
//!
//! Four layers (bottom-up):
//!
//! * **Incremental kernels** — [`crate::model::forward::prefill_in`] and
//!   [`crate::model::forward::decode_step_kv_in`]: one forward per prompt
//!   (or per prompt *suffix*, continuing a cached prefix), then one
//!   single-token batched step per generated token, attending over paged
//!   K/V caches through per-sequence page tables. Exposed across backends
//!   as the `prefill` / `decode_step_kv` artifact entries.
//! * **[`KvPool`]** (`serve::kv`) — paged cache storage: fixed-size pages
//!   ([`kv::DEFAULT_PAGE_SIZE`] tokens), per-slot page tables, on-demand
//!   allocation as decode advances, refcounted sharing with copy-on-write
//!   — in-use bytes scale with cached tokens, not `slots × capacity`. Its
//!   footprint feeds `MemoryReport::with_kv_cache`.
//! * **[`PrefixCache`]** (`serve::prefix`) — retains full pages of
//!   finished prompts keyed by their token runs, so N requests sharing a
//!   system-prompt stem store and prefill it once (LRU-evicted back to
//!   the pool under page pressure).
//! * **[`Scheduler`] + [`ServeEngine`]** (`serve::scheduler` /
//!   `serve::engine`) — a request queue admitted by **free pages**,
//!   highest [`Request::priority`] first with a shortest-job tiebreak
//!   (plus an anti-starvation guard), and a mixed prefill+decode
//!   iteration loop that admits new prompts mid-decode and reports TTFT /
//!   per-token latency / throughput. Admission reserves pages
//!   optimistically by default ([`Reservation`]): a mid-decode page
//!   shortfall **preempts** a running sequence (lowest priority, most
//!   exclusive pages, fewest cached tokens), parks its full pages in the
//!   prefix cache and requeues it — resumption re-feeds prompt +
//!   generated tokens and rejoins the sampling stream at the same step,
//!   so output is bit-identical to an uninterrupted run. Requests carry
//!   [`SamplingParams`] (temperature / top-k / top-p over the
//!   deterministic [`crate::util::rng::Rng`], plus stop sequences);
//!   greedy is the `temperature == 0` special case.
//!
//! The [`KvBackend`] trait is the seam between the engine and a compute
//! backend. [`crate::runtime::ReferenceBackend`] implements it in-place
//! over its workspace arena (zero steady-state decode allocations,
//! chunked prefill supported); the PJRT `Engine` (cargo feature `pjrt`)
//! implements it functionally through the lowered `prefill` /
//! `decode_step_kv` artifacts (cache-in/cache-out, pending
//! device-resident caches).
//!
//! Parity contract: KV-cached greedy decode is **token-for-token
//! identical** to the retained full-reforward oracle
//! (`Evaluator::generate_oracle` over the `decode_step` artifact), with
//! or without prefix sharing, and per-row results are independent of
//! batch-mates — so scheduler output does not depend on arrival
//! interleaving. Sampled decode is bit-reproducible from
//! `SamplingParams::seed` regardless of batch composition — and both
//! properties survive preemption: a preempted-and-resumed sequence emits
//! the same tokens as an uninterrupted run. Pinned in
//! `tests/serve_decode.rs` and `tests/serve_sampling.rs`.

pub mod engine;
pub mod kv;
pub mod prefix;
pub mod sampling;
pub mod scheduler;

pub use engine::{Reservation, Response, ServeConfig, ServeEngine, ServeStats};
pub use kv::{KvPool, DEFAULT_PAGE_SIZE};
pub use prefix::PrefixCache;
pub use sampling::{sample_token, stop_len, SamplingParams};
pub use scheduler::{Request, Scheduler};

use anyhow::Result;

use crate::model::forward::{self, KvView};
use crate::runtime::{Backend, Preset, RefTensor, ReferenceBackend};

/// A compute backend that can run the KV-cached serving path.
///
/// `blocks` are the uploaded model weights (same buffers `execute` takes);
/// cache views come from a host-side [`KvPool`]. Implementations must
/// keep the greedy parity contract: logits bit-equal to what the
/// full-reforward `decode_step` entry produces for the same sequence.
pub trait KvBackend: Backend {
    /// Run `prompt` once, filling `seq`'s cache rows `pos..pos+len`;
    /// returns the last position's logits `[vocab]`. `seq.pos > 0`
    /// continues a partially-cached sequence (only meaningful when
    /// [`KvBackend::supports_chunked_prefill`] is true). Advances
    /// `seq.pos` past the prompt (the caller syncs its pool).
    fn kv_prefill(
        &self,
        preset: &Preset,
        blocks: &[Self::Buffer],
        prompt: &[i32],
        seq: &mut KvView<'_>,
    ) -> Result<Vec<f32>>;

    /// Advance each sequence by one token (`tokens[i]` lands at
    /// `seqs[i].pos`); returns next-token logits `[n, vocab]`. Advances
    /// each `seq.pos` by one.
    fn kv_decode_step(
        &self,
        preset: &Preset,
        blocks: &[Self::Buffer],
        tokens: &[i32],
        seqs: &mut [KvView<'_>],
    ) -> Result<Vec<f32>>;

    /// Whether [`KvBackend::kv_prefill`] accepts `seq.pos > 0`
    /// (continuing a cached prefix). Backends running the single-shot
    /// functional artifact return false; the engine then skips
    /// prefix-cache attachment and prefills whole prompts.
    fn supports_chunked_prefill(&self) -> bool {
        false
    }
}

/// Borrow the weight handles as f32 slices (guards keep the dynamic
/// borrows alive while the kernels run — handles are `RefCell`-backed).
fn ref_guards<'a>(blocks: &'a [RefTensor]) -> Result<Vec<std::cell::Ref<'a, [f32]>>> {
    blocks.iter().map(|b| b.as_f32()).collect()
}

/// In-place fast path: the kernels run directly against the backend's
/// workspace arena, so steady-state decode steps allocate nothing.
impl KvBackend for ReferenceBackend {
    fn kv_prefill(
        &self,
        preset: &Preset,
        blocks: &[RefTensor],
        prompt: &[i32],
        seq: &mut KvView<'_>,
    ) -> Result<Vec<f32>> {
        let guards = ref_guards(blocks)?;
        let flats: Vec<&[f32]> = guards.iter().map(|g| &**g).collect();
        self.with_workspace(|ws| {
            forward::prefill_in(ws, &preset.model, &preset.blocks, &flats, prompt, seq)
        })
    }

    fn kv_decode_step(
        &self,
        preset: &Preset,
        blocks: &[RefTensor],
        tokens: &[i32],
        seqs: &mut [KvView<'_>],
    ) -> Result<Vec<f32>> {
        let guards = ref_guards(blocks)?;
        let flats: Vec<&[f32]> = guards.iter().map(|g| &**g).collect();
        self.with_workspace(|ws| {
            forward::decode_step_kv_in(ws, &preset.model, &preset.blocks, &flats, tokens, seqs)
        })
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }
}

/// Functional path over the lowered `prefill` / `decode_step_kv`
/// artifacts: caches round-trip host↔device per call (XLA-style
/// cache-in/cache-out until device-resident cache buffers land). Compiled
/// against the in-tree `xla` stub in CI; runs for real only with actual
/// PJRT bindings. Single-shot prefill only (`supports_chunked_prefill`
/// stays false), so the engine never hands it a partially-cached
/// sequence.
#[cfg(feature = "pjrt")]
impl KvBackend for crate::runtime::Engine {
    fn kv_prefill(
        &self,
        preset: &Preset,
        blocks: &[Self::Buffer],
        prompt: &[i32],
        seq: &mut KvView<'_>,
    ) -> Result<Vec<f32>> {
        let d = preset.model.n_heads * preset.model.d_head;
        let t = prompt.len();
        // mirror the reference impl's contract: an over-long (or empty)
        // prompt is an error, not a panic in the cache scatter below
        let cap = seq.capacity();
        if t == 0 || t > cap {
            return Err(anyhow::anyhow!("prefill: prompt length {t} outside 1..={cap}"));
        }
        if seq.pos != 0 {
            return Err(anyhow::anyhow!(
                "prefill: the functional artifact cannot continue {} cached tokens",
                seq.pos
            ));
        }
        let exe = self.load_preset_exe(&preset.model.name, "prefill")?;
        let tok = self.upload_i32(prompt, &[1, t])?;
        let mut args: Vec<&Self::Buffer> = blocks.iter().collect();
        args.push(&tok);
        let mut out = self.execute_to_host(&exe, &args)?;
        let logits = out.take_vec(0)?;
        let k = out.take_vec(1)?;
        let v = out.take_vec(2)?;
        for l in 0..preset.model.n_layers {
            seq.write_rows(l, 0, &k[l * t * d..(l + 1) * t * d], &v[l * t * d..(l + 1) * t * d])?;
        }
        seq.pos = t;
        Ok(logits)
    }

    fn kv_decode_step(
        &self,
        preset: &Preset,
        blocks: &[Self::Buffer],
        tokens: &[i32],
        seqs: &mut [KvView<'_>],
    ) -> Result<Vec<f32>> {
        let d = preset.model.n_heads * preset.model.d_head;
        let n_layers = preset.model.n_layers;
        let exe = self.load_preset_exe(&preset.model.name, "decode_step_kv")?;
        let mut all = Vec::with_capacity(tokens.len() * preset.model.vocab);
        for (&tok, seq) in tokens.iter().zip(seqs.iter_mut()) {
            // functional cache of exactly pos+1 rows: the cached prefix
            // plus room for the new token (the artifact is length-agnostic
            // — per-position rotary values do not depend on table size)
            let rows = seq.pos + 1;
            let mut k_flat = vec![0.0f32; n_layers * rows * d];
            let mut v_flat = vec![0.0f32; n_layers * rows * d];
            for l in 0..n_layers {
                seq.read_rows(
                    l,
                    rows,
                    &mut k_flat[l * rows * d..(l + 1) * rows * d],
                    &mut v_flat[l * rows * d..(l + 1) * rows * d],
                )?;
            }
            let k_buf = self.upload_f32(&k_flat, &[k_flat.len()])?;
            let v_buf = self.upload_f32(&v_flat, &[v_flat.len()])?;
            let tok_buf = self.upload_i32(&[tok], &[1])?;
            let pos_buf = self.upload_i32(&[seq.pos as i32], &[1])?;
            let mut args: Vec<&Self::Buffer> = blocks.iter().collect();
            args.extend([&k_buf, &v_buf, &tok_buf, &pos_buf]);
            let mut out = self.execute_to_host(&exe, &args)?;
            all.extend(out.take_vec(0)?);
            let k_new = out.take_vec(1)?;
            let v_new = out.take_vec(2)?;
            let plane = k_new.len() / n_layers.max(1);
            for l in 0..n_layers {
                let ks = &k_new[l * plane..(l + 1) * plane];
                let vs = &v_new[l * plane..(l + 1) * plane];
                seq.write_rows(l, 0, ks, vs)?;
            }
            seq.pos += 1;
        }
        Ok(all)
    }
}

/// Decide the fate of a freshly-sampled token — the stop conditions of
/// the full-reforward oracle loop, written once and shared by the serving
/// engine and `Evaluator::generate` so cached decode can never drift from
/// `generate_oracle` (the sampled path reuses it verbatim: only where
/// `next` comes from differs — [`sample_token`] instead of argmax):
///
/// * a row that already emitted `max_new` tokens samples nothing more;
/// * a NaN-poisoned row (`next == None`) or an EOS stops without emitting;
/// * a full context (`cached >= capacity`) stops without emitting;
/// * otherwise the token is emitted, and the row finishes when it was the
///   `max_new`-th token or the context has no room to feed it back.
///
/// Returns `(token to emit, sequence finished)`; `cached` is the number
/// of tokens fed to the model so far (prompt + emitted predecessors).
pub fn greedy_step(
    next: Option<usize>,
    eos: i32,
    cached: usize,
    capacity: usize,
    n_generated: usize,
    max_new: usize,
) -> (Option<i32>, bool) {
    if n_generated >= max_new {
        return (None, true);
    }
    let next = match next {
        None => return (None, true),
        Some(n) => n as i32,
    };
    if next == eos || cached >= capacity {
        return (None, true);
    }
    let finished = n_generated + 1 >= max_new || cached + 1 >= capacity;
    (Some(next), finished)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_step_mirrors_oracle_stop_conditions() {
        let eos = 2;
        // plain emission, room to continue
        assert_eq!(greedy_step(Some(7), eos, 4, 10, 0, 5), (Some(7), false));
        // EOS never emitted
        assert_eq!(greedy_step(Some(2), eos, 4, 10, 0, 5), (None, true));
        // NaN-poisoned row (no finite argmax) stops
        assert_eq!(greedy_step(None, eos, 4, 10, 0, 5), (None, true));
        // full context: nothing can be placed
        assert_eq!(greedy_step(Some(7), eos, 10, 10, 0, 5), (None, true));
        // last placeable token is still emitted, then the row finishes
        assert_eq!(greedy_step(Some(7), eos, 9, 10, 0, 5), (Some(7), true));
        // max_new-th token is emitted, then the row finishes
        assert_eq!(greedy_step(Some(7), eos, 4, 10, 4, 5), (Some(7), true));
        // budget already spent (max_new == 0) samples nothing
        assert_eq!(greedy_step(Some(7), eos, 4, 10, 0, 0), (None, true));
    }
}
