//! Dirichlet sampling + weighted sampling without replacement.
//!
//! AdaGradSelect models block-selection probabilities as
//! `p ~ Dirichlet(f + δ)` where `f` are historical selection frequencies.
//! A Dirichlet draw is k independent `Gamma(α_i, 1)` draws normalized to
//! the simplex (Marsaglia–Tsang under the hood via `rand_distr`).
//!
//! Sampling k blocks *without replacement* according to `p` uses the
//! Efraimidis–Spirakis exponential-keys trick: draw `key_i = u_i^(1/p_i)`
//! and take the k largest keys — equivalent to sequential draws with
//! renormalization, in O(n log n) with no renormalization loop.

use crate::util::rng::Rng;

use super::sampling::gamma;

/// Draw `p ~ Dirichlet(alpha)`. Requires every `alpha_i > 0`.
pub fn sample_dirichlet(alpha: &[f64], rng: &mut Rng) -> Vec<f64> {
    assert!(!alpha.is_empty(), "empty alpha");
    let mut draws: Vec<f64> = alpha
        .iter()
        .map(|&a| {
            assert!(a > 0.0, "alpha must be positive, got {a}");
            // Gamma(a) can underflow to exactly 0.0 for tiny a; clamp so
            // the normalized vector stays inside the open simplex.
            gamma(a, rng).max(1e-300)
        })
        .collect();
    let sum: f64 = draws.iter().sum();
    for d in draws.iter_mut() {
        *d /= sum;
    }
    draws
}

/// Sample `k` distinct indices according to probabilities `p` (must sum to
/// ~1, all non-negative; zeros are never selected unless forced by k).
pub fn weighted_sample_without_replacement(p: &[f64], k: usize, rng: &mut Rng) -> Vec<usize> {
    assert!(k <= p.len(), "k={k} > n={}", p.len());
    // Efraimidis–Spirakis: key = ln(u)/w, take k largest (w=0 -> -inf).
    let mut keyed: Vec<(f64, usize)> = p
        .iter()
        .enumerate()
        .map(|(i, &w)| {
            let u: f64 = rng.gen_range_f64(1e-12, 1.0);
            let key = if w > 0.0 { u.ln() / w } else { f64::NEG_INFINITY };
            (key, i)
        })
        .collect();
    keyed.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut out: Vec<usize> = keyed[..k].iter().map(|&(_, i)| i).collect();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dirichlet_is_on_simplex() {
        let mut rng = Rng::seed_from_u64(0);
        for alpha in [vec![1.0; 5], vec![0.1, 10.0, 0.5], vec![100.0, 1.0]] {
            let p = sample_dirichlet(&alpha, &mut rng);
            assert_eq!(p.len(), alpha.len());
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn dirichlet_concentrates_on_large_alpha() {
        let mut rng = Rng::seed_from_u64(1);
        let alpha = vec![500.0, 1.0, 1.0, 1.0];
        let mean: f64 = (0..200)
            .map(|_| sample_dirichlet(&alpha, &mut rng)[0])
            .sum::<f64>()
            / 200.0;
        // E[p_0] = 500/503
        assert!((mean - 500.0 / 503.0).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn wswor_returns_k_distinct_sorted() {
        let mut rng = Rng::seed_from_u64(2);
        let p = vec![0.1; 10];
        for k in [1, 3, 10] {
            let s = weighted_sample_without_replacement(&p, k, &mut rng);
            assert_eq!(s.len(), k);
            let mut d = s.clone();
            d.dedup();
            assert_eq!(d.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn wswor_respects_weights() {
        let mut rng = Rng::seed_from_u64(3);
        // index 0 has 100x the weight of the others; with k=1 it should
        // dominate the draws.
        let mut p = vec![0.001; 11];
        p[0] = 0.1;
        let hits = (0..500)
            .filter(|_| weighted_sample_without_replacement(&p, 1, &mut rng)[0] == 0)
            .count();
        assert!(hits > 400, "hits {hits}");
    }

    #[test]
    fn wswor_zero_weight_excluded() {
        let mut rng = Rng::seed_from_u64(4);
        let p = vec![0.5, 0.0, 0.5, 0.0];
        for _ in 0..100 {
            let s = weighted_sample_without_replacement(&p, 2, &mut rng);
            assert_eq!(s, vec![0, 2]);
        }
    }
}
