//! Baseline selection strategies: Algorithm 1 plus the comparison points
//! used by the experiment harness.

use crate::util::rng::Rng;

use super::grad_norm::top_k_indices;
use super::{SelectionCtx, SelectionStrategy, StepPlan};

/// Full fine-tuning: every block, every step.
pub struct FullSelector {
    n_blocks: usize,
}

impl FullSelector {
    pub fn new(n_blocks: usize) -> Self {
        Self { n_blocks }
    }
}

impl SelectionStrategy for FullSelector {
    fn decide(&mut self, _ctx: &SelectionCtx) -> StepPlan {
        StepPlan::Decided((0..self.n_blocks).collect())
    }

    fn name(&self) -> String {
        "full".into()
    }
}

/// Algorithm 1 — Gradient-Guided Block Selection: top-k blocks by this
/// step's gradient norms (or by cumulative norms, the paper's phrasing for
/// the preliminary study; both are exposed for the ablation harness).
pub struct TopKSelector {
    k: usize,
    use_cumulative: bool,
    cumulative: Vec<f64>,
}

impl TopKSelector {
    pub fn new(n_blocks: usize, k: usize) -> Self {
        Self { k, use_cumulative: false, cumulative: vec![0.0; n_blocks] }
    }

    pub fn cumulative(n_blocks: usize, k: usize) -> Self {
        Self { k, use_cumulative: true, cumulative: vec![0.0; n_blocks] }
    }
}

impl SelectionStrategy for TopKSelector {
    fn decide(&mut self, _ctx: &SelectionCtx) -> StepPlan {
        // Algorithm 1 ranks on this step's norms — it can never skip the
        // backward pass (the cost AdaGradSelect's exploitation avoids).
        StepPlan::NeedsNorms
    }

    fn choose(&mut self, ctx: &SelectionCtx) -> Vec<usize> {
        assert_eq!(ctx.grad_norms.len(), self.cumulative.len(),
                   "TopKSelector needs per-block grad norms");
        for (c, g) in self.cumulative.iter_mut().zip(ctx.grad_norms) {
            *c += *g;
        }
        if self.use_cumulative {
            top_k_indices(&self.cumulative, self.k)
        } else {
            top_k_indices(ctx.grad_norms, self.k)
        }
    }

    fn needs_grad_norms(&self, _ctx: &SelectionCtx) -> bool {
        true
    }

    fn name(&self) -> String {
        if self.use_cumulative {
            format!("topk-cum(k={})", self.k)
        } else {
            format!("topk(k={})", self.k)
        }
    }
}

/// LISA-style uniform random layerwise sampling (no gradient signal).
pub struct RandomSelector {
    n_blocks: usize,
    k: usize,
    rng: Rng,
}

impl RandomSelector {
    pub fn new(n_blocks: usize, k: usize, seed: u64) -> Self {
        Self { n_blocks, k, rng: Rng::seed_from_u64(seed) }
    }
}

impl SelectionStrategy for RandomSelector {
    fn decide(&mut self, _ctx: &SelectionCtx) -> StepPlan {
        let mut idx: Vec<usize> = (0..self.n_blocks).collect();
        // partial Fisher-Yates for the first k
        for i in 0..self.k {
            let j = self.rng.gen_range(i, self.n_blocks);
            idx.swap(i, j);
        }
        let mut out = idx[..self.k].to_vec();
        out.sort_unstable();
        StepPlan::Decided(out)
    }

    fn name(&self) -> String {
        format!("random(k={})", self.k)
    }
}

/// Deterministic rotation over contiguous windows of k blocks.
pub struct RoundRobinSelector {
    n_blocks: usize,
    k: usize,
    cursor: usize,
}

impl RoundRobinSelector {
    pub fn new(n_blocks: usize, k: usize) -> Self {
        Self { n_blocks, k, cursor: 0 }
    }
}

impl SelectionStrategy for RoundRobinSelector {
    fn decide(&mut self, _ctx: &SelectionCtx) -> StepPlan {
        let mut out: Vec<usize> =
            (0..self.k).map(|i| (self.cursor + i) % self.n_blocks).collect();
        self.cursor = (self.cursor + self.k) % self.n_blocks;
        out.sort_unstable();
        out.dedup();
        StepPlan::Decided(out)
    }

    fn name(&self) -> String {
        format!("round-robin(k={})", self.k)
    }
}

/// Always the same subset (e.g. "first two layers" probes).
pub struct FixedSubsetSelector {
    subset: Vec<usize>,
}

impl FixedSubsetSelector {
    pub fn new(mut subset: Vec<usize>) -> Self {
        subset.sort_unstable();
        subset.dedup();
        Self { subset }
    }
}

impl SelectionStrategy for FixedSubsetSelector {
    fn decide(&mut self, _ctx: &SelectionCtx) -> StepPlan {
        StepPlan::Decided(self.subset.clone())
    }

    fn name(&self) -> String {
        format!("fixed({:?})", self.subset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(norms: &'a [f64]) -> SelectionCtx<'a> {
        SelectionCtx { step: 0, epoch: 1, grad_norms: norms }
    }

    #[test]
    fn full_selects_everything() {
        let mut s = FullSelector::new(5);
        assert_eq!(s.select(&ctx(&[])), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn topk_fresh_ranks_by_step_norms() {
        let mut s = TopKSelector::new(4, 2);
        let norms = [0.1, 5.0, 0.2, 4.0];
        assert_eq!(s.select(&ctx(&norms)), vec![1, 3]);
    }

    #[test]
    fn topk_cumulative_remembers_history() {
        let mut s = TopKSelector::cumulative(3, 1);
        assert_eq!(s.select(&ctx(&[10.0, 0.0, 0.0])), vec![0]);
        // fresh norms favour 1, but cumulative still favours 0
        assert_eq!(s.select(&ctx(&[0.0, 6.0, 0.0])), vec![0]);
        assert_eq!(s.select(&ctx(&[0.0, 6.0, 0.0])), vec![1]);
    }

    #[test]
    fn random_selects_k_distinct_and_varies() {
        let mut s = RandomSelector::new(10, 3, 0);
        let a = s.select(&ctx(&[]));
        assert_eq!(a.len(), 3);
        let distinct: std::collections::HashSet<_> =
            (0..20).map(|_| s.select(&ctx(&[]))).collect();
        assert!(distinct.len() > 5);
    }

    #[test]
    fn round_robin_covers_all_blocks() {
        let mut s = RoundRobinSelector::new(7, 2);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..7 {
            for b in s.select(&ctx(&[])) {
                seen.insert(b);
            }
        }
        assert_eq!(seen.len(), 7);
    }

    #[test]
    fn norm_free_strategies_decide_before_the_backward() {
        // every policy that doesn't rank on this step's gradients must
        // commit pre-backward, so the trainer can run the masked step
        let c = ctx(&[]);
        assert!(matches!(FullSelector::new(3).decide(&c), StepPlan::Decided(_)));
        assert!(matches!(RandomSelector::new(5, 2, 0).decide(&c), StepPlan::Decided(_)));
        assert!(matches!(RoundRobinSelector::new(5, 2).decide(&c), StepPlan::Decided(_)));
        assert!(matches!(FixedSubsetSelector::new(vec![1]).decide(&c), StepPlan::Decided(_)));
        // Algorithm 1 cannot: it needs the fresh norms
        assert_eq!(TopKSelector::new(3, 1).decide(&c), StepPlan::NeedsNorms);
    }

    #[test]
    fn fixed_subset_stable_and_deduped() {
        let mut s = FixedSubsetSelector::new(vec![3, 1, 3]);
        assert_eq!(s.select(&ctx(&[])), vec![1, 3]);
        assert_eq!(s.select(&ctx(&[])), vec![1, 3]);
    }
}
