//! Scalar samplers: standard normal (Box–Muller) and Gamma(α, 1)
//! (Marsaglia–Tsang), self-contained so the coordinator has no external
//! distribution dependencies.
//!
//! Marsaglia, G. and Tsang, W.W. (2000), "A simple method for generating
//! gamma variables": for α ≥ 1, with d = α − 1/3, c = 1/sqrt(9d), draw
//! x ~ N(0,1), v = (1+cx)^3, accept when ln(u) < x²/2 + d − dv + d·ln(v).
//! For α < 1 use the boost Gamma(α) = Gamma(α+1) · U^(1/α).

use crate::util::rng::Rng;

/// Standard normal via Box–Muller (polar-free, two uniforms per pair; we
/// discard the second — simplicity over throughput, this is not hot).
pub fn standard_normal(rng: &mut Rng) -> f64 {
    let u1: f64 = rng.gen_range_f64(f64::MIN_POSITIVE, 1.0);
    let u2: f64 = rng.gen_f64();
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

/// Gamma(shape, scale=1) via Marsaglia–Tsang.
pub fn gamma(shape: f64, rng: &mut Rng) -> f64 {
    assert!(shape > 0.0, "gamma shape must be positive");
    if shape < 1.0 {
        // boosting: Gamma(a) = Gamma(a + 1) * U^{1/a}
        let u: f64 = rng.gen_range_f64(f64::MIN_POSITIVE, 1.0);
        return gamma(shape + 1.0, rng) * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = standard_normal(rng);
        let t = 1.0 + c * x;
        if t <= 0.0 {
            continue;
        }
        let v = t * t * t;
        let u: f64 = rng.gen_range_f64(f64::MIN_POSITIVE, 1.0);
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from_u64(0);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| standard_normal(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_moments() {
        let mut rng = Rng::seed_from_u64(1);
        for shape in [0.5f64, 1.0, 2.5, 10.0, 100.0] {
            let n = 20_000;
            let xs: Vec<f64> = (0..n).map(|_| gamma(shape, &mut rng)).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
            // Gamma(a,1): mean a, var a
            assert!((mean - shape).abs() / shape < 0.05, "shape {shape} mean {mean}");
            assert!((var - shape).abs() / shape < 0.15, "shape {shape} var {var}");
        }
    }

    #[test]
    fn gamma_always_positive() {
        let mut rng = Rng::seed_from_u64(2);
        for _ in 0..5000 {
            assert!(gamma(0.1, &mut rng) > 0.0);
        }
    }
}
