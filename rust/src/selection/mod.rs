//! Block-selection strategies — the paper's core contribution (L3).
//!
//! Every fine-tuning method in the paper is expressed as a
//! [`SelectionStrategy`]: given the current step/epoch and (optionally) the
//! per-block gradient norms of this step, return the set of block indices
//! whose parameters the optimizer updates.
//!
//! * [`TopKSelector`] — Algorithm 1, *Gradient-Guided Block Selection*.
//! * [`AdaGradSelect`] — Algorithm 2: Dirichlet exploitation over
//!   historical selection frequencies + ε-greedy gradient-norm exploration
//!   during epoch 1, with exponentially decaying ε.
//! * Baselines: [`FullSelector`] (full fine-tuning), [`RandomSelector`]
//!   (LISA-style uniform layerwise sampling), [`RoundRobinSelector`],
//!   [`FixedSubsetSelector`].

mod adagrad;
mod dirichlet;
pub mod grad_norm;
pub mod sampling;
mod strategies;
mod ucb;

pub use adagrad::{AdaGradSelect, AdaGradSelectParams};
pub use dirichlet::{sample_dirichlet, weighted_sample_without_replacement};
pub use grad_norm::GradNormTracker;
pub use strategies::{
    FixedSubsetSelector, FullSelector, RandomSelector, RoundRobinSelector, TopKSelector,
};
pub use ucb::UcbSelector;

/// Per-step context handed to a strategy.
#[derive(Debug, Clone, Copy)]
pub struct SelectionCtx<'a> {
    /// Global step index, 0-based.
    pub step: u64,
    /// Epoch index, **1-based** to match the paper ("epoch == 1" explores).
    pub epoch: u32,
    /// This step's per-block gradient L2 norms (squared norms are tracked
    /// separately; these are `sqrt` values). Empty during the pre-step
    /// [`SelectionStrategy::decide`] call and whenever the caller knows
    /// the strategy doesn't need them.
    pub grad_norms: &'a [f64],
}

/// Outcome of the pre-step [`SelectionStrategy::decide`] call.
///
/// This is the split that lets selection actually *gate* compute: a
/// [`StepPlan::Decided`] step knows its blocks before the backward pass
/// runs, so the trainer can execute a masked backward that skips the
/// weight-gradient GEMMs of every unselected block, never propagates the
/// d-stream below the shallowest selected block, and downloads only the
/// selected gradient flats. Only a [`StepPlan::NeedsNorms`] step (ε-greedy
/// exploration, top-k, UCB) pays for the full backward.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepPlan {
    /// Selection is already known without this step's gradients (Dirichlet
    /// exploitation, random/round-robin/fixed/full policies).
    Decided(Vec<usize>),
    /// The strategy needs this step's per-block gradient norms: run the
    /// full backward, reduce the norms, then call
    /// [`SelectionStrategy::choose`].
    NeedsNorms,
}

/// A block-selection policy.
///
/// The per-step protocol is two-phase: [`SelectionStrategy::decide`] runs
/// *before* the backward pass (with `ctx.grad_norms` empty) and either
/// commits to a selection or demands this step's gradient norms;
/// [`SelectionStrategy::choose`] runs *after* the norm reduction for
/// steps where `decide` returned [`StepPlan::NeedsNorms`]. The provided
/// [`SelectionStrategy::select`] composes the two for callers that always
/// have norms at hand (tests, benches, the golden-parity harness).
pub trait SelectionStrategy: Send {
    /// Pre-backward decision (sorted, deduped block indices when decided).
    /// `ctx.grad_norms` is empty at this point.
    fn decide(&mut self, ctx: &SelectionCtx) -> StepPlan;

    /// Post-norms choice for steps where [`SelectionStrategy::decide`]
    /// returned [`StepPlan::NeedsNorms`]; `ctx.grad_norms` now holds this
    /// step's per-block norms. Strategies that never demand norms keep
    /// the default (unreachable) implementation.
    fn choose(&mut self, ctx: &SelectionCtx) -> Vec<usize> {
        let _ = ctx;
        unreachable!("{}: choose() called but decide() never returns NeedsNorms", self.name())
    }

    /// Choose the set of blocks to update this step (sorted, deduped),
    /// given that `ctx.grad_norms` is already populated. Equivalent to
    /// `decide` + `choose` — one strategy-RNG trajectory either way.
    fn select(&mut self, ctx: &SelectionCtx) -> Vec<usize> {
        match self.decide(ctx) {
            StepPlan::Decided(sel) => sel,
            StepPlan::NeedsNorms => self.choose(ctx),
        }
    }

    /// Advisory: whether [`SelectionStrategy::decide`] *may* return
    /// [`StepPlan::NeedsNorms`] at this ctx (i.e. whether this step might
    /// touch gradients at all). Telemetry/capacity planning only — the
    /// trainer gates the norm reduction on the actual `decide` outcome.
    fn needs_grad_norms(&self, _ctx: &SelectionCtx) -> bool {
        false
    }

    /// Human-readable name for logs / results tables.
    fn name(&self) -> String;

    /// Historical per-block selection counts, if the strategy tracks them.
    fn frequencies(&self) -> Option<&[u64]> {
        None
    }

    /// Bandit telemetry: last decision label ("explore"/"exploit") and the
    /// ε in effect at that step. `None` for non-bandit strategies.
    fn last_decision(&self) -> Option<(&'static str, f64)> {
        None
    }

    /// Bandit telemetry: cumulative (explore, exploit) step counts.
    fn bandit_counts(&self) -> Option<(u64, u64)> {
        None
    }
}

/// `k = max(1, floor(pct/100 * n_blocks))` — the paper selects the top-k%
/// of blocks and observes 10% of 25 transformer blocks => 2 blocks.
pub fn k_from_pct(n_blocks: usize, pct: f64) -> usize {
    ((pct / 100.0) * n_blocks as f64).floor().max(1.0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_from_pct_matches_paper_examples() {
        // Paper: 10% of Qwen2.5-0.5B's 25 transformer blocks = 2 blocks.
        assert_eq!(k_from_pct(25, 10.0), 2);
        // LLaMA3.2-1B: 18 blocks, 10% => a single block per iteration.
        assert_eq!(k_from_pct(18, 10.0), 1);
        assert_eq!(k_from_pct(27, 100.0), 27);
        // never zero
        assert_eq!(k_from_pct(8, 1.0), 1);
    }
}
