//! Block-selection strategies — the paper's core contribution (L3).
//!
//! Every fine-tuning method in the paper is expressed as a
//! [`SelectionStrategy`]: given the current step/epoch and (optionally) the
//! per-block gradient norms of this step, return the set of block indices
//! whose parameters the optimizer updates.
//!
//! * [`TopKSelector`] — Algorithm 1, *Gradient-Guided Block Selection*.
//! * [`AdaGradSelect`] — Algorithm 2: Dirichlet exploitation over
//!   historical selection frequencies + ε-greedy gradient-norm exploration
//!   during epoch 1, with exponentially decaying ε.
//! * Baselines: [`FullSelector`] (full fine-tuning), [`RandomSelector`]
//!   (LISA-style uniform layerwise sampling), [`RoundRobinSelector`],
//!   [`FixedSubsetSelector`].

mod adagrad;
mod dirichlet;
pub mod grad_norm;
pub mod sampling;
mod strategies;
mod ucb;

pub use adagrad::{AdaGradSelect, AdaGradSelectParams};
pub use dirichlet::{sample_dirichlet, weighted_sample_without_replacement};
pub use grad_norm::GradNormTracker;
pub use strategies::{
    FixedSubsetSelector, FullSelector, RandomSelector, RoundRobinSelector, TopKSelector,
};
pub use ucb::UcbSelector;

/// Per-step context handed to a strategy.
#[derive(Debug, Clone, Copy)]
pub struct SelectionCtx<'a> {
    /// Global step index, 0-based.
    pub step: u64,
    /// Epoch index, **1-based** to match the paper ("epoch == 1" explores).
    pub epoch: u32,
    /// This step's per-block gradient L2 norms (squared norms are tracked
    /// separately; these are `sqrt` values). Empty when the caller knows
    /// the strategy doesn't need them.
    pub grad_norms: &'a [f64],
}

/// A block-selection policy.
pub trait SelectionStrategy: Send {
    /// Choose the set of blocks to update this step (sorted, deduped).
    fn select(&mut self, ctx: &SelectionCtx) -> Vec<usize>;

    /// Whether `select` consumes `ctx.grad_norms` at this step. The trainer
    /// can skip norm computation when this is false *and* telemetry does
    /// not ask for norms.
    fn needs_grad_norms(&self, _ctx: &SelectionCtx) -> bool {
        false
    }

    /// Human-readable name for logs / results tables.
    fn name(&self) -> String;

    /// Historical per-block selection counts, if the strategy tracks them.
    fn frequencies(&self) -> Option<&[u64]> {
        None
    }

    /// Bandit telemetry: last decision label ("explore"/"exploit") and the
    /// ε in effect at that step. `None` for non-bandit strategies.
    fn last_decision(&self) -> Option<(&'static str, f64)> {
        None
    }

    /// Bandit telemetry: cumulative (explore, exploit) step counts.
    fn bandit_counts(&self) -> Option<(u64, u64)> {
        None
    }
}

/// `k = max(1, floor(pct/100 * n_blocks))` — the paper selects the top-k%
/// of blocks and observes 10% of 25 transformer blocks => 2 blocks.
pub fn k_from_pct(n_blocks: usize, pct: f64) -> usize {
    ((pct / 100.0) * n_blocks as f64).floor().max(1.0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_from_pct_matches_paper_examples() {
        // Paper: 10% of Qwen2.5-0.5B's 25 transformer blocks = 2 blocks.
        assert_eq!(k_from_pct(25, 10.0), 2);
        // LLaMA3.2-1B: 18 blocks, 10% => a single block per iteration.
        assert_eq!(k_from_pct(18, 10.0), 1);
        assert_eq!(k_from_pct(27, 100.0), 27);
        // never zero
        assert_eq!(k_from_pct(8, 1.0), 1);
    }
}
