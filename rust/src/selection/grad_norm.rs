//! Per-block gradient L2-norm tracking (Algorithm 1's selection signal).
//!
//! The trainer hands over the per-block gradient vectors after each
//! backward pass; this tracker computes blockwise `sqrt(sum(g^2))` (rayon
//! across blocks — the reduction is memory-bound and the blocks are
//! independent) and maintains both the *fresh* per-step norms and the
//! *cumulative* norms the paper's Algorithm 1 ranks on.

use crate::util::par::par_map;

#[derive(Debug, Clone)]
pub struct GradNormTracker {
    /// Most recent per-step block norms.
    pub last: Vec<f64>,
    /// Cumulative (summed over steps) block norms.
    pub cumulative: Vec<f64>,
    steps: u64,
}

impl GradNormTracker {
    pub fn new(n_blocks: usize) -> Self {
        Self { last: vec![0.0; n_blocks], cumulative: vec![0.0; n_blocks], steps: 0 }
    }

    /// Compute per-block norms from flat gradient slices and accumulate.
    pub fn observe<S: AsRef<[f32]> + Sync>(&mut self, grads: &[S]) -> &[f64] {
        assert_eq!(grads.len(), self.last.len());
        self.last = par_map(grads, |_, g| block_norm(g.as_ref()));
        for (c, l) in self.cumulative.iter_mut().zip(&self.last) {
            *c += *l;
        }
        self.steps += 1;
        &self.last
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }
}

/// `sqrt(sum(g^2))` in f64 accumulation (the blocks are small enough that
/// one pass per block is fine; chunked to keep the accumulator in f64).
pub fn block_norm(g: &[f32]) -> f64 {
    block_norm_sq(g).sqrt()
}

/// `sum(g^2)` with f64 accumulation, vectorization-friendly inner loop.
pub fn block_norm_sq(g: &[f32]) -> f64 {
    // accumulate partial sums in f32 lanes per 4k chunk, then sum in f64:
    // fast and accurate enough (parity-tested against the HLO kernel).
    g.chunks(4096)
        .map(|c| {
            let mut acc = 0.0f64;
            let mut lanes = [0.0f32; 8];
            let mut it = c.chunks_exact(8);
            for ch in &mut it {
                for (l, &x) in lanes.iter_mut().zip(ch) {
                    *l += x * x;
                }
            }
            for &x in it.remainder() {
                acc += (x as f64) * (x as f64);
            }
            acc + lanes.iter().map(|&x| x as f64).sum::<f64>()
        })
        .sum()
}

/// Indices of the k largest values (ties broken by lower index first).
pub fn top_k_indices(values: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&a, &b| {
        values[b].partial_cmp(&values[a]).unwrap().then(a.cmp(&b))
    });
    let mut out = idx[..k.min(values.len())].to_vec();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_norm_matches_naive() {
        let g: Vec<f32> = (0..10_001).map(|i| ((i % 17) as f32 - 8.0) * 0.1).collect();
        let naive: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum();
        assert!((block_norm_sq(&g) - naive).abs() / naive < 1e-6);
    }

    #[test]
    fn tracker_accumulates() {
        let mut t = GradNormTracker::new(2);
        t.observe(&[vec![3.0f32, 4.0], vec![0.0f32; 4]]);
        assert!((t.last[0] - 5.0).abs() < 1e-9);
        assert_eq!(t.last[1], 0.0);
        t.observe(&[vec![3.0f32, 4.0], vec![1.0f32, 0.0, 0.0, 0.0]]);
        assert!((t.cumulative[0] - 10.0).abs() < 1e-9);
        assert!((t.cumulative[1] - 1.0).abs() < 1e-9);
        assert_eq!(t.steps(), 2);
    }

    #[test]
    fn top_k_basic() {
        let v = vec![1.0, 9.0, 3.0, 9.0, 2.0];
        assert_eq!(top_k_indices(&v, 2), vec![1, 3]);
        assert_eq!(top_k_indices(&v, 3), vec![1, 2, 3]);
        assert_eq!(top_k_indices(&v, 0), Vec::<usize>::new());
    }
}
