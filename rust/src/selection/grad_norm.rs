//! Per-block gradient L2-norm tracking (Algorithm 1's selection signal).
//!
//! The trainer hands over the per-block gradient vectors after each
//! backward pass; this tracker computes blockwise `sqrt(sum(g^2))` (rayon
//! across blocks — the reduction is memory-bound and the blocks are
//! independent) and maintains both the *fresh* per-step norms and the
//! *cumulative* norms the paper's Algorithm 1 ranks on.

use crate::util::par::par_map;

#[derive(Debug, Clone)]
pub struct GradNormTracker {
    /// Most recent per-step block norms. On masked (exploit) steps only
    /// the selected entries are refreshed; the rest hold the last value
    /// observed when that block's gradient existed.
    pub last: Vec<f64>,
    /// Cumulative (summed over steps) block norms. Accumulates exactly
    /// what [`GradNormTracker::record`]/[`GradNormTracker::record_selected`]
    /// were handed — i.e. post-clip norms, the values selection and
    /// clipping actually saw.
    pub cumulative: Vec<f64>,
    steps: u64,
    reduced_blocks: u64,
}

impl GradNormTracker {
    pub fn new(n_blocks: usize) -> Self {
        Self {
            last: vec![0.0; n_blocks],
            cumulative: vec![0.0; n_blocks],
            steps: 0,
            reduced_blocks: 0,
        }
    }

    /// Compute per-block norms from flat gradient slices and accumulate.
    /// Equivalent to [`block_norms`] + [`GradNormTracker::record`]; the
    /// trainer uses the split form so it can clip *before* accumulating.
    pub fn observe<S: AsRef<[f32]> + Sync>(&mut self, grads: &[S]) -> &[f64] {
        let norms = block_norms(grads);
        self.record(&norms);
        &self.last
    }

    /// Fold one full set of per-block norms (already clipped, if clipping
    /// is on) into `last`/`cumulative`.
    pub fn record(&mut self, norms: &[f64]) {
        assert_eq!(norms.len(), self.last.len());
        self.last.copy_from_slice(norms);
        for (c, l) in self.cumulative.iter_mut().zip(norms) {
            *c += *l;
        }
        self.steps += 1;
        self.reduced_blocks += norms.len() as u64;
    }

    /// Masked-step variant: `norms[i]` is the norm of block
    /// `selected[i]`; unselected blocks had no gradient this step, so
    /// neither `last` nor `cumulative` move for them.
    pub fn record_selected(&mut self, selected: &[usize], norms: &[f64]) {
        assert_eq!(selected.len(), norms.len());
        for (&b, &n) in selected.iter().zip(norms) {
            self.last[b] = n;
            self.cumulative[b] += n;
        }
        self.steps += 1;
        self.reduced_blocks += selected.len() as u64;
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Total per-block norm reductions performed (the work the paper's
    /// exploitation phase avoids) — the bench's zero-norm-reduction
    /// invariant counts this.
    pub fn reduced_blocks(&self) -> u64 {
        self.reduced_blocks
    }
}

/// Per-block L2 norms of flat gradient slices (rayon-style across blocks;
/// the reduction is memory-bound and the blocks are independent).
pub fn block_norms<S: AsRef<[f32]> + Sync>(grads: &[S]) -> Vec<f64> {
    par_map(grads, |_, g| block_norm(g.as_ref()))
}

/// [`block_norms`] rounded through the backend boundary: each norm is
/// `sqrt(f64(f32(sum(g²))))` — exactly the value the device-resident
/// trainer derives from reading back the `grad_norm_sq` entry's f32
/// scalar. The host-loop trainer uses this variant so the two execution
/// modes feed bit-identical norms into clipping, telemetry and the
/// selection strategies (the bit-parity oracle contract).
pub fn block_norms_boundary<S: AsRef<[f32]> + Sync>(grads: &[S]) -> Vec<f64> {
    par_map(grads, |_, g| norm_from_sq_f32(block_norm_sq(g.as_ref()) as f32))
}

/// Reconstruct a block norm from the f32 squared-norm scalar that crossed
/// the backend boundary (shared by both trainer execution modes).
pub fn norm_from_sq_f32(norm_sq: f32) -> f64 {
    (norm_sq as f64).sqrt()
}

/// `sqrt(sum(g^2))` in f64 accumulation (the blocks are small enough that
/// one pass per block is fine; chunked to keep the accumulator in f64).
pub fn block_norm(g: &[f32]) -> f64 {
    block_norm_sq(g).sqrt()
}

/// `sum(g^2)` with f64 accumulation, vectorization-friendly inner loop.
pub fn block_norm_sq(g: &[f32]) -> f64 {
    // accumulate partial sums in f32 lanes per 4k chunk, then sum in f64:
    // fast and accurate enough (parity-tested against the HLO kernel).
    g.chunks(4096)
        .map(|c| {
            let mut acc = 0.0f64;
            let mut lanes = [0.0f32; 8];
            let mut it = c.chunks_exact(8);
            for ch in &mut it {
                for (l, &x) in lanes.iter_mut().zip(ch) {
                    *l += x * x;
                }
            }
            for &x in it.remainder() {
                acc += (x as f64) * (x as f64);
            }
            acc + lanes.iter().map(|&x| x as f64).sum::<f64>()
        })
        .sum()
}

/// Indices of the k largest values (ties broken by lower index first).
pub fn top_k_indices(values: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&a, &b| {
        values[b].partial_cmp(&values[a]).unwrap().then(a.cmp(&b))
    });
    let mut out = idx[..k.min(values.len())].to_vec();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_norm_matches_naive() {
        let g: Vec<f32> = (0..10_001).map(|i| ((i % 17) as f32 - 8.0) * 0.1).collect();
        let naive: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum();
        assert!((block_norm_sq(&g) - naive).abs() / naive < 1e-6);
    }

    #[test]
    fn tracker_accumulates() {
        let mut t = GradNormTracker::new(2);
        t.observe(&[vec![3.0f32, 4.0], vec![0.0f32; 4]]);
        assert!((t.last[0] - 5.0).abs() < 1e-9);
        assert_eq!(t.last[1], 0.0);
        t.observe(&[vec![3.0f32, 4.0], vec![1.0f32, 0.0, 0.0, 0.0]]);
        assert!((t.cumulative[0] - 10.0).abs() < 1e-9);
        assert!((t.cumulative[1] - 1.0).abs() < 1e-9);
        assert_eq!(t.steps(), 2);
    }

    #[test]
    fn record_selected_leaves_unselected_untouched() {
        let mut t = GradNormTracker::new(3);
        t.record(&[1.0, 2.0, 3.0]);
        t.record_selected(&[1], &[5.0]);
        assert_eq!(t.last, vec![1.0, 5.0, 3.0]);
        assert_eq!(t.cumulative, vec![1.0, 7.0, 3.0]);
        assert_eq!(t.steps(), 2);
        assert_eq!(t.reduced_blocks(), 4);
    }

    #[test]
    fn cumulative_accumulates_exactly_what_was_recorded() {
        // the clip-before-accumulate contract: the tracker never sees
        // pre-clip norms, so cumulative == sum of recorded values
        let mut t = GradNormTracker::new(2);
        t.record(&[0.5, 0.25]); // e.g. post-clip
        t.record(&[0.5, 0.25]);
        assert_eq!(t.cumulative, vec![1.0, 0.5]);
    }

    #[test]
    fn top_k_basic() {
        let v = vec![1.0, 9.0, 3.0, 9.0, 2.0];
        assert_eq!(top_k_indices(&v, 2), vec![1, 3]);
        assert_eq!(top_k_indices(&v, 3), vec![1, 2, 3]);
        assert_eq!(top_k_indices(&v, 0), Vec::<usize>::new());
    }
}
