//! UCB1 block selector — the natural multi-armed-bandit extension the
//! paper's §3.2 "Connection to Multi-Armed Bandit" invites but does not
//! evaluate (our extension; ablation harness compares it to Algorithm 2).
//!
//! Each block is an arm; the reward observed when a block is updated is
//! its (normalized) gradient norm — the same signal Algorithm 1 ranks on,
//! but folded into a mean-reward estimate instead of a frequency count.
//! Selection takes the k arms maximizing
//!
//!   UCB_i = r̄_i + c·sqrt(ln(t) / n_i)
//!
//! with unplayed arms forced first (infinite bonus). Unlike ε-greedy +
//! Dirichlet, UCB needs *per-step* gradient norms only for the blocks it
//! just played, which the trainer already has.

use super::grad_norm::top_k_indices;
use super::{SelectionCtx, SelectionStrategy, StepPlan};

pub struct UcbSelector {
    k: usize,
    c: f64,
    /// Mean observed reward per block.
    mean: Vec<f64>,
    /// Play count per block.
    plays: Vec<u64>,
    t: u64,
    last_selected: Vec<usize>,
}

impl UcbSelector {
    pub fn new(n_blocks: usize, k: usize, c: f64) -> Self {
        assert!(k >= 1 && k <= n_blocks);
        Self {
            k,
            c,
            mean: vec![0.0; n_blocks],
            plays: vec![0; n_blocks],
            t: 0,
            last_selected: Vec::new(),
        }
    }

    /// Fold the rewards (grad norms) observed for the previously selected
    /// blocks into the running means.
    fn observe(&mut self, grad_norms: &[f64]) {
        if grad_norms.is_empty() {
            return;
        }
        let total: f64 = grad_norms.iter().sum::<f64>().max(1e-12);
        for &b in &self.last_selected {
            let reward = grad_norms[b] / total; // normalized to [0, 1]-ish
            let n = self.plays[b] as f64;
            self.mean[b] = (self.mean[b] * n + reward) / (n + 1.0);
            self.plays[b] += 1;
        }
    }

    fn scores(&self) -> Vec<f64> {
        let ln_t = ((self.t + 1) as f64).ln();
        self.mean
            .iter()
            .zip(&self.plays)
            .map(|(&m, &n)| {
                if n == 0 {
                    f64::INFINITY
                } else {
                    m + self.c * (ln_t / n as f64).sqrt()
                }
            })
            .collect()
    }
}

impl SelectionStrategy for UcbSelector {
    fn decide(&mut self, _ctx: &SelectionCtx) -> StepPlan {
        // rewards for the arms just played come from this step's norms
        StepPlan::NeedsNorms
    }

    fn choose(&mut self, ctx: &SelectionCtx) -> Vec<usize> {
        self.observe(ctx.grad_norms);
        self.t += 1;
        let sel = top_k_indices(&self.scores(), self.k);
        self.last_selected = sel.clone();
        sel
    }

    fn needs_grad_norms(&self, _ctx: &SelectionCtx) -> bool {
        true
    }

    fn name(&self) -> String {
        format!("ucb(k={},c={})", self.k, self.c)
    }

    fn frequencies(&self) -> Option<&[u64]> {
        Some(&self.plays)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(step: u64, norms: &[f64]) -> SelectionCtx<'_> {
        SelectionCtx { step, epoch: 1, grad_norms: norms }
    }

    #[test]
    fn plays_every_arm_first() {
        let mut s = UcbSelector::new(6, 2, 1.0);
        let norms = vec![1.0; 6];
        let mut seen = std::collections::HashSet::new();
        for t in 0..3 {
            seen.extend(s.select(&ctx(t, &norms)));
        }
        assert_eq!(seen.len(), 6, "all arms explored in the first n/k steps");
    }

    #[test]
    fn converges_to_high_reward_arms() {
        let mut s = UcbSelector::new(8, 2, 0.3);
        // blocks 2 and 5 consistently carry the gradient mass
        let mut norms = vec![0.01; 8];
        norms[2] = 5.0;
        norms[5] = 4.0;
        let mut hits = 0;
        for t in 0..300 {
            let sel = s.select(&ctx(t, &norms));
            if t >= 100 && sel == vec![2, 5] {
                hits += 1;
            }
        }
        assert!(hits > 150, "hits {hits}");
    }

    #[test]
    fn exact_k_valid_sorted() {
        let mut s = UcbSelector::new(10, 3, 1.0);
        let norms = vec![0.5; 10];
        for t in 0..50 {
            let sel = s.select(&ctx(t, &norms));
            assert_eq!(sel.len(), 3);
            assert!(sel.windows(2).all(|w| w[0] < w[1]));
            assert!(sel.iter().all(|&b| b < 10));
        }
    }

    #[test]
    fn play_counts_sum_correctly() {
        let mut s = UcbSelector::new(5, 2, 1.0);
        let norms = vec![1.0; 5];
        for t in 0..20 {
            s.select(&ctx(t, &norms));
        }
        // plays are recorded one step late (observe-then-select), so after
        // 20 selects, 19 selections have been credited.
        assert_eq!(s.frequencies().unwrap().iter().sum::<u64>(), 19 * 2);
    }
}
