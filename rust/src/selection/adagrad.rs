//! AdaGradSelect — Algorithm 2 of the paper.
//!
//! Block selection as a multi-armed bandit:
//!
//! * **Epoch 1** (exploration–exploitation): at each step, with probability
//!   `ε_t = ε₀·exp(−λ·t)` *explore* — select the top-k blocks by this
//!   step's gradient norms (Algorithm 1); otherwise *exploit* — draw
//!   `p ~ Dirichlet(f + δ)` from the historical selection frequencies `f`
//!   and sample k blocks without replacement according to `p`.
//! * **Epoch ≥ 2**: pure Dirichlet exploitation (ε = 0).
//!
//! Frequencies are updated after every selection, so early exploration
//! shapes later exploitation. The paper highlights that at step 0 the
//! policy always explores (ε₀ = 1 by default ⇒ `rand() < 1`), and that by
//! the end of epoch 1 it is effectively pure exploitation.

use crate::util::rng::Rng;

use super::dirichlet::{sample_dirichlet, weighted_sample_without_replacement};
use super::grad_norm::top_k_indices;
use super::{SelectionCtx, SelectionStrategy, StepPlan};

#[derive(Debug, Clone)]
pub struct AdaGradSelectParams {
    /// Number of blocks selected per step (top-k% of the block count).
    pub k: usize,
    /// Initial exploration probability ε₀.
    pub eps0: f64,
    /// Exponential decay rate λ (per *step within epoch 1*).
    pub lambda: f64,
    /// Dirichlet smoothing constant δ > 0.
    pub delta: f64,
    /// Steps per epoch (used to derive the epoch from the global step when
    /// the trainer doesn't pass epochs explicitly).
    pub steps_per_epoch: u64,
    pub seed: u64,
    /// Ablation: keep ε-greedy exploration active after epoch 1.
    pub explore_after_epoch1: bool,
    /// Ablation: replace Dirichlet(f+δ) with uniform sampling.
    pub uniform_exploit: bool,
}

impl AdaGradSelectParams {
    pub fn new(k: usize, steps_per_epoch: u64) -> Self {
        Self {
            k,
            eps0: 1.0,
            // decay so that ε ≈ 0.01 by the end of epoch 1 — "at the first
            // step there will always be exploration and at the Nth step
            // there will always be exploitation".
            lambda: if steps_per_epoch > 1 {
                (100.0f64).ln() / (steps_per_epoch as f64 - 1.0)
            } else {
                1.0
            },
            delta: 1.0,
            steps_per_epoch,
            seed: 0,
            explore_after_epoch1: false,
            uniform_exploit: false,
        }
    }
}

/// Outcome breadcrumb for telemetry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    Explore,
    Exploit,
}

pub struct AdaGradSelect {
    params: AdaGradSelectParams,
    /// Historical selection frequency per block (the bandit state `f`).
    freq: Vec<u64>,
    rng: Rng,
    pub last_decision: Option<Decision>,
    pub last_epsilon: f64,
    n_explore: u64,
    n_exploit: u64,
}

impl AdaGradSelect {
    pub fn new(n_blocks: usize, params: AdaGradSelectParams) -> Self {
        assert!(params.k >= 1 && params.k <= n_blocks);
        assert!(params.delta > 0.0, "delta must be positive");
        let rng = Rng::seed_from_u64(params.seed.wrapping_add(0xA6A6));
        Self {
            params,
            freq: vec![0; n_blocks],
            rng,
            last_decision: None,
            last_epsilon: 0.0,
            n_explore: 0,
            n_exploit: 0,
        }
    }

    pub fn params(&self) -> &AdaGradSelectParams {
        &self.params
    }

    pub fn explore_exploit_counts(&self) -> (u64, u64) {
        (self.n_explore, self.n_exploit)
    }

    /// ε at a given step. For the paper's method this is only evaluated
    /// during epoch 1, where the global step *is* the step within the
    /// epoch; with the `explore_after_epoch1` ablation the decay simply
    /// continues across epoch boundaries (ε keeps shrinking instead of
    /// sawtoothing back to ε₀ every epoch).
    pub fn epsilon_at(&self, step: u64) -> f64 {
        self.params.eps0 * (-self.params.lambda * step as f64).exp()
    }

    fn exploit(&mut self) -> Vec<usize> {
        let p = if self.params.uniform_exploit {
            vec![1.0 / self.freq.len() as f64; self.freq.len()]
        } else {
            let alpha: Vec<f64> =
                self.freq.iter().map(|&f| f as f64 + self.params.delta).collect();
            sample_dirichlet(&alpha, &mut self.rng)
        };
        weighted_sample_without_replacement(&p, self.params.k, &mut self.rng)
    }

    fn record(&mut self, selected: &[usize]) {
        for &b in selected {
            self.freq[b] += 1;
        }
    }
}

impl SelectionStrategy for AdaGradSelect {
    fn decide(&mut self, ctx: &SelectionCtx) -> StepPlan {
        let in_epoch1 = ctx.epoch <= 1;
        let explore_allowed = in_epoch1 || self.params.explore_after_epoch1;

        if explore_allowed {
            let eps = self.epsilon_at(ctx.step);
            self.last_epsilon = eps;
            if self.rng.gen_f64() < eps {
                // exploration ranks on this step's norms — full backward
                self.last_decision = Some(Decision::Explore);
                self.n_explore += 1;
                return StepPlan::NeedsNorms;
            }
        } else {
            self.last_epsilon = 0.0;
        }
        // exploitation: Dirichlet(f+δ) over the frequency history — the
        // paper's "avoids gradient access" phase. Deciding here, before
        // the backward pass, is what lets the trainer run the masked step.
        self.last_decision = Some(Decision::Exploit);
        self.n_exploit += 1;
        let selected = self.exploit();
        self.record(&selected);
        StepPlan::Decided(selected)
    }

    fn choose(&mut self, ctx: &SelectionCtx) -> Vec<usize> {
        // only reached after decide() returned NeedsNorms (explore)
        debug_assert_eq!(self.last_decision, Some(Decision::Explore));
        assert_eq!(
            ctx.grad_norms.len(),
            self.freq.len(),
            "exploration step needs grad norms"
        );
        let selected = top_k_indices(ctx.grad_norms, self.params.k);
        self.record(&selected);
        selected
    }

    fn needs_grad_norms(&self, ctx: &SelectionCtx) -> bool {
        // Only epoch-1 (or always-explore ablation) steps can explore; the
        // trainer may skip the norm reduction entirely afterwards — this is
        // the "avoids gradient access" property the paper claims for the
        // exploitation phase.
        ctx.epoch <= 1 || self.params.explore_after_epoch1
    }

    fn name(&self) -> String {
        format!("adagradselect(k={})", self.params.k)
    }

    fn frequencies(&self) -> Option<&[u64]> {
        Some(&self.freq)
    }

    fn last_decision(&self) -> Option<(&'static str, f64)> {
        self.last_decision.map(|d| {
            let label = match d {
                Decision::Explore => "explore",
                Decision::Exploit => "exploit",
            };
            (label, self.last_epsilon)
        })
    }

    fn bandit_counts(&self) -> Option<(u64, u64)> {
        Some((self.n_explore, self.n_exploit))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(step: u64, epoch: u32, norms: &[f64]) -> SelectionCtx<'_> {
        SelectionCtx { step, epoch, grad_norms: norms }
    }

    fn params(k: usize, spe: u64, seed: u64) -> AdaGradSelectParams {
        let mut p = AdaGradSelectParams::new(k, spe);
        p.seed = seed;
        p
    }

    #[test]
    fn first_step_always_explores_with_eps0_one() {
        let norms = vec![0.0, 9.0, 0.0, 8.0, 0.0];
        for seed in 0..10 {
            let mut s = AdaGradSelect::new(5, params(2, 100, seed));
            let sel = s.select(&ctx(0, 1, &norms));
            assert_eq!(sel, vec![1, 3], "seed {seed}");
            assert_eq!(s.last_decision, Some(Decision::Explore));
        }
    }

    #[test]
    fn epsilon_decays_to_near_zero_by_epoch_end() {
        let s = AdaGradSelect::new(5, params(2, 200, 0));
        assert!((s.epsilon_at(0) - 1.0).abs() < 1e-12);
        assert!(s.epsilon_at(199) <= 0.0101);
        assert!(s.epsilon_at(100) < s.epsilon_at(50));
    }

    #[test]
    fn epoch2_never_explores() {
        let norms = vec![9.0, 0.0, 0.0];
        let mut s = AdaGradSelect::new(3, params(1, 10, 0));
        for step in 0..200 {
            s.select(&ctx(step, 2, &norms));
            assert_eq!(s.last_decision, Some(Decision::Exploit));
        }
        assert_eq!(s.explore_exploit_counts().0, 0);
        assert!(!s.needs_grad_norms(&ctx(0, 2, &[])));
    }

    #[test]
    fn frequencies_track_selections() {
        let norms = vec![1.0; 4];
        let mut s = AdaGradSelect::new(4, params(2, 50, 1));
        for step in 0..50 {
            s.select(&ctx(step, 1, &norms));
        }
        let f = s.frequencies().unwrap();
        assert_eq!(f.iter().sum::<u64>(), 100); // 2 per step * 50
    }

    #[test]
    fn exploitation_prefers_frequent_blocks() {
        // Bias the history hard toward blocks {0,1}; Dirichlet exploitation
        // must overwhelmingly return them.
        let mut s = AdaGradSelect::new(6, params(2, 1, 2));
        s.freq = vec![500, 500, 0, 0, 0, 0];
        let mut hits = 0;
        for step in 0..200 {
            let sel = s.select(&ctx(step, 2, &[]));
            // undo the frequency self-reinforcement for a clean test
            for &b in &sel {
                s.freq[b] -= 1;
            }
            if sel == vec![0, 1] {
                hits += 1;
            }
        }
        assert!(hits > 150, "hits {hits}");
    }

    #[test]
    fn uniform_ablation_spreads_selections() {
        let mut p = params(1, 1, 3);
        p.uniform_exploit = true;
        let mut s = AdaGradSelect::new(8, p);
        let mut seen = std::collections::HashSet::new();
        for step in 0..300 {
            seen.extend(s.select(&ctx(step, 2, &[])));
        }
        assert_eq!(seen.len(), 8);
    }

    #[test]
    fn ablation_epsilon_decays_across_epochs_without_sawtooth() {
        // regression: `explore_after_epoch1` used to reset ε to ε₀ at
        // every epoch boundary (t % steps_per_epoch); the decay must
        // continue across epochs instead
        let norms = vec![1.0; 4];
        let mut p = params(1, 10, 0);
        p.explore_after_epoch1 = true;
        let mut s = AdaGradSelect::new(4, p);
        let mut eps_seen = Vec::new();
        for step in 0..30u64 {
            let epoch = 1 + (step / 10) as u32;
            s.select(&ctx(step, epoch, &norms));
            eps_seen.push(s.last_epsilon);
        }
        for (i, w) in eps_seen.windows(2).enumerate() {
            assert!(w[1] < w[0], "epsilon rose at step {}: {:?}", i + 1, eps_seen);
        }
        // first step of epoch 2 continues the decay (the old bug put it
        // back at ε₀ = 1)
        assert!(eps_seen[10] < eps_seen[9]);
        assert!(eps_seen[29] < 1e-4);
    }

    #[test]
    fn decide_choose_composition_matches_select() {
        let norms: Vec<f64> = (0..6).map(|i| (i as f64 * 0.7).cos().abs()).collect();
        let mut a = AdaGradSelect::new(6, params(2, 15, 11));
        let mut b = AdaGradSelect::new(6, params(2, 15, 11));
        for step in 0..45u64 {
            let epoch = 1 + (step / 15) as u32;
            let got = a.select(&ctx(step, epoch, &norms));
            let want = match b.decide(&ctx(step, epoch, &[])) {
                StepPlan::Decided(sel) => sel,
                StepPlan::NeedsNorms => b.choose(&ctx(step, epoch, &norms)),
            };
            assert_eq!(got, want, "step {step}");
            assert_eq!(a.last_decision, b.last_decision);
        }
        assert_eq!(a.explore_exploit_counts(), b.explore_exploit_counts());
    }

    #[test]
    fn exploit_steps_decide_without_norms() {
        // epoch ≥ 2: the plan is fully decided pre-backward with empty
        // norms — the property that lets the trainer skip gradient work
        let mut s = AdaGradSelect::new(5, params(2, 10, 3));
        for step in 0..40u64 {
            match s.decide(&ctx(step, 2, &[])) {
                StepPlan::Decided(sel) => assert_eq!(sel.len(), 2),
                StepPlan::NeedsNorms => panic!("exploit step demanded norms"),
            }
        }
    }

    #[test]
    fn selection_deterministic_per_seed() {
        let norms = vec![1.0, 2.0, 3.0, 4.0];
        let run = |seed| {
            let mut s = AdaGradSelect::new(4, params(2, 20, seed));
            (0..40).map(|t| s.select(&ctx(t, 1 + (t / 20) as u32, &norms))).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
