//! Typed configuration system (JSON files + programmatic defaults).
//!
//! A [`RunConfig`] fully describes one fine-tuning run: preset, method,
//! optimizer/schedule, data generator, residency model and eval settings.
//! Configs load from JSON (`agsel train --config run.json`), from CLI
//! flags, or from [`RunConfig::preset_defaults`]. Validation enforces the
//! paper's practitioner guideline (`min% >= 100/B` — at least one block
//! per iteration).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::Preset;
use crate::util::json::Value;

/// Which fine-tuning method drives the run — one per paper baseline.
#[derive(Debug, Clone, PartialEq)]
pub enum Method {
    /// Full fine-tuning: every block every step.
    Full,
    /// Algorithm 1: top-k% by per-step gradient norm.
    TopK { pct: f64 },
    /// Algorithm 2: the paper's contribution.
    AdaGradSelect {
        pct: f64,
        eps0: f64,
        /// Decay rate λ; `None` derives "ε≈0.01 at epoch end" (paper's
        /// "always explore at step 1, always exploit at step N").
        lambda: Option<f64>,
        delta: f64,
        /// Ablation switches (off in the paper's method).
        explore_after_epoch1: bool,
        uniform_exploit: bool,
    },
    /// LoRA baseline; `double_rank` selects the r=256-analogue artifact.
    Lora { double_rank: bool },
    /// LISA-style uniform random layerwise sampling.
    Random { pct: f64 },
    /// Deterministic rotation baseline.
    RoundRobin { pct: f64 },
    /// UCB1 bandit (our MAB extension; see `selection::UcbSelector`).
    Ucb { pct: f64, c: f64 },
    /// Fixed subset probe (block indices).
    Fixed { blocks: Vec<usize> },
}

impl Method {
    /// The paper's default AdaGradSelect hyperparameters at a given pct.
    pub fn ags(pct: f64) -> Method {
        Method::AdaGradSelect {
            pct,
            eps0: 1.0,
            lambda: None,
            delta: 1.0,
            explore_after_epoch1: false,
            uniform_exploit: false,
        }
    }

    pub fn to_json(&self) -> Value {
        match self {
            Method::Full => Value::obj(vec![("kind", Value::str("full"))]),
            Method::TopK { pct } => {
                Value::obj(vec![("kind", Value::str("topk")), ("pct", Value::num(*pct))])
            }
            Method::AdaGradSelect { pct, eps0, lambda, delta, explore_after_epoch1, uniform_exploit } => {
                Value::obj(vec![
                    ("kind", Value::str("adagradselect")),
                    ("pct", Value::num(*pct)),
                    ("eps0", Value::num(*eps0)),
                    ("lambda", lambda.map(Value::num).unwrap_or(Value::Null)),
                    ("delta", Value::num(*delta)),
                    ("explore_after_epoch1", Value::Bool(*explore_after_epoch1)),
                    ("uniform_exploit", Value::Bool(*uniform_exploit)),
                ])
            }
            Method::Lora { double_rank } => Value::obj(vec![
                ("kind", Value::str("lora")),
                ("double_rank", Value::Bool(*double_rank)),
            ]),
            Method::Random { pct } => {
                Value::obj(vec![("kind", Value::str("random")), ("pct", Value::num(*pct))])
            }
            Method::RoundRobin { pct } => {
                Value::obj(vec![("kind", Value::str("round-robin")), ("pct", Value::num(*pct))])
            }
            Method::Ucb { pct, c } => Value::obj(vec![
                ("kind", Value::str("ucb")),
                ("pct", Value::num(*pct)),
                ("c", Value::num(*c)),
            ]),
            Method::Fixed { blocks } => Value::obj(vec![
                ("kind", Value::str("fixed")),
                ("blocks", Value::arr_usize(blocks)),
            ]),
        }
    }

    pub fn from_json(v: &Value) -> Result<Method> {
        let kind = v.get("kind")?.as_str()?;
        let pct = || -> Result<f64> { v.get("pct")?.as_f64() };
        Ok(match kind {
            "full" => Method::Full,
            "topk" => Method::TopK { pct: pct()? },
            "adagradselect" | "ada-grad-select" => Method::AdaGradSelect {
                pct: pct()?,
                eps0: v.opt("eps0").map(|x| x.as_f64()).transpose()?.unwrap_or(1.0),
                lambda: match v.opt("lambda") {
                    None | Some(Value::Null) => None,
                    Some(x) => Some(x.as_f64()?),
                },
                delta: v.opt("delta").map(|x| x.as_f64()).transpose()?.unwrap_or(1.0),
                explore_after_epoch1: v
                    .opt("explore_after_epoch1")
                    .map(|x| x.as_bool())
                    .transpose()?
                    .unwrap_or(false),
                uniform_exploit: v
                    .opt("uniform_exploit")
                    .map(|x| x.as_bool())
                    .transpose()?
                    .unwrap_or(false),
            },
            "lora" => Method::Lora {
                double_rank: v
                    .opt("double_rank")
                    .map(|x| x.as_bool())
                    .transpose()?
                    .unwrap_or(false),
            },
            "random" | "lisa" => Method::Random { pct: pct()? },
            "round-robin" => Method::RoundRobin { pct: pct()? },
            "ucb" => Method::Ucb {
                pct: pct()?,
                c: v.opt("c").map(|x| x.as_f64()).transpose()?.unwrap_or(0.5),
            },
            "fixed" => Method::Fixed {
                blocks: v
                    .get("blocks")?
                    .as_arr()?
                    .iter()
                    .map(|x| x.as_usize())
                    .collect::<Result<_>>()?,
            },
            other => bail!("unknown method kind {other:?}"),
        })
    }

    pub fn label(&self) -> String {
        match self {
            Method::Full => "full-ft".into(),
            Method::TopK { pct } => format!("topk-{pct:.0}%"),
            Method::AdaGradSelect { pct, .. } => format!("adagradselect-{pct:.0}%"),
            Method::Lora { double_rank } => {
                if *double_rank {
                    "lora-r2".into()
                } else {
                    "lora-r1".into()
                }
            }
            Method::Random { pct } => format!("random-{pct:.0}%"),
            Method::RoundRobin { pct } => format!("round-robin-{pct:.0}%"),
            Method::Ucb { pct, .. } => format!("ucb-{pct:.0}%"),
            Method::Fixed { blocks } => format!("fixed-{blocks:?}"),
        }
    }

    pub fn selection_pct(&self) -> Option<f64> {
        match self {
            Method::TopK { pct }
            | Method::AdaGradSelect { pct, .. }
            | Method::Random { pct }
            | Method::RoundRobin { pct }
            | Method::Ucb { pct, .. } => Some(*pct),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrainParams {
    pub steps: u64,
    /// Steps per epoch (AdaGradSelect's explore window is epoch 1).
    pub steps_per_epoch: u64,
    pub lr: f32,
    /// Linear warmup steps followed by cosine decay to `lr * min_lr_frac`.
    pub warmup_steps: u64,
    pub min_lr_frac: f32,
    pub log_every: u64,
    /// 0 disables periodic eval.
    pub eval_every: u64,
    pub grad_clip: Option<f32>,
}

impl Default for TrainParams {
    fn default() -> Self {
        Self {
            steps: 300,
            steps_per_epoch: 100,
            lr: 1e-3,
            warmup_steps: 20,
            min_lr_frac: 0.1,
            log_every: 10,
            eval_every: 0,
            grad_clip: Some(1.0),
        }
    }
}

#[derive(Debug, Clone)]
pub struct DataParams {
    /// `"mixed"` (MetaMathQA stand-in, default), `"gsm8k-sim"`, or
    /// `"math-sim"`.
    pub train_suite: String,
    pub seed: u64,
    /// Number of held-out problems per eval suite.
    pub eval_problems: usize,
    /// Max tokens generated per answer during greedy decode.
    pub max_new_tokens: usize,
}

impl Default for DataParams {
    fn default() -> Self {
        Self {
            train_suite: "mixed".into(),
            seed: 0,
            eval_problems: 128,
            max_new_tokens: 32,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ResidencyParams {
    /// `"pcie4" | "nvlink" | "pcie3x4"`.
    pub link: String,
    /// Bytes per parameter for optimizer state (2 = bf16 as in the paper).
    pub bytes_per_param: usize,
}

impl Default for ResidencyParams {
    fn default() -> Self {
        Self { link: "pcie4".into(), bytes_per_param: 2 }
    }
}

impl ResidencyParams {
    pub fn pcie_model(&self) -> Result<crate::optimizer::PcieModel> {
        use crate::optimizer::PcieModel;
        Ok(match self.link.as_str() {
            "pcie4" => PcieModel::default(),
            "nvlink" => PcieModel::nvlink(),
            "pcie3x4" => PcieModel::slow_gen3_x4(),
            other => return Err(anyhow!("unknown link model {other:?}")),
        })
    }
}

#[derive(Debug, Clone)]
pub struct RunConfig {
    pub preset: String,
    pub method: Method,
    pub train: TrainParams,
    pub data: DataParams,
    pub residency: ResidencyParams,
    pub artifacts_dir: PathBuf,
    pub seed: u64,
    /// Use the Pallas-attention train_step artifact when available.
    pub pallas_kernel: bool,
    /// Where JSONL metrics go (None = no file logging).
    pub metrics_path: Option<PathBuf>,
}

fn default_artifacts_dir() -> PathBuf {
    PathBuf::from("artifacts")
}

impl RunConfig {
    /// Sane defaults for a preset with AdaGradSelect(30%).
    pub fn preset_defaults(preset: &str) -> Self {
        Self {
            preset: preset.to_string(),
            method: Method::AdaGradSelect {
                pct: 30.0,
                eps0: 1.0,
                lambda: None,
                delta: 1.0,
                explore_after_epoch1: false,
                uniform_exploit: false,
            },
            train: TrainParams::default(),
            data: DataParams::default(),
            residency: ResidencyParams::default(),
            artifacts_dir: default_artifacts_dir(),
            seed: 0,
            pallas_kernel: false,
            metrics_path: None,
        }
    }

    pub fn from_json_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_json_str(&text)
    }

    /// Parse a config; unspecified sections fall back to defaults. The
    /// only required fields are `preset` and `method`.
    pub fn from_json_str(text: &str) -> Result<Self> {
        let v = Value::parse(text).context("parsing JSON config")?;
        let mut cfg = RunConfig::preset_defaults(v.get("preset")?.as_str()?);
        cfg.method = Method::from_json(v.get("method")?)?;
        if let Some(t) = v.opt("train") {
            let d = &mut cfg.train;
            if let Some(x) = t.opt("steps") { d.steps = x.as_u64()?; }
            if let Some(x) = t.opt("steps_per_epoch") { d.steps_per_epoch = x.as_u64()?; }
            if let Some(x) = t.opt("lr") { d.lr = x.as_f32()?; }
            if let Some(x) = t.opt("warmup_steps") { d.warmup_steps = x.as_u64()?; }
            if let Some(x) = t.opt("min_lr_frac") { d.min_lr_frac = x.as_f32()?; }
            if let Some(x) = t.opt("log_every") { d.log_every = x.as_u64()?; }
            if let Some(x) = t.opt("eval_every") { d.eval_every = x.as_u64()?; }
            if let Some(x) = t.opt("grad_clip") {
                d.grad_clip = match x {
                    Value::Null => None,
                    x => Some(x.as_f32()?),
                };
            }
        }
        if let Some(t) = v.opt("data") {
            let d = &mut cfg.data;
            if let Some(x) = t.opt("train_suite") { d.train_suite = x.as_str()?.to_string(); }
            if let Some(x) = t.opt("seed") { d.seed = x.as_u64()?; }
            if let Some(x) = t.opt("eval_problems") { d.eval_problems = x.as_usize()?; }
            if let Some(x) = t.opt("max_new_tokens") { d.max_new_tokens = x.as_usize()?; }
        }
        if let Some(t) = v.opt("residency") {
            let d = &mut cfg.residency;
            if let Some(x) = t.opt("link") { d.link = x.as_str()?.to_string(); }
            if let Some(x) = t.opt("bytes_per_param") { d.bytes_per_param = x.as_usize()?; }
        }
        if let Some(x) = v.opt("artifacts_dir") {
            cfg.artifacts_dir = PathBuf::from(x.as_str()?);
        }
        if let Some(x) = v.opt("seed") { cfg.seed = x.as_u64()?; }
        if let Some(x) = v.opt("pallas_kernel") { cfg.pallas_kernel = x.as_bool()?; }
        if let Some(x) = v.opt("metrics_path") {
            cfg.metrics_path = match x {
                Value::Null => None,
                x => Some(PathBuf::from(x.as_str()?)),
            };
        }
        Ok(cfg)
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("preset", Value::str(&self.preset)),
            ("method", self.method.to_json()),
            (
                "train",
                Value::obj(vec![
                    ("steps", Value::num(self.train.steps as f64)),
                    ("steps_per_epoch", Value::num(self.train.steps_per_epoch as f64)),
                    ("lr", Value::num(self.train.lr as f64)),
                    ("warmup_steps", Value::num(self.train.warmup_steps as f64)),
                    ("min_lr_frac", Value::num(self.train.min_lr_frac as f64)),
                    ("log_every", Value::num(self.train.log_every as f64)),
                    ("eval_every", Value::num(self.train.eval_every as f64)),
                    (
                        "grad_clip",
                        self.train.grad_clip.map(|c| Value::num(c as f64)).unwrap_or(Value::Null),
                    ),
                ]),
            ),
            (
                "data",
                Value::obj(vec![
                    ("train_suite", Value::str(&self.data.train_suite)),
                    ("seed", Value::num(self.data.seed as f64)),
                    ("eval_problems", Value::num(self.data.eval_problems as f64)),
                    ("max_new_tokens", Value::num(self.data.max_new_tokens as f64)),
                ]),
            ),
            (
                "residency",
                Value::obj(vec![
                    ("link", Value::str(&self.residency.link)),
                    ("bytes_per_param", Value::num(self.residency.bytes_per_param as f64)),
                ]),
            ),
            ("artifacts_dir", Value::str(self.artifacts_dir.to_string_lossy())),
            ("seed", Value::num(self.seed as f64)),
            ("pallas_kernel", Value::Bool(self.pallas_kernel)),
        ])
    }

    /// Validate against a concrete preset (block counts etc).
    pub fn validate(&self, preset: &Preset) -> Result<()> {
        if self.train.steps == 0 {
            return Err(anyhow!("train.steps must be > 0"));
        }
        if self.train.steps_per_epoch == 0 {
            return Err(anyhow!("train.steps_per_epoch must be > 0"));
        }
        if let Some(pct) = self.method.selection_pct() {
            if !(0.0..=100.0).contains(&pct) {
                return Err(anyhow!("selection pct {pct} out of (0, 100]"));
            }
            let min = preset.min_selection_pct();
            if pct < min {
                return Err(anyhow!(
                    "selection pct {pct:.1}% < paper guideline min {min:.1}% \
                     (must update at least one of {} blocks per iteration)",
                    preset.n_blocks()
                ));
            }
        }
        if let Method::Fixed { blocks } = &self.method {
            if blocks.is_empty() {
                return Err(anyhow!("fixed method needs at least one block"));
            }
            if blocks.iter().any(|&b| b >= preset.n_blocks()) {
                return Err(anyhow!("fixed block index out of range"));
            }
        }
        if let Method::AdaGradSelect { eps0, delta, .. } = &self.method {
            if !(0.0..=1.0).contains(eps0) {
                return Err(anyhow!("eps0 must be in [0, 1]"));
            }
            if *delta <= 0.0 {
                return Err(anyhow!("delta must be > 0"));
            }
        }
        Ok(())
    }

    /// Learning rate at a step: linear warmup then cosine decay.
    ///
    /// Delegates to [`crate::optimizer::lr_cosine`], the same f32-step
    /// formula the device-resident `train_step_fused` entry evaluates
    /// from its on-device schedule tensor — one definition on both sides
    /// of the backend boundary, so host-loop and device-resident runs see
    /// bit-identical learning rates.
    pub fn lr_at(&self, step: u64) -> f32 {
        let t = &self.train;
        crate::optimizer::lr_cosine(
            t.lr,
            t.warmup_steps as f32,
            t.steps as f32,
            t.min_lr_frac,
            step as f32,
        )
    }

    /// The `train_step_fused` schedule tensor: `[lr, warmup_steps,
    /// total_steps, min_lr_frac]`, uploaded once at trainer construction
    /// and consumed on device by [`crate::optimizer::lr_cosine`].
    pub fn lr_schedule_tensor(&self) -> [f32; 4] {
        let t = &self.train;
        [t.lr, t.warmup_steps as f32, t.steps as f32, t.min_lr_frac]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn preset() -> Preset {
        Manifest::builtin().preset("qwen-sim").unwrap().clone()
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = RunConfig::preset_defaults("qwen-sim");
        cfg.train.steps = 77;
        cfg.train.grad_clip = None;
        cfg.method = Method::Lora { double_rank: true };
        let text = cfg.to_json().to_string();
        let back = RunConfig::from_json_str(&text).unwrap();
        assert_eq!(back.preset, "qwen-sim");
        assert_eq!(back.method, cfg.method);
        assert_eq!(back.train.steps, 77);
        assert_eq!(back.train.grad_clip, None);
    }

    #[test]
    fn validates_min_pct_guideline() {
        let p = preset();
        let mut cfg = RunConfig::preset_defaults("qwen-sim");
        cfg.method = Method::TopK { pct: 1.0 }; // below 100/27 ≈ 3.7%
        assert!(cfg.validate(&p).is_err());
        cfg.method = Method::TopK { pct: 10.0 };
        cfg.validate(&p).unwrap();
    }

    #[test]
    fn validates_adagrad_params() {
        let p = preset();
        let mut cfg = RunConfig::preset_defaults("qwen-sim");
        cfg.method = Method::AdaGradSelect {
            pct: 20.0,
            eps0: 1.5,
            lambda: None,
            delta: 1.0,
            explore_after_epoch1: false,
            uniform_exploit: false,
        };
        assert!(cfg.validate(&p).is_err());
    }

    #[test]
    fn lr_schedule_shape() {
        let mut cfg = RunConfig::preset_defaults("qwen-sim");
        cfg.train.lr = 1.0;
        cfg.train.warmup_steps = 10;
        cfg.train.steps = 110;
        cfg.train.min_lr_frac = 0.1;
        assert!(cfg.lr_at(0) < 0.2);
        assert!((cfg.lr_at(9) - 1.0).abs() < 1e-6);
        assert!(cfg.lr_at(60) < 1.0);
        assert!((cfg.lr_at(109) - 0.1).abs() < 0.02);
    }

    #[test]
    fn method_labels_stable() {
        assert_eq!(Method::Full.label(), "full-ft");
        assert_eq!(Method::TopK { pct: 10.0 }.label(), "topk-10%");
        assert_eq!(
            Method::Lora { double_rank: true }.label(),
            "lora-r2"
        );
    }

    #[test]
    fn parses_method_json() {
        let text = r#"{"preset": "qwen-sim", "method": {"kind": "adagradselect", "pct": 20.0}}"#;
        let cfg = RunConfig::from_json_str(text).unwrap();
        match cfg.method {
            Method::AdaGradSelect { pct, eps0, delta, .. } => {
                assert_eq!(pct, 20.0);
                assert_eq!(eps0, 1.0);
                assert_eq!(delta, 1.0);
            }
            _ => panic!("wrong method"),
        }
    }
}
