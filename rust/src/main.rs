//! `agsel` — the AdaGradSelect launcher.
//!
//! ```text
//! agsel <command> [flags]
//!
//! commands:
//!   train          fine-tune a preset with a chosen method
//!   eval           evaluate a saved checkpoint on the synthetic suites
//!   memory-report  print the §3.3 deterministic memory table
//!   exp <which>    regenerate paper experiments
//!                  (fig1 | fig3 | fig4 | table1 | ablations | all)
//!   inspect        list presets and their artifacts
//!
//! common flags: --backend reference|pjrt (default reference)
//!               --artifacts DIR (pjrt only) --out DIR (results)
//! train flags:  --preset P --method M --pct X --steps N --steps-per-epoch N
//!               --seed S --metrics FILE --save FILE --config FILE.json
//!               --pallas --no-eval
//! exp flags:    --steps N --steps-per-epoch N --eval-problems N
//!               --presets a,b,c --seed S
//! ```

use std::path::PathBuf;

use adagradselect::config::{Method, RunConfig};
use adagradselect::data::{MathGen, Split, Suite};
use adagradselect::eval::Evaluator;
use adagradselect::experiments::{self, ExpOptions};
use adagradselect::memory::{method_memory, pct_reduction};
use adagradselect::runtime::ReferenceBackend;
use adagradselect::serve::KvBackend;
use adagradselect::telemetry::markdown_table;
use adagradselect::train::Trainer;
use adagradselect::util::cli::Args;
use adagradselect::{anyhow, Result};

const USAGE: &str = "usage: agsel <train|eval|memory-report|exp|inspect> [flags]; see `agsel help`";

fn parse_method(name: &str, pct: f64) -> Result<Method> {
    Ok(match name {
        "full" | "fft" => Method::Full,
        "topk" => Method::TopK { pct },
        "adagradselect" | "ags" => Method::ags(pct),
        "lora" => Method::Lora { double_rank: false },
        "lora2" => Method::Lora { double_rank: true },
        "random" | "lisa" => Method::Random { pct },
        "round-robin" => Method::RoundRobin { pct },
        "ucb" => Method::Ucb { pct, c: 0.5 },
        other => return Err(anyhow!("unknown method {other:?}")),
    })
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut args = Args::parse(&argv, &["pallas", "no-eval", "help"])?;
    let backend = args.str_or("backend", "reference");
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    std::fs::create_dir_all(&out_dir).ok();

    match backend.as_str() {
        "reference" | "cpu" | "native" => {
            dispatch(&ReferenceBackend::new(), &mut args, artifacts, out_dir)
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => dispatch(
            &adagradselect::runtime::Engine::load(&artifacts)?,
            &mut args,
            artifacts.clone(),
            out_dir,
        ),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => Err(anyhow!(
            "this binary was built without the `pjrt` feature; \
             rebuild with `cargo build --features pjrt`"
        )),
        other => Err(anyhow!("unknown backend {other:?} (reference|pjrt)")),
    }
}

fn dispatch<B: KvBackend>(
    backend: &B,
    args: &mut Args,
    artifacts: PathBuf,
    out_dir: PathBuf,
) -> Result<()> {
    let cmd = args.positional.first().cloned().unwrap_or_else(|| "help".into());
    match cmd.as_str() {
        "train" => cmd_train(backend, args, artifacts)?,
        "eval" => cmd_eval(backend, args)?,
        "memory-report" => cmd_memory(backend, args)?,
        "exp" => cmd_exp(backend, args, artifacts, out_dir)?,
        "inspect" => cmd_inspect(backend)?,
        "help" | "--help" => println!("{USAGE}"),
        other => return Err(anyhow!("unknown command {other:?}; {USAGE}")),
    }
    Ok(())
}

fn cmd_train<B: KvBackend>(backend: &B, args: &mut Args, artifacts: PathBuf) -> Result<()> {
    let preset = args.str_or("preset", "qwen-sim");
    let method = args.str_or("method", "adagradselect");
    let pct = args.f64_or("pct", 30.0)?;
    let steps = args.u64_or("steps", 300)?;
    let spe = args.u64_or("steps-per-epoch", 100)?;
    let seed = args.u64_or("seed", 0)?;
    let metrics = args.str_opt("metrics").map(PathBuf::from);
    let save = args.str_opt("save").map(PathBuf::from);
    let config = args.str_opt("config");
    let pallas = args.bool_flag("pallas");
    let no_eval = args.bool_flag("no-eval");
    args.finish()?;

    let mut cfg = match config {
        Some(p) => RunConfig::from_json_file(p)?,
        None => RunConfig::preset_defaults(&preset),
    };
    cfg.preset = preset;
    cfg.method = parse_method(&method, pct)?;
    cfg.train.steps = steps;
    cfg.train.steps_per_epoch = spe;
    cfg.artifacts_dir = artifacts;
    cfg.metrics_path = metrics;
    cfg.pallas_kernel = pallas;
    cfg.seed = seed;

    let mut trainer = Trainer::new(backend, cfg.clone())?;
    let summary = trainer.run()?;
    println!("{}", summary.to_json());

    let state = trainer.eval_state()?;
    if let Some(path) = save {
        state.save(&path)?;
        println!("saved checkpoint to {path:?}");
    }
    if !no_eval {
        let ev = Evaluator::new(backend, &cfg.preset, cfg.data.max_new_tokens)?;
        for suite in [Suite::Gsm8kSim, Suite::MathSim] {
            let probs = MathGen::new(suite, Split::Eval, cfg.seed)
                .problems(0, cfg.data.eval_problems);
            let res = ev.accuracy(&state, &probs)?;
            println!(
                "{}: accuracy {:.1}% ({}/{}), format rate {:.1}%, {} over-length skipped",
                suite.name(),
                res.accuracy * 100.0,
                res.n_correct,
                res.n,
                res.format_rate * 100.0,
                res.n_truncated
            );
        }
    }
    Ok(())
}

fn cmd_eval<B: KvBackend>(backend: &B, args: &mut Args) -> Result<()> {
    let preset = args.str_or("preset", "qwen-sim");
    let checkpoint = args
        .str_opt("checkpoint")
        .ok_or_else(|| anyhow!("--checkpoint required"))?;
    let problems = args.usize_or("problems", 128)?;
    args.finish()?;

    let state = adagradselect::model::ModelState::load(&checkpoint)?;
    let ev = Evaluator::new(backend, &preset, 40)?;
    for suite in [Suite::Gsm8kSim, Suite::MathSim] {
        let probs = MathGen::new(suite, Split::Eval, 0).problems(0, problems);
        let res = ev.accuracy(&state, &probs)?;
        println!(
            "{}: accuracy {:.1}% ({}/{}), {} over-length skipped",
            suite.name(),
            res.accuracy * 100.0,
            res.n_correct,
            res.n,
            res.n_truncated
        );
    }
    Ok(())
}

fn cmd_memory<B: KvBackend>(backend: &B, args: &mut Args) -> Result<()> {
    let preset = args.str_or("preset", "qwen-sim");
    let bpp = args.usize_or("bytes-per-param", 2)?;
    args.finish()?;

    let p = backend.manifest().preset(&preset)?;
    let full_opt = method_memory(p, &Method::Full, bpp).optimizer;
    let mut rows = Vec::new();
    for m in experiments::paper_methods() {
        let rep = method_memory(p, &m, bpp);
        rows.push(vec![
            m.label(),
            format!("{:.2}", rep.params as f64 / 1e6),
            format!("{:.2}", rep.grads as f64 / 1e6),
            format!("{:.2}", rep.optimizer as f64 / 1e6),
            format!("{:.2}", rep.activations as f64 / 1e6),
            format!("{:.2}", rep.total() as f64 / 1e6),
            format!("{:.1}%", pct_reduction(rep.optimizer, full_opt)),
        ]);
    }
    println!(
        "memory report for {preset} at {bpp} bytes/param (paper §3.3)\n\n{}",
        markdown_table(
            &["method", "params MB", "grads MB", "optimizer MB", "acts MB", "total MB", "opt reduction vs FFT"],
            &rows
        )
    );

    // paper-scale projection (same formulas at the published model sizes)
    let mut rows = Vec::new();
    for m in adagradselect::memory::PAPER_MODELS {
        for frac in [0.10, 0.30] {
            let rep = m.selective_report(frac, 16, 1024, bpp);
            rows.push(vec![
                m.name.to_string(),
                format!("ags-{:.0}%", frac * 100.0),
                format!("{:.2}", rep.optimizer as f64 / 1e9),
                format!("{:.2}", rep.total() as f64 / 1e9),
                format!("{:.1}%", m.total_reduction_pct(frac, 16, 1024, bpp)),
            ]);
        }
        let full = m.full_report(16, 1024, bpp);
        rows.push(vec![
            m.name.to_string(),
            "full-ft".into(),
            format!("{:.2}", full.optimizer as f64 / 1e9),
            format!("{:.2}", full.total() as f64 / 1e9),
            "0.0%".into(),
        ]);
    }
    println!(
        "paper-scale projection (batch 16, seq 1024, {bpp} B/param)\n\n{}",
        markdown_table(
            &["model", "method", "optimizer GB", "total GB", "total reduction"],
            &rows
        )
    );
    Ok(())
}

fn cmd_exp<B: KvBackend>(
    backend: &B,
    args: &mut Args,
    artifacts: PathBuf,
    out_dir: PathBuf,
) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .cloned()
        .ok_or_else(|| anyhow!("exp needs a target: fig1|fig3|fig4|table1|ablations|all"))?;
    let opt = ExpOptions {
        artifacts_dir: artifacts,
        out_dir: out_dir.clone(),
        steps: args.u64_or("steps", 300)?,
        steps_per_epoch: args.u64_or("steps-per-epoch", 100)?,
        eval_problems: args.usize_or("eval-problems", 128)?,
        seed: args.u64_or("seed", 0)?,
    };
    let presets = args.str_or("presets", "qwen-sim,llama-sim,phi-sim");
    let pcts_raw = args.str_or("pcts", "4,10,20,30,50,75,100");
    args.finish()?;
    let preset_list: Vec<&str> = presets.split(',').filter(|s| !s.is_empty()).collect();
    let pcts: Vec<f64> = pcts_raw
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();

    match which.as_str() {
        "fig1" => {
            experiments::fig1(backend, &opt)?;
        }
        "fig3" => {
            experiments::fig3(backend, &opt, &pcts)?;
        }
        "fig4" => experiments::fig4(backend, &opt)?,
        "table1" => {
            experiments::table1(backend, &opt, &preset_list)?;
        }
        "ablations" => {
            experiments::ablations(backend, &opt)?;
        }
        "all" => experiments::all(backend, &opt, &preset_list, &pcts)?,
        other => return Err(anyhow!("unknown experiment {other:?}")),
    }
    println!("experiment outputs written to {out_dir:?}");
    Ok(())
}

fn cmd_inspect<B: KvBackend>(backend: &B) -> Result<()> {
    println!("backend: {}", backend.platform());
    let manifest = backend.manifest();
    let mut names: Vec<_> = manifest.presets.keys().collect();
    names.sort();
    for name in names {
        let p = &manifest.presets[name];
        let mut arts: Vec<_> = p.artifacts.keys().cloned().collect();
        arts.sort();
        println!(
            "{name}: {} blocks, {} params, d={}, L={}, artifacts: {arts:?}",
            p.n_blocks(),
            p.total_params,
            p.model.d_model,
            p.model.n_layers,
        );
    }
    Ok(())
}
