//! Scheduler page-budget auditor.

/// Re-check the admission-control solvency law: every page the engine
/// has *promised* to running sequences (`reserved`) must be backed by a
/// page it can actually produce — one already `held` by a slot's table,
/// one on the `free` list, or one reclaimable from the prefix cache
/// (`evictable`, entries only the cache references). If the promise
/// exceeds the backing, a decode step can hit an unrecoverable
/// out-of-pages error even though admission said yes.
///
/// The caller re-derives all four quantities from the live structures
/// (active list, pool, cache) rather than trusting the engine's own
/// `page_budget` arithmetic — that is the point of the audit.
pub fn check_budget(reserved: usize, held: usize, free: usize, evictable: usize) -> Vec<String> {
    let mut violations = Vec::new();
    let backing = held + free + evictable;
    if reserved > backing {
        violations.push(format!(
            "budget: {reserved} pages promised but only {backing} exist \
             ({held} held + {free} free + {evictable} evictable)"
        ));
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solvent_budget_is_clean() {
        assert!(check_budget(0, 0, 0, 0).is_empty());
        assert!(check_budget(6, 2, 3, 1).is_empty());
        assert!(check_budget(5, 2, 3, 1).is_empty());
    }

    #[test]
    fn overcommitted_budget_fires() {
        let v = check_budget(10, 2, 3, 1);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("10 pages promised"), "{v:?}");
    }
}
