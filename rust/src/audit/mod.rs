//! Shadow-state invariant auditors for the unsafe hot paths.
//!
//! Each validator re-derives a subsystem's invariants **from first
//! principles** — independent of the counters the subsystem maintains
//! incrementally — and reports every violation as a human-readable
//! string. An empty report means the state is sound; a non-empty one
//! means incremental bookkeeping has drifted from reality (a leaked
//! page, a double-release, a budget promise the pool cannot back, an
//! aliased arena slab, a NaN escaping a kernel).
//!
//! The validators themselves compile unconditionally (so `cargo check`
//! and the default test lane keep them honest), but the *hooks* that run
//! them on the hot paths — [`crate::serve::ServeEngine`]'s post-step
//! check and the trainer's per-step backend audit — are gated behind the
//! `audit` cargo feature. With the feature off the hooks are compiled
//! out entirely: zero branches, zero cost, bit-identical outputs (the
//! `audit/compiled_out` bench invariant pins this). With
//! `--features audit` every engine step and train step pays a full
//! re-derivation pass and panics/errors on the first violation.
//!
//! ```text
//! cargo test --features audit            # full suite with validators on
//! cargo test --features audit --test audit_props   # randomized churn
//! ```

pub mod budget;
pub mod finite;
pub mod kv;

pub use budget::check_budget;
pub use finite::{assert_finite, check_finite};
pub use kv::check_kv_pool;
