//! Paged-KV shadow refcount auditor.

use crate::serve::kv::KvPool;
use crate::serve::prefix::PrefixCache;

/// Rebuild every page's reference count from scratch — walk all in-use
/// slots' page tables plus the prefix cache's entry pages — and compare
/// against the pool's incremental `refc` bookkeeping, then re-check the
/// free list, the allocation ledger and the slot accounting. Catches
/// leaks (a page no table maps but `refc > 0` keeps off the free list),
/// double-releases (shadow count above the recorded one), and COW drift
/// (a fork that forgot to drop the old page's reference).
pub fn check_kv_pool(pool: &KvPool, cache: &PrefixCache) -> Vec<String> {
    let mut violations = Vec::new();
    let n_pages = pool.n_pages();

    // shadow refcounts: one reference per table entry, one per cache entry
    let mut shadow = vec![0u32; n_pages];
    let mut slots_in_use = 0usize;
    for slot in 0..pool.n_slots() {
        if !pool.is_in_use(slot) {
            if !pool.table(slot).is_empty() {
                violations.push(format!(
                    "kv: free slot {slot} still maps {} pages",
                    pool.table(slot).len()
                ));
            }
            continue;
        }
        slots_in_use += 1;
        if pool.len(slot) > pool.mapped_rows(slot) {
            violations.push(format!(
                "kv: slot {slot} caches {} rows but maps only {}",
                pool.len(slot),
                pool.mapped_rows(slot)
            ));
        }
        if pool.len(slot) > pool.capacity() {
            violations.push(format!(
                "kv: slot {slot} caches {} rows beyond the {}-row capacity",
                pool.len(slot),
                pool.capacity()
            ));
        }
        for &page in pool.table(slot) {
            if (page as usize) < n_pages {
                shadow[page as usize] += 1;
            } else {
                violations.push(format!(
                    "kv: slot {slot} maps page {page} out of range 0..{n_pages}"
                ));
            }
        }
    }
    for page in cache.entry_pages() {
        if (page as usize) < n_pages {
            shadow[page as usize] += 1;
        } else {
            violations
                .push(format!("kv: prefix cache holds page {page} out of range 0..{n_pages}"));
        }
    }
    for (page, &expect) in shadow.iter().enumerate() {
        let got = pool.page_ref(page as u32);
        if got != expect {
            violations.push(format!(
                "kv: page {page} refcount drift: pool records {got}, \
                 tables + prefix cache reference it {expect} time(s)"
            ));
        }
    }

    // free list: in range, duplicate-free, refcount zero — and complete
    // (every zero-refcount page is on it, else the page leaked)
    let mut on_free_list = vec![false; n_pages];
    for &page in pool.free_page_ids() {
        if page as usize >= n_pages {
            violations.push(format!("kv: free list holds page {page} out of range 0..{n_pages}"));
            continue;
        }
        if on_free_list[page as usize] {
            violations.push(format!("kv: page {page} appears twice on the free list"));
        }
        on_free_list[page as usize] = true;
        if pool.page_ref(page) != 0 {
            violations.push(format!(
                "kv: free-listed page {page} has refcount {}",
                pool.page_ref(page)
            ));
        }
    }
    for page in 0..n_pages {
        if pool.page_ref(page as u32) == 0 && !on_free_list[page] {
            violations.push(format!("kv: page {page} leaked (refcount 0 but not on the free list)"));
        }
    }

    // allocation ledger: claims minus returns must equal live pages
    let live = pool.pages_in_use() as u64;
    if pool.pages_allocated() < pool.pages_released() {
        violations.push(format!(
            "kv: ledger underflow: {} pages released but only {} allocated",
            pool.pages_released(),
            pool.pages_allocated()
        ));
    } else if pool.pages_allocated() - pool.pages_released() != live {
        violations.push(format!(
            "kv: ledger drift: allocated {} - released {} != {live} pages in use",
            pool.pages_allocated(),
            pool.pages_released()
        ));
    }

    // slot accounting: free slots + in-use slots must cover the pool
    if pool.n_free() + slots_in_use != pool.n_slots() {
        violations.push(format!(
            "kv: slot drift: {} free + {slots_in_use} in use != {} slots",
            pool.n_free(),
            pool.n_slots()
        ));
    }

    violations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Manifest, ModelSpec};

    fn model() -> ModelSpec {
        Manifest::builtin().preset("test-tiny").unwrap().model.clone()
    }

    #[test]
    fn sound_pool_is_clean_through_share_and_cow() {
        let m = model();
        let mut pool = KvPool::new(&m, 3);
        let cache = PrefixCache::new();
        assert!(check_kv_pool(&pool, &cache).is_empty(), "fresh pool");
        let p = pool.page_size();
        let a = pool.alloc().unwrap();
        pool.ensure_room(a, p + 1).unwrap();
        pool.set_len(a, p + 1);
        assert!(check_kv_pool(&pool, &cache).is_empty(), "after prefill");
        let stem = pool.table(a)[0];
        let b = pool.alloc().unwrap();
        pool.attach_shared(b, &[stem], p - 1);
        assert!(check_kv_pool(&pool, &cache).is_empty(), "after share");
        pool.make_row_writable(b, p - 1).unwrap();
        assert!(check_kv_pool(&pool, &cache).is_empty(), "after COW fork");
        pool.release(b);
        pool.release(a);
        assert!(check_kv_pool(&pool, &cache).is_empty(), "after release");
    }

    #[test]
    fn refcount_drift_fires() {
        let m = model();
        let mut pool = KvPool::new(&m, 2);
        let cache = PrefixCache::new();
        let a = pool.alloc().unwrap();
        pool.ensure_room(a, 1).unwrap();
        // an extra reference nothing maps: exactly what a leaked
        // prefix-cache retain or a missed COW decrement looks like
        let page = pool.table(a)[0];
        pool.retain_page(page);
        let v = check_kv_pool(&pool, &cache);
        assert!(
            v.iter().any(|s| s.contains("refcount drift")),
            "auditor must flag the drift: {v:?}"
        );
    }

    #[test]
    fn cache_references_are_counted() {
        let m = model();
        let mut pool = KvPool::new(&m, 2);
        let mut cache = PrefixCache::new();
        let a = pool.alloc().unwrap();
        let p = pool.page_size();
        pool.ensure_room(a, p).unwrap();
        pool.set_len(a, p);
        let tokens: Vec<i32> = (0..p as i32).collect();
        let table = pool.table(a).to_vec();
        cache.insert(&tokens, &table, &mut pool);
        assert!(check_kv_pool(&pool, &cache).is_empty(), "cache retain is not drift");
        pool.release(a);
        assert!(check_kv_pool(&pool, &cache).is_empty(), "cache keeps the stem alive");
        cache.clear(&mut pool);
        assert!(check_kv_pool(&pool, &cache).is_empty(), "clear releases cleanly");
    }
}
