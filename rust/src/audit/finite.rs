//! Finite-ness (NaN / inf) probes for kernel boundaries.

/// Scan a kernel output for non-finite values. Reports the first bad
/// index plus a total count, so a single poisoned lane and a fully
/// saturated buffer are distinguishable in the violation text.
pub fn check_finite(name: &str, xs: &[f32]) -> Vec<String> {
    let mut violations = Vec::new();
    let bad = xs.iter().filter(|x| !x.is_finite()).count();
    if bad > 0 {
        let first = xs.iter().position(|x| !x.is_finite()).unwrap_or(0);
        violations.push(format!(
            "finite: {name}: {bad}/{} non-finite values (first at index {first}: {})",
            xs.len(),
            xs[first]
        ));
    }
    violations
}

/// Panic on the first non-finite value — the hot-path hook form, used
/// under `cfg(feature = "audit")` at kernel boundaries.
pub fn assert_finite(name: &str, xs: &[f32]) {
    let v = check_finite(name, xs);
    assert!(v.is_empty(), "audit failed:\n{}", v.join("\n"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finite_buffers_are_clean() {
        assert!(check_finite("x", &[]).is_empty());
        assert!(check_finite("x", &[0.0, -1.5, f32::MAX, f32::MIN_POSITIVE]).is_empty());
    }

    #[test]
    fn nan_and_inf_fire() {
        let v = check_finite("logits", &[1.0, f32::NAN, f32::INFINITY]);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("logits") && v[0].contains("2/3"), "{v:?}");
        assert!(v[0].contains("index 1"), "{v:?}");
        assert!(!check_finite("g", &[f32::NEG_INFINITY]).is_empty());
    }

    #[test]
    #[should_panic(expected = "audit failed")]
    fn assert_form_panics() {
        assert_finite("x", &[f32::NAN]);
    }
}
