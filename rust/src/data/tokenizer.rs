//! Char-level tokenizer, built from the manifest's vocabulary string so
//! Rust and the build-time Python side can never drift.

use crate::runtime::TokenizerSpec;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
    pub unk: i32,
    pub vocab_size: usize,
    char_to_id: std::collections::HashMap<char, i32>,
    id_to_char: Vec<Option<char>>,
}

impl Tokenizer {
    pub fn from_spec(spec: &TokenizerSpec) -> Self {
        let mut char_to_id = std::collections::HashMap::new();
        let mut id_to_char = vec![None; spec.vocab_size];
        for (i, c) in spec.chars.chars().enumerate() {
            let id = 4 + i as i32;
            char_to_id.insert(c, id);
            id_to_char[id as usize] = Some(c);
        }
        Self {
            pad: spec.pad,
            bos: spec.bos,
            eos: spec.eos,
            unk: spec.unk,
            vocab_size: spec.vocab_size,
            char_to_id,
            id_to_char,
        }
    }

    pub fn encode(&self, text: &str, bos: bool, eos: bool) -> Vec<i32> {
        let mut ids = Vec::with_capacity(text.len() + 2);
        if bos {
            ids.push(self.bos);
        }
        for c in text.chars().flat_map(|c| c.to_lowercase()) {
            ids.push(*self.char_to_id.get(&c).unwrap_or(&self.unk));
        }
        if eos {
            ids.push(self.eos);
        }
        ids
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter_map(|&i| {
                self.id_to_char.get(i as usize).copied().flatten()
            })
            .collect()
    }

    /// Decode stopping at the first EOS (for generated continuations).
    pub fn decode_until_eos(&self, ids: &[i32]) -> String {
        let end = ids.iter().position(|&i| i == self.eos).unwrap_or(ids.len());
        self.decode(&ids[..end])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn tok() -> Tokenizer {
        Tokenizer::from_spec(&Manifest::builtin().tokenizer)
    }

    #[test]
    fn roundtrip() {
        let t = tok();
        let s = "alice has 3 apples. #### 42\n";
        let ids = t.encode(s, true, true);
        assert_eq!(ids[0], t.bos);
        assert_eq!(*ids.last().unwrap(), t.eos);
        assert_eq!(t.decode(&ids[1..ids.len() - 1]), s);
    }

    #[test]
    fn unknown_char_is_unk() {
        let t = tok();
        assert_eq!(t.encode("~", false, false), vec![t.unk]);
    }

    #[test]
    fn uppercase_folds() {
        let t = tok();
        assert_eq!(t.encode("AbC", false, false), t.encode("abc", false, false));
    }

    #[test]
    fn ids_in_vocab_range() {
        let t = tok();
        for id in t.encode("9z+ #:'%$\n", true, true) {
            assert!((0..t.vocab_size as i32).contains(&id));
        }
    }

    #[test]
    fn decode_until_eos_stops() {
        let t = tok();
        let mut ids = t.encode("12", false, false);
        ids.push(t.eos);
        ids.extend(t.encode("junk", false, false));
        assert_eq!(t.decode_until_eos(&ids), "12");
    }
}
