//! Synthetic math-word-problem generator (MetaMathQA / GSM8K / MATH
//! stand-in).
//!
//! Problems follow the GSM8K answer convention the paper's eval harness
//! relies on: free-text reasoning terminated by `#### <integer>`. The
//! generator is fully deterministic from `(suite, split, index)` so train
//! and eval sets are reproducible and disjoint-by-construction (different
//! seed namespaces; the eval extractor also never sees train indices).
//!
//! `gsm8k-sim`: 1–3 arithmetic steps over small operands, phrased as
//! templated word problems — learnable by a char-level SLM in a few
//! hundred steps, yet hard enough that untrained models score ~0.
//! `math-sim`: 3–5 step expressions with larger operands, `mod` and
//! squares — the harder benchmark where all methods score lower (matching
//! the paper's GSM8K-vs-MATH gap).

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Suite {
    Gsm8kSim,
    MathSim,
    /// Interleaved gsm8k-sim + math-sim — the MetaMathQA-40K stand-in
    /// (the paper's training set spans both problem families).
    Mixed,
}

impl Suite {
    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "gsm8k-sim" | "gsm8k" => Some(Suite::Gsm8kSim),
            "math-sim" | "math" => Some(Suite::MathSim),
            "mixed" | "metamath-sim" => Some(Suite::Mixed),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Suite::Gsm8kSim => "gsm8k-sim",
            Suite::MathSim => "math-sim",
            Suite::Mixed => "mixed",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Eval,
}

#[derive(Debug, Clone)]
pub struct Problem {
    pub question: String,
    pub reasoning: String,
    pub answer: i64,
}

impl Problem {
    /// Full supervised text: `q: …\na: … #### n`.
    pub fn full_text(&self) -> String {
        format!("q: {}\na: {} #### {}", self.question, self.reasoning, self.answer)
    }

    /// Prompt shown at eval time (model must produce reasoning + answer).
    pub fn prompt(&self) -> String {
        format!("q: {}\na: ", self.question)
    }
}

/// Extract the `#### <integer>` answer from generated text, if any.
pub fn extract_answer(text: &str) -> Option<i64> {
    let idx = text.rfind("####")?;
    let tail = &text[idx + 4..];
    let tail = tail.trim_start();
    let end = tail
        .char_indices()
        .take_while(|(i, c)| c.is_ascii_digit() || (*i == 0 && *c == '-'))
        .map(|(i, c)| i + c.len_utf8())
        .last()?;
    tail[..end].parse().ok()
}

const NAMES: [&str; 8] = ["alice", "ben", "carla", "dev", "emma", "farid", "gia", "hana"];
const ITEMS: [&str; 8] =
    ["apples", "books", "coins", "pens", "cards", "shells", "stamps", "marbles"];

pub struct MathGen {
    suite: Suite,
    split: Split,
    seed: u64,
}

impl MathGen {
    pub fn new(suite: Suite, split: Split, seed: u64) -> Self {
        Self { suite, split, seed }
    }

    fn rng_for(&self, index: u64) -> Rng {
        // disjoint namespaces: split tag ^ suite tag ^ user seed ^ index
        let split_tag: u64 = match self.split {
            Split::Train => 0x5452_4149_4E00_0000,
            Split::Eval => 0x4556_414C_0000_0000,
        };
        let suite_tag: u64 = match self.suite {
            Suite::Gsm8kSim => 0x1111,
            Suite::MathSim => 0x2222,
            Suite::Mixed => unreachable!("mixed resolves to a concrete suite"),
        };
        Rng::seed_from_u64(
            split_tag ^ suite_tag ^ self.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ index,
        )
    }

    /// Deterministic problem #`index` of this (suite, split, seed).
    pub fn problem(&self, index: u64) -> Problem {
        // Mixed interleaves the two families with disjoint sub-indices.
        let (suite, index) = match self.suite {
            Suite::Mixed => (
                if index % 2 == 0 { Suite::Gsm8kSim } else { Suite::MathSim },
                index / 2,
            ),
            s => (s, index),
        };
        let mut rng = MathGen { suite, split: self.split, seed: self.seed }.rng_for(index);
        match suite {
            Suite::Gsm8kSim => gsm8k_problem(&mut rng),
            Suite::MathSim => math_problem(&mut rng),
            Suite::Mixed => unreachable!(),
        }
    }

    pub fn problems(&self, start: u64, count: usize) -> Vec<Problem> {
        (start..start + count as u64).map(|i| self.problem(i)).collect()
    }
}

fn gsm8k_problem(rng: &mut Rng) -> Problem {
    let name = NAMES[rng.gen_range(0, NAMES.len())];
    let other = NAMES[rng.gen_range(0, NAMES.len())];
    let item = ITEMS[rng.gen_range(0, ITEMS.len())];
    match rng.gen_range(0, 6) as u32 {
        0 => {
            // gain
            let a = rng.gen_range_i64(2, 10);
            let b = rng.gen_range_i64(2, 10);
            Problem {
                question: format!(
                    "{name} has {a} {item}. {other} gives {name} {b} more. how many {item} does {name} have?"
                ),
                reasoning: format!("{a} + {b} = {}", a + b),
                answer: a + b,
            }
        }
        1 => {
            // loss
            let a = rng.gen_range_i64(5, 15);
            let b = rng.gen_range_i64(1, a);
            Problem {
                question: format!(
                    "{name} has {a} {item}. {name} gives {b} to {other}. how many {item} are left?"
                ),
                reasoning: format!("{a} - {b} = {}", a - b),
                answer: a - b,
            }
        }
        2 => {
            // multiply
            let a = rng.gen_range_i64(2, 7);
            let b = rng.gen_range_i64(2, 7);
            Problem {
                question: format!(
                    "{name} has {a} bags with {b} {item} in each bag. how many {item} in total?"
                ),
                reasoning: format!("{a} * {b} = {}", a * b),
                answer: a * b,
            }
        }
        3 => {
            // two-step: gain then loss
            let a = rng.gen_range_i64(3, 10);
            let b = rng.gen_range_i64(2, 8);
            let c = rng.gen_range_i64(1, a + b);
            Problem {
                question: format!(
                    "{name} has {a} {item}, buys {b} more, then loses {c}. how many {item} now?"
                ),
                reasoning: format!("{a} + {b} = {}. {} - {c} = {}", a + b, a + b, a + b - c),
                answer: a + b - c,
            }
        }
        4 => {
            // two-step: multiply then add
            let a = rng.gen_range_i64(2, 6);
            let b = rng.gen_range_i64(2, 6);
            let c = rng.gen_range_i64(1, 9);
            Problem {
                question: format!(
                    "{name} has {a} boxes of {b} {item} and {c} loose {item}. how many {item} in total?"
                ),
                reasoning: format!("{a} * {b} = {}. {} + {c} = {}", a * b, a * b, a * b + c),
                answer: a * b + c,
            }
        }
        _ => {
            // share equally
            let b = rng.gen_range_i64(2, 6);
            let q = rng.gen_range_i64(2, 8);
            let a = b * q;
            Problem {
                question: format!(
                    "{name} shares {a} {item} equally among {b} friends. how many {item} does each friend get?"
                ),
                reasoning: format!("{a} / {b} = {q}"),
                answer: q,
            }
        }
    }
}

fn math_problem(rng: &mut Rng) -> Problem {
    match rng.gen_range(0, 4) as u32 {
        0 => {
            // (a*b + c) mod d
            let a = rng.gen_range_i64(3, 13);
            let b = rng.gen_range_i64(3, 13);
            let c = rng.gen_range_i64(2, 20);
            let d = rng.gen_range_i64(3, 10);
            let t1 = a * b;
            let t2 = t1 + c;
            Problem {
                question: format!("compute ({a} * {b} + {c}) mod {d}."),
                reasoning: format!(
                    "{a} * {b} = {t1}. {t1} + {c} = {t2}. {t2} mod {d} = {}",
                    t2 % d
                ),
                answer: t2 % d,
            }
        }
        1 => {
            // a^2 - b
            let a = rng.gen_range_i64(3, 12);
            let b = rng.gen_range_i64(1, 25);
            let t1 = a * a;
            Problem {
                question: format!("compute {a} * {a} - {b}."),
                reasoning: format!("{a} * {a} = {t1}. {t1} - {b} = {}", t1 - b),
                answer: t1 - b,
            }
        }
        2 => {
            // a*b - c*d
            let a = rng.gen_range_i64(2, 10);
            let b = rng.gen_range_i64(2, 10);
            let c = rng.gen_range_i64(2, 6);
            let d = rng.gen_range_i64(2, 6);
            let (t1, t2) = (a * b, c * d);
            Problem {
                question: format!("compute {a} * {b} - {c} * {d}."),
                reasoning: format!("{a} * {b} = {t1}. {c} * {d} = {t2}. {t1} - {t2} = {}", t1 - t2),
                answer: t1 - t2,
            }
        }
        _ => {
            // ((a + b) * c) mod d, three steps
            let a = rng.gen_range_i64(2, 15);
            let b = rng.gen_range_i64(2, 15);
            let c = rng.gen_range_i64(2, 7);
            let d = rng.gen_range_i64(3, 11);
            let t1 = a + b;
            let t2 = t1 * c;
            Problem {
                question: format!("compute (({a} + {b}) * {c}) mod {d}."),
                reasoning: format!(
                    "{a} + {b} = {t1}. {t1} * {c} = {t2}. {t2} mod {d} = {}",
                    t2 % d
                ),
                answer: t2 % d,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixed_interleaves_families() {
        let g = MathGen::new(Suite::Mixed, Split::Train, 0);
        let a = g.problem(0);
        let b = g.problem(1);
        // even indices are word problems, odd are compute expressions
        assert!(!a.question.starts_with("compute"));
        assert!(b.question.starts_with("compute"));
        // sub-index mapping matches the concrete suites
        let gs = MathGen::new(Suite::Gsm8kSim, Split::Train, 0);
        assert_eq!(a.full_text(), gs.problem(0).full_text());
    }

    #[test]
    fn deterministic_per_index() {
        let g = MathGen::new(Suite::Gsm8kSim, Split::Train, 0);
        assert_eq!(g.problem(5).full_text(), g.problem(5).full_text());
        assert_ne!(g.problem(5).full_text(), g.problem(6).full_text());
    }

    #[test]
    fn train_eval_disjoint_streams() {
        let tr = MathGen::new(Suite::Gsm8kSim, Split::Train, 0);
        let ev = MathGen::new(Suite::Gsm8kSim, Split::Eval, 0);
        let same = (0..50).filter(|&i| tr.problem(i).full_text() == ev.problem(i).full_text()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn reasoning_is_consistent_with_answer() {
        for suite in [Suite::Gsm8kSim, Suite::MathSim] {
            let g = MathGen::new(suite, Split::Train, 3);
            for i in 0..200 {
                let p = g.problem(i);
                // last number in the reasoning must be the answer
                let last_num: i64 = p
                    .reasoning
                    .split(|c: char| !(c.is_ascii_digit() || c == '-'))
                    .filter(|s| !s.is_empty())
                    .last()
                    .unwrap()
                    .parse()
                    .unwrap();
                assert_eq!(last_num, p.answer, "{suite:?} #{i}: {}", p.full_text());
            }
        }
    }

    #[test]
    fn extract_answer_works() {
        assert_eq!(extract_answer("3 + 4 = 7 #### 7"), Some(7));
        assert_eq!(extract_answer("x #### -12\n"), Some(-12));
        assert_eq!(extract_answer("#### 5 then #### 9!"), Some(9));
        assert_eq!(extract_answer("no marker"), None);
        assert_eq!(extract_answer("#### notanum"), None);
    }

    #[test]
    fn answers_extractable_from_full_text() {
        for suite in [Suite::Gsm8kSim, Suite::MathSim] {
            let g = MathGen::new(suite, Split::Eval, 9);
            for i in 0..100 {
                let p = g.problem(i);
                assert_eq!(extract_answer(&p.full_text()), Some(p.answer));
            }
        }
    }

    #[test]
    fn problems_fit_sequence_budget() {
        // all generated text must fit the smallest sim preset seq (128)
        for suite in [Suite::Gsm8kSim, Suite::MathSim] {
            let g = MathGen::new(suite, Split::Train, 1);
            for i in 0..500 {
                let p = g.problem(i);
                assert!(
                    p.full_text().len() + 2 <= 128,
                    "{suite:?} #{i} too long: {} chars",
                    p.full_text().len()
                );
            }
        }
    }

    #[test]
    fn math_sim_is_harder_than_gsm8k_sim() {
        // proxy: average reasoning step count
        let steps = |suite| {
            let g = MathGen::new(suite, Split::Train, 0);
            (0..200)
                .map(|i| g.problem(i).reasoning.matches('=').count())
                .sum::<usize>() as f64
                / 200.0
        };
        assert!(steps(Suite::MathSim) > steps(Suite::Gsm8kSim));
    }
}
