//! Batching: problems → padded `[batch, seq]` token/target matrices.
//!
//! Each row is one problem: `<bos> q: … \na: … #### n <eos>` followed by
//! PAD. Inputs are `seq[:-1]`-style (tokens), targets are the same row
//! shifted left by one with PAD beyond the text — the L2 loss masks PAD
//! targets, so padding positions contribute nothing.

use super::mathgen::MathGen;
use super::tokenizer::Tokenizer;

/// One training batch, flattened row-major for upload.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq_len: usize,
}

/// Infinite deterministic batch stream over a generator.
///
/// Under data-parallel sharding ([`TrainBatcher::shard`]) each rank
/// draws `batch / n` rows per step and the ranks *partition* the
/// unsharded stream step-aligned: on every step, rank `r` owns the
/// contiguous row range `[r·b_local, (r+1)·b_local)` of that step's
/// unsharded batch, so concatenating the rank batches in rank order
/// reproduces the unsharded batch byte-for-byte. (An alternative
/// design — reseeding each rank's generator with `seed ^ rank` — would
/// give disjoint but *different* problems than the single-worker
/// stream, breaking the bit-parity contract the sharded trainer is
/// held to, so the cursor partition is used instead.)
pub struct TrainBatcher {
    gen: MathGen,
    tok: Tokenizer,
    batch: usize,
    seq_len: usize,
    cursor: u64,
    /// Number of shards the global stream is split across (1 = unsharded).
    n_shards: u64,
    /// This batcher's rank in `0..n_shards`.
    rank: u64,
}

impl TrainBatcher {
    pub fn new(gen: MathGen, tok: Tokenizer, batch: usize, seq_len: usize) -> Self {
        Self { gen, tok, batch, seq_len, cursor: 0, n_shards: 1, rank: 0 }
    }

    /// Restrict this batcher to shard `rank` of `n`: it yields
    /// `batch / n` rows per step — rank `r`'s contiguous slice of the
    /// step's unsharded batch — so the union over ranks, taken in rank
    /// order within each step, equals the unsharded stream in order.
    /// `n` must divide the batch size; `shard(1, 0)` is the identity.
    pub fn shard(mut self, n: usize, rank: usize) -> Self {
        assert!(n > 0 && rank < n, "shard rank {rank} out of range for {n} shards");
        assert!(
            self.batch % n == 0,
            "{n} shards do not divide batch size {}",
            self.batch
        );
        self.batch /= n;
        self.n_shards = n as u64;
        self.rank = rank as u64;
        self
    }

    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    /// Rows this batcher yields per step (the local batch size).
    pub fn rows_per_step(&self) -> usize {
        self.batch
    }

    /// Map a local row counter to the global problem index: step
    /// `cursor / b_local` starts at `step · b_local · n` in the
    /// unsharded stream, rank `r` owns the `r`-th `b_local`-row slice.
    fn global_index(&self, cursor: u64) -> u64 {
        let b = self.batch as u64;
        (cursor / b) * (b * self.n_shards) + self.rank * b + (cursor % b)
    }

    /// Encode one problem row into (tokens, targets), both `seq_len` long.
    pub fn encode_row(&self, text: &str) -> (Vec<i32>, Vec<i32>) {
        let mut ids = self.tok.encode(text, true, true);
        ids.truncate(self.seq_len + 1); // keep one extra for the shift
        let mut tokens = vec![self.tok.pad; self.seq_len];
        let mut targets = vec![self.tok.pad; self.seq_len];
        let n_in = (ids.len() - 1).min(self.seq_len);
        tokens[..n_in].copy_from_slice(&ids[..n_in]);
        let n_tg = (ids.len() - 1).min(self.seq_len);
        targets[..n_tg].copy_from_slice(&ids[1..1 + n_tg]);
        (tokens, targets)
    }

    pub fn next_batch(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch * self.seq_len);
        let mut targets = Vec::with_capacity(self.batch * self.seq_len);
        for _ in 0..self.batch {
            let p = self.gen.problem(self.global_index(self.cursor));
            self.cursor += 1;
            let (t, g) = self.encode_row(&p.full_text());
            tokens.extend(t);
            targets.extend(g);
        }
        Batch { tokens, targets, batch: self.batch, seq_len: self.seq_len }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Split, Suite};
    use crate::runtime::Manifest;

    fn batcher() -> TrainBatcher {
        let tok = Tokenizer::from_spec(&Manifest::builtin().tokenizer);
        TrainBatcher::new(MathGen::new(Suite::Gsm8kSim, Split::Train, 0), tok, 4, 128)
    }

    #[test]
    fn batch_shapes() {
        let mut b = batcher();
        let batch = b.next_batch();
        assert_eq!(batch.tokens.len(), 4 * 128);
        assert_eq!(batch.targets.len(), 4 * 128);
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let b = batcher();
        let (t, g) = b.encode_row("q: 1 + 1?\na: 1 + 1 = 2 #### 2");
        // where both defined: target[i] == token[i+1]
        let text_len = t.iter().position(|&x| x == 0).unwrap();
        for i in 0..text_len - 1 {
            assert_eq!(g[i], t[i + 1], "pos {i}");
        }
        // last supervised target is EOS
        assert_eq!(g[text_len - 1], 2);
    }

    #[test]
    fn rows_start_with_bos_and_pad_tail() {
        let b = batcher();
        let (t, g) = b.encode_row("q: x?\na: 1 #### 1");
        assert_eq!(t[0], 1); // BOS
        assert_eq!(*t.last().unwrap(), 0);
        assert_eq!(*g.last().unwrap(), 0);
    }

    #[test]
    fn stream_advances() {
        let mut b = batcher();
        let a = b.next_batch();
        let c = b.next_batch();
        assert_ne!(a.tokens, c.tokens);
        assert_eq!(b.cursor(), 8);
    }

    #[test]
    fn shard_union_equals_unsharded_stream_in_order() {
        for n in [1usize, 2, 4] {
            let mut full = batcher();
            let mut shards: Vec<TrainBatcher> =
                (0..n).map(|r| batcher().shard(n, r)).collect();
            for step in 0..3 {
                let want = full.next_batch();
                let mut tokens = Vec::new();
                let mut targets = Vec::new();
                for s in shards.iter_mut() {
                    let b = s.next_batch();
                    assert_eq!(b.batch, 4 / n, "step {step}: local batch");
                    tokens.extend(b.tokens);
                    targets.extend(b.targets);
                }
                assert_eq!(tokens, want.tokens, "step {step}, {n} shards");
                assert_eq!(targets, want.targets, "step {step}, {n} shards");
            }
        }
    }

    #[test]
    #[should_panic(expected = "do not divide")]
    fn shard_rejects_non_dividing_counts() {
        let _ = batcher().shard(3, 0);
    }

    #[test]
    fn long_text_truncates_cleanly() {
        let b = batcher();
        let long = "q: ".to_string() + &"9 + ".repeat(100) + "1?\na: 1 #### 1";
        let (t, g) = b.encode_row(&long);
        assert_eq!(t.len(), 128);
        assert_eq!(g.len(), 128);
        assert!(t.iter().all(|&x| x >= 0 && x < 64));
    }
}
