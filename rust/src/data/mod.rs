//! Data substrate: tokenizer, synthetic math corpus, batching.
//!
//! The paper fine-tunes on MetaMathQA-40K and evaluates on GSM8K/MATH —
//! none of which are available in this environment (repro band 0). The
//! substitution (DESIGN.md §2) is a deterministic generator of templated
//! math word problems in the same format (`question → reasoning →
//! `#### <answer>`), with two difficulty suites standing in for the two
//! benchmarks:
//!
//! * `gsm8k-sim` — 1–3 step small-operand word problems;
//! * `math-sim`  — 3–5 step expressions with larger operands, mod/square.
//!
//! Train and eval splits draw from disjoint seed namespaces.

mod dataset;
pub mod mathgen;
mod tokenizer;

pub use dataset::{Batch, TrainBatcher};
pub use mathgen::{extract_answer, MathGen, Problem, Split, Suite};
pub use tokenizer::Tokenizer;
