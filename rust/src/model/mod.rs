//! Rust-side model substrate: parameter store + the reference transformer.
//!
//! The coordinator is deliberately shape-oblivious: a model is a list of
//! paper-"blocks" (embed | layer 0..L-1 | final norm + head), each one flat
//! `Vec<f32>` whose internal tensor layout is described by the manifest.
//! Initialization follows each tensor's init spec (`normal:<std>`, `ones`,
//! `zeros`) with a per-tensor seeded stream so results are reproducible and
//! independent of block iteration order.
//!
//! [`forward`] holds the pure-Rust transformer forward/backward that backs
//! `runtime::ReferenceBackend` — the dense correctness reference every
//! selective method is validated against.

pub mod forward;
mod state;

pub use state::{BlockStats, ModelState};
