//! Rust-side parameter store: per-block flat vectors + seeded init +
//! checkpointing.
//!
//! The coordinator is deliberately shape-oblivious: a model is a list of
//! paper-"blocks" (embed | layer 0..L-1 | final norm + head), each one flat
//! `Vec<f32>` whose internal tensor layout is described by the manifest.
//! Initialization follows each tensor's init spec (`normal:<std>`, `ones`,
//! `zeros`) with a per-tensor ChaCha stream so results are reproducible and
//! independent of block iteration order.

mod state;

pub use state::{BlockStats, ModelState};
